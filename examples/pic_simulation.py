"""End-to-end driver — the paper's own kind of workload: a BIT1-style PIC-MC
ionization simulation streaming diagnostics (.dat analogue) and particle
dumps (.dmp analogue) through openPMD + the JBP(BP4) engine with
aggregation + blosc compression, monitored by the Darshan layer, with
checkpoint/restart.

    PYTHONPATH=src python examples/pic_simulation.py [--steps 2000]
"""
import argparse
import pathlib
import tempfile
import time

import jax

from repro.configs.bit1 import IO_KNOBS, cpu_config
from repro.core import EngineConfig, Series
from repro.core.darshan import MONITOR
from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
from repro.pic.simulation import (diagnostics, init_sim, pic_run_chunk,
                                  write_diagnostics_openpmd,
                                  write_particle_dump_openpmd)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--mvstep", type=int, default=200,
                    help="diagnostic interval (paper: 1000)")
    ap.add_argument("--dmpstep", type=int, default=1000,
                    help="checkpoint interval (paper: 10000)")
    ap.add_argument("--scale", type=int, default=256,
                    help="paper-size divisor (100K cells / scale)")
    ap.add_argument("--n-io-ranks", type=int, default=16)
    args = ap.parse_args(argv)

    workdir = pathlib.Path(tempfile.mkdtemp(prefix="repro-pic-"))
    cfg = cpu_config(args.scale)
    print(f"BIT1 use case (scaled 1/{args.scale}): {cfg.n_cells} cells, "
          f"3 species x {cfg.n_electrons} particles, {args.steps} steps")
    print(f"I/O knobs: mvstep={args.mvstep} dmpstep={args.dmpstep} "
          f"(paper: {IO_KNOBS['mvstep']}/{IO_KNOBS['dmpstep']})")

    MONITOR.reset()
    series = Series(workdir / "diag.bp4", "w", n_ranks=args.n_io_ranks,
                    engine_config=EngineConfig(aggregators=4, codec="blosc",
                                               workers=4))
    state = init_sim(cfg, jax.random.PRNGKey(0))
    t0 = time.time()
    for start in range(0, args.steps, args.mvstep):
        n = min(args.mvstep, args.steps - start)
        state = pic_run_chunk(state, cfg, n)
        write_diagnostics_openpmd(series, state, cfg,
                                  n_io_ranks=args.n_io_ranks)
        if int(state.step) % args.dmpstep == 0:
            write_particle_dump_openpmd(series, state, cfg,
                                        n_io_ranks=args.n_io_ranks)
            save_checkpoint(workdir / "ckpt", state._asdict(),
                            int(state.step), n_io_ranks=args.n_io_ranks)
        series.flush()
        d = diagnostics(state, cfg)
        print(f"  step {int(state.step):6d}  e={d['count/e']:9.0f} "
              f"D+={d['count/D_plus']:9.0f} D={d['count/D']:9.0f} "
              f"ionized={d['ionizations']:9.0f}")
    series.close()
    wall = time.time() - t0

    # restart proof: restore the last checkpoint and continue 100 steps
    back, at = restore_checkpoint(workdir / "ckpt",
                                  jax.tree_util.tree_map(lambda x: x,
                                                         state._asdict()))
    from repro.pic.simulation import PicState
    restored = PicState(**back)
    restored = pic_run_chunk(restored, cfg, 100)
    print(f"restart from step {at} OK -> continued to {int(restored.step)}")

    rep = MONITOR.report(args.n_io_ranks)
    print(f"\nwall={wall:.1f}s  bytes_written="
          f"{rep['total']['POSIX_BYTES_WRITTEN']/2**20:.1f}MiB  "
          f"files={MONITOR.total_files_written()}")
    cost = MONITOR.cost_per_process(args.n_io_ranks)
    print(f"darshan per-process: read={cost['read_s']:.4f}s "
          f"write={cost['write_s']:.4f}s meta={cost['meta_s']:.4f}s")
    print(f"openPMD series: {workdir / 'diag.bp4'}")


if __name__ == "__main__":
    main()
