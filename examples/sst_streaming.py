"""In-situ streaming (the paper's §VI future work, implemented): PIC
diagnostics flow producer->consumer through the SST-style engine with NO
filesystem in the loop — the consumer computes live ionization statistics
while the simulation keeps stepping.

    PYTHONPATH=src python examples/sst_streaming.py
"""
import threading

import jax
import numpy as np

from repro.configs.bit1 import cpu_config
from repro.core.sst_engine import SstStream, attach_consumer
from repro.pic.simulation import diagnostics, init_sim, pic_run_chunk


def main():
    cfg = cpu_config(512)
    stream = SstStream(queue_depth=2)
    history = []

    def consumer(step, data):
        ne = float(data["density_e"].sum() * cfg.dx)
        nn = float(data["density_D"].sum() * cfg.dx)
        history.append((step, ne, nn))
        print(f"  [consumer] step {step:5d}: n_e={ne:9.0f} n_D={nn:9.0f}")

    t = attach_consumer(stream, consumer)
    state = init_sim(cfg, jax.random.PRNGKey(0))
    for chunk in range(6):
        state = pic_run_chunk(state, cfg, 100)
        d = diagnostics(state, cfg)
        stream.begin_step(int(state.step))
        for name in ("density/e", "density/D"):
            arr = d[name]
            stream.put(name.replace("/", "_"), arr, global_shape=arr.shape,
                       offset=(0,))
        stream.end_step()
    stream.close()
    t.join(timeout=10)

    assert len(history) == 6
    assert history[-1][2] < history[0][2], "neutrals should deplete"
    print(f"\nstreamed {len(history)} steps in-situ; neutral depletion "
          f"{history[0][2]:.0f} -> {history[-1][2]:.0f} (no files written)")


if __name__ == "__main__":
    main()
