"""In-situ streaming (the paper's §VI future work, implemented): PIC
diagnostics flow producer->consumer through the SST-style engine, a
`repro.insitu` ReducerSet analyzes them live while the simulation keeps
stepping, and a tee persists the same snapshots to a BP4 series. At the
end the post-hoc replay over `BpReader` must match the live reduction
EXACTLY (the insitu parity guarantee), and `jbpls` inspects the series
from metadata alone.

    PYTHONPATH=src python examples/sst_streaming.py
"""
import tempfile
from pathlib import Path

import jax

from repro.configs.bit1 import cpu_config
from repro.core.async_engine import AsyncBpWriter
from repro.core.bp_engine import EngineConfig
from repro.core.sst_engine import SstStream
from repro.insitu import (FieldEnergy, Moments, ReducerSet, SpeciesCount,
                          assert_parity, attach_reducers, reduce_posthoc)
from repro.pic.simulation import init_sim, run_with_diagnostics
from repro.tools import jbpls


def make_reducers(cfg) -> ReducerSet:
    return ReducerSet([
        SpeciesCount("density/e", scale=cfg.dx, name="n_e"),
        SpeciesCount("density/D", scale=cfg.dx, name="n_D"),
        Moments("vdist/e", name="vdist_moments"),
        FieldEnergy("density/e", cell_volume=cfg.dx, name="e_field_energy"),
    ])


def main():
    cfg = cpu_config(512)
    out = Path(tempfile.mkdtemp(prefix="repro-sst-")) / "insitu.bp4"

    # producer -> stream -> {live reducers, tee -> async BP4 series}
    tee = AsyncBpWriter(out, n_ranks=4,
                        cfg=EngineConfig(aggregators=2, codec="blosc"))
    stream = SstStream(queue_depth=2, tee=tee)
    live = make_reducers(cfg)
    consumer = attach_reducers(stream, live)

    state = init_sim(cfg, jax.random.PRNGKey(0))
    state = run_with_diagnostics(state, cfg, None, n_chunks=6,
                                 steps_per_chunk=100, stream=stream)
    stream.close()
    consumer.join(timeout=10)

    # post-hoc replay over the teed series must match the live run exactly
    posthoc = reduce_posthoc(str(out), make_reducers(cfg))
    assert_parity(live.results(), posthoc)

    res = live.results()
    n_e, n_D = res["n_e"]["counts"], res["n_D"]["counts"]
    for step, ne, nd in zip(res["n_e"]["steps"], n_e, n_D):
        print(f"  [live] step {step:5d}: n_e={ne:9.0f} n_D={nd:9.0f}")
    assert n_D[-1] < n_D[0], "neutrals should deplete"
    print(f"\nstreamed {len(n_e)} steps in-situ; neutral depletion "
          f"{n_D[0]:.0f} -> {n_D[-1]:.0f}; live == post-hoc (exact)\n")

    print("jbpls (metadata-only listing of the teed series):")
    jbpls.main([str(out), "-l", "-L"])


if __name__ == "__main__":
    main()
