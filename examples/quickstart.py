"""Quickstart: train a small LM with openPMD/JBP checkpointing, crash it,
resume it, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import tempfile

import numpy as np

from repro.configs.base import get_config, reduce_for_smoke
from repro.core.darshan import MONITOR
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    workdir = pathlib.Path(tempfile.mkdtemp(prefix="repro-quickstart-"))
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    tcfg = TrainerConfig(steps=40, log_every=10, ckpt_every=10,
                         seq_len=128, global_batch=8)
    hp = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=40)

    print("== phase 1: train, crash at step 25 ==")
    try:
        Trainer(cfg, tcfg, hp, workdir / "ckpt").run(crash_at=25)
    except RuntimeError as e:
        print(f"   {e}")

    print("== phase 2: auto-resume from the newest valid checkpoint ==")
    out = Trainer(cfg, tcfg, hp, workdir / "ckpt").run()

    print("== phase 3: greedy serving ==")
    eng = ServeEngine(cfg, out["state"]["params"],
                      ServeConfig(max_batch=2, max_seq=160, max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32)
    toks = eng.generate(prompts)
    print("   generated:", toks.tolist())

    print("== darshan I/O report ==")
    cost = MONITOR.cost_per_process()
    print(f"   per-process read={cost['read_s']:.4f}s "
          f"write={cost['write_s']:.4f}s meta={cost['meta_s']:.4f}s")
    print(f"   workdir: {workdir}")


if __name__ == "__main__":
    main()
