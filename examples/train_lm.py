"""Train a ~100M-param LM for a few hundred steps with the full stack:
sharded AdamW, remat'd flash attention, async JBP checkpoints, restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch smollm-360m]

The default config is a 6-layer cut of smollm-360m (~100M params, most of it
embedding) sized for this 1-core container; --full uses the real config.
"""
import argparse
import dataclasses
import pathlib
import tempfile

from repro.configs.base import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (slow on CPU)")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-100m", n_layers=6,
                                  d_model=512, n_heads=8, n_kv_heads=8,
                                  d_ff=1536, head_dim=None)
    n = cfg.n_params()
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={args.steps} "
          f"seq={args.seq} batch={args.batch}")

    workdir = pathlib.Path(tempfile.mkdtemp(prefix="repro-train-"))
    tcfg = TrainerConfig(steps=args.steps, log_every=10,
                         ckpt_every=max(args.steps // 4, 10),
                         seq_len=args.seq, global_batch=args.batch,
                         grad_compression=args.grad_compression)
    hp = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    out = Trainer(cfg, tcfg, hp, workdir / "ckpt").run()
    first, last = out["history"][0], out["history"][-1]
    print(f"\nloss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"({last['wall_s']:.1f}s wall)")
    print(f"checkpoints: {workdir / 'ckpt'}")


if __name__ == "__main__":
    main()
