"""The paper's optimization story in one script: sweep aggregators,
compressors, and stripe settings for a fixed checkpoint-like workload, and
print the Fig-6/7/9-style comparison with Darshan cost attribution.

    PYTHONPATH=src python examples/io_tuning.py
"""
import pathlib
import shutil
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import GiB, pic_payload
from repro.core.bp_engine import BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.striping import StripeConfig


def one(tag, cfg, n_ranks=64, bytes_per_rank=512 * 1024, steps=2):
    MONITOR.reset()
    d = pathlib.Path(tempfile.mkdtemp(prefix="repro-tune-"))
    try:
        t0 = time.perf_counter()
        w = BpWriter(d / "s.bp4", n_ranks, cfg)
        total = 0
        for s in range(steps):
            w.begin_step(s)
            for r in range(n_ranks):
                arr = pic_payload(r, bytes_per_rank)["particles"]
                total += arr.nbytes
                w.put("p/x", arr, global_shape=(arr.size * n_ranks,),
                      offset=(arr.size * r,), rank=r)
            w.end_step()
        w.close()
        dt = time.perf_counter() - t0
        stored = MONITOR.report()["total"]["POSIX_BYTES_WRITTEN"]
        cost = MONITOR.cost_per_process(n_ranks)
        print(f"{tag:42s} {total/dt/GiB:7.3f} GiB/s  ratio={total/stored:5.2f} "
              f"meta/proc={cost['meta_s']*1e3:6.2f}ms")
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main():
    print(f"{'configuration':42s} {'throughput':>10s}")
    for m in (1, 4, 16, 64):
        one(f"aggregators={m}", EngineConfig(aggregators=m, workers=4))
    for codec in ("none", "blosc", "bzip2"):
        one(f"codec={codec} (1 AGGR)",
            EngineConfig(aggregators=1, codec=codec, workers=4))
    for c, s in ((1, 1 << 20), (4, 1 << 20), (4, 1 << 18), (8, 1 << 16)):
        one(f"stripe count={c} size={s >> 10}KiB (blosc, 1 AGGR)",
            EngineConfig(aggregators=1, codec="blosc", workers=4,
                         stripe=StripeConfig(c, s), n_osts=8))


if __name__ == "__main__":
    main()
