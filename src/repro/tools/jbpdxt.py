"""jbpdxt CLI — analyze a DXT per-operation trace (`dxt.json` sidecar).

The counters-only view (`parser_dump`, `jbpls --io-report`) says how MUCH
I/O happened; the DXT trace says WHEN — which rank wrote which bytes to
which subfile, and what the step lifecycle (snapshot/compress/transport/
seal/commit) was doing around it. This tool is the darshan-parser
equivalent for our traces:

    PYTHONPATH=src python -m repro.tools.jbpdxt SERIES_OR_TRACE
        [--bins N] [--chrome out.json] [--dxt out.txt] [--json]

  * timeline summary — event/span counts, busy time and byte totals per
    op, trace wall span, drop counter,
  * per-subfile straggler table — for every file touched by write/read
    ops: op count, byte total (exactly the file's Darshan
    POSIX_BYTES_WRITTEN/READ), busy time, effective bandwidth, and when
    the file FINISHED relative to the earliest finisher — the straggler
    column the paper reads off its DXT plots (an `ost<k>/` path component
    is surfaced as the OST column),
  * bandwidth-over-time — bytes moved per time bin (`--bins`, default
    20) with an ASCII sparkbar, the "did the commit stall the stream?"
    view,
  * exports — `--chrome out.json` (Perfetto / chrome://tracing loadable)
    and `--dxt out.txt` (darshan-parser DXT-style text).

Accepts a series directory (reads its `dxt.json`) or a trace file path.
Shares `repro.tools._runner` conventions: exit 0 ok, 2 usage/not-a-trace.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys
from collections import defaultdict

from repro.core.darshan import open_file
from repro.core.dxt import SPAN_OPS, load_trace, to_chrome, to_dxt_text
from repro.tools import _runner as R

_OST_RE = re.compile(r"(?:^|/)ost(\d+)/")
_BAR = " .:-=+*#%@"


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n:.0f}B"
        n /= 1024
    return f"{n:.1f}GiB"


def summarize(events, dropped: int = 0) -> dict:
    """The machine-readable analysis (--json prints this verbatim):
    {"span_s", "ops": {op: {count, busy_s, bytes}}, "files": {path:
    {ops, bytes_written, bytes_read, busy_s, t_end, ost}}, "dropped"}."""
    ops: dict = defaultdict(lambda: {"count": 0, "busy_s": 0.0, "bytes": 0})
    files: dict = {}
    t_lo, t_hi = float("inf"), float("-inf")
    for src, rank, path, op, off, ln, t0, t1 in events:
        t_lo, t_hi = min(t_lo, t0), max(t_hi, t1)
        o = ops[op]
        o["count"] += 1
        o["busy_s"] += t1 - t0
        o["bytes"] += int(ln)
        if op in SPAN_OPS or op == "shm_write" or not path:
            continue
        f = files.setdefault(path, {"ops": 0, "bytes_written": 0,
                                    "bytes_read": 0, "busy_s": 0.0,
                                    "t_end": t1, "ost": None})
        f["ops"] += 1
        f["busy_s"] += t1 - t0
        f["t_end"] = max(f["t_end"], t1)
        if op == "write":
            f["bytes_written"] += int(ln)
        elif op == "read":
            f["bytes_read"] += int(ln)
        m = _OST_RE.search(path)
        if m:
            f["ost"] = int(m.group(1))
    return {"events": len(events), "dropped": int(dropped),
            "span_s": (t_hi - t_lo) if events else 0.0,
            "t0": t_lo if events else 0.0,
            "ops": {k: dict(v) for k, v in sorted(ops.items())},
            "files": files}


def bandwidth_bins(events, n_bins: int) -> list[tuple[float, int]]:
    """(bin_start_s_rel, bytes) per bin — write/read bytes attributed to
    the bin the op ENDED in (one op, one bin: totals stay exact)."""
    data = [(e[7], int(e[5])) for e in events if e[3] in ("write", "read")]
    if not data:
        return []
    t_lo = min(e[6] for e in events)
    t_hi = max(t for t, _ in data)
    width = max((t_hi - t_lo) / n_bins, 1e-9)
    bins = [0] * n_bins
    for t, nb in data:
        bins[min(int((t - t_lo) / width), n_bins - 1)] += nb
    return [(i * width, b) for i, b in enumerate(bins)]


def _print_report(summ: dict, bins: list, out=None):
    out = out if out is not None else sys.stdout
    p = lambda *a: print(*a, file=out)          # noqa: E731
    p(f"# jbpdxt: {summ['events']} events over {summ['span_s']:.3f}s "
      f"(dropped: {summ['dropped']})")
    p("#")
    p("# timeline summary")
    p(f"{'op':<12}{'count':>8}{'busy_s':>12}{'bytes':>12}")
    for op, o in summ["ops"].items():
        kind = "span" if op in SPAN_OPS else "posix"
        p(f"{op:<12}{o['count']:>8}{o['busy_s']:>12.6f}"
          f"{_fmt_bytes(o['bytes']):>12}  [{kind}]")
    files = summ["files"]
    if files:
        p("#")
        p("# per-subfile straggler table (straggler_s: finished this long "
          "after the first finisher)")
        first_end = min(f["t_end"] for f in files.values())
        p(f"{'file':<28}{'ost':>4}{'ops':>6}{'written':>12}{'read':>12}"
          f"{'busy_s':>10}{'MiB/s':>8}{'straggler_s':>12}")
        for path in sorted(files, key=lambda k: files[k]["t_end"]):
            f = files[path]
            nb = f["bytes_written"] + f["bytes_read"]
            bw = (nb / f["busy_s"] / 1024 ** 2) if f["busy_s"] > 0 else 0.0
            name = path if len(path) <= 27 else "…" + path[-26:]
            p(f"{name:<28}{f['ost'] if f['ost'] is not None else '-':>4}"
              f"{f['ops']:>6}{_fmt_bytes(f['bytes_written']):>12}"
              f"{_fmt_bytes(f['bytes_read']):>12}{f['busy_s']:>10.6f}"
              f"{bw:>8.1f}{f['t_end'] - first_end:>12.6f}")
    if bins:
        p("#")
        p("# bandwidth over time (write+read bytes per bin)")
        peak = max(b for _, b in bins) or 1
        for t, b in bins:
            bar = _BAR[min(int(b / peak * (len(_BAR) - 1)), len(_BAR) - 1)]
            p(f"  t+{t:9.4f}s {_fmt_bytes(b):>12} |{bar * 3}")


def main(argv=None) -> int:
    ap = R.make_parser(
        "jbpdxt", "analyze a DXT per-operation I/O trace: timeline "
        "summary, per-subfile straggler table, bandwidth-over-time, "
        "Chrome trace / DXT text export")
    ap.add_argument("trace",
                    help="series directory (containing dxt.json) or a "
                         "trace file written by TRACER.dump()")
    ap.add_argument("--bins", type=int, default=20, metavar="N",
                    help="bandwidth-over-time bin count (default 20)")
    ap.add_argument("--chrome", default=None, metavar="OUT.json",
                    help="write a Chrome trace-event JSON (load in "
                         "Perfetto / chrome://tracing)")
    ap.add_argument("--dxt", default=None, metavar="OUT.txt",
                    help="write darshan-parser DXT-style text")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print the machine-readable summary instead of "
                         "the tables")
    args = ap.parse_args(argv)

    try:
        doc = load_trace(args.trace)
    except FileNotFoundError:
        print(f"jbpdxt: {args.trace}: no trace found (run with JBP_DXT=1 "
              f"or TRACER.enable() to produce a dxt.json sidecar)",
              file=sys.stderr)
        return R.EXIT_USAGE
    except (ValueError, json.JSONDecodeError) as e:
        print(f"jbpdxt: {e}", file=sys.stderr)
        return R.EXIT_USAGE
    events, dropped = doc["events"], doc.get("dropped", 0)

    if args.chrome:
        with open_file(args.chrome, "w") as f:
            json.dump(to_chrome(events, dropped), f)
        print(f"jbpdxt: wrote Chrome trace -> {args.chrome} "
              f"(open in https://ui.perfetto.dev)", file=sys.stderr)
    if args.dxt:
        with open_file(args.dxt, "w") as f:
            f.write(to_dxt_text(events, dropped))
        print(f"jbpdxt: wrote DXT text -> {args.dxt}", file=sys.stderr)

    summ = summarize(events, dropped)
    if args.as_json:
        print(json.dumps(summ, indent=1))
    else:
        _print_report(summ, bandwidth_bins(events, max(1, args.bins)))
    if args.io_report:
        R.io_report("jbpdxt")
    return R.EXIT_OK


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
