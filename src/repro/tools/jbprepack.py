"""jbprepack — rewrite a JBP (BP4) series at a new aggregator count,
optionally recompressing and restriping along the way.

The elastic-restart gap, closed: shards and subfiles are per-writer
artifacts, so a series written at W=8 was stuck at 8 subfiles forever.
Repack replays the committed steps through the chunk tables — per chunk,
a box read of exactly that chunk's extent (fanned out over a ReaderPool
with `--parallel`) and a `put()` under the SAME rank/offset — into a fresh
series with W′ aggregators, a different codec, or a different stripe
layout. Chunk structure (rank, offset, extent), per-chunk min/max
statistics, per-step attributes, dtypes and shapes are all preserved, so
the output is byte-equivalent UNDER THE READER: `read_var` returns
bit-identical arrays for every variable of every step. (The files
themselves differ — that is the point: new aggregation/codec/striping.)

    PYTHONPATH=src python -m repro.tools.jbprepack SRC DST -w W' [options]

Options:
    -w / --writers W'   aggregator count of the output series (required)
    --codec C           recompress with C (none|blosc|zlib|bzip2);
                        default: keep the source series' codec
    --stripe CxS        stripe each output subfile over C OSTs, S bytes
                        per stripe (e.g. 2x65536)
    --n-osts K          OST pool size for --stripe (default 4)
    --parallel N        ReaderPool workers for the chunk reads
    --workers K         writer-pool threads of the output engine
    --verify            re-read BOTH series afterwards and assert every
                        variable is bit-identical (the paranoid mode CI
                        uses)
    --force             overwrite DST if it exists
    --io-report         print this run's own Darshan counters to stderr

Torn/uncommitted steps of the source are dropped (only md.idx-committed
steps replay) — repack of a crashed series is also its repair.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import sys
import time
from typing import Optional

from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import open_file
from repro.core.striping import StripeConfig
from repro.tools import _runner as R


def _source_codec(path: pathlib.Path) -> str:
    """Codec recorded in profiling.json, or 'none' for bare series."""
    p = path / "profiling.json"
    try:
        with open_file(p, "r") as f:
            return json.loads(f.read()).get("codec", "none")
    except (OSError, ValueError):
        return "none"


def _source_ranks(reader: BpReader) -> int:
    """The put()-rank space of the source: max rank in any chunk table + 1
    (the writer needs n_ranks only to validate puts and assign
    aggregators)."""
    hi = 0
    for step in reader.valid_steps():
        for name in reader.var_names(step):
            for ch in reader.iter_chunks(step, name):
                hi = max(hi, ch.rank)
    return hi + 1


def repack(src, dst, *, n_writers: int, codec: Optional[str] = None,
           stripe: Optional[StripeConfig] = None, n_osts: int = 4,
           parallel: int = 0, workers: int = 4,
           fsync_policy: str = "close") -> dict:
    """Rewrite `src` -> `dst` with W′=`n_writers` aggregators. Returns
    {steps, vars, chunks, bytes_read_raw, bytes_stored, wall_s}."""
    src = pathlib.Path(str(src))
    dst = pathlib.Path(str(dst))
    t0 = time.perf_counter()
    stats = {"steps": 0, "vars": 0, "chunks": 0, "bytes_raw": 0,
             "bytes_stored": 0}
    with BpReader(src, parallel=parallel) as reader:
        steps = reader.valid_steps()
        cfg = EngineConfig(
            aggregators=max(1, int(n_writers)),
            codec=codec if codec is not None else _source_codec(src),
            stripe=stripe, n_osts=n_osts, workers=workers,
            fsync_policy=fsync_policy)
        n_ranks = _source_ranks(reader) if steps else 1
        w = BpWriter(dst, n_ranks, cfg)
        try:
            for step in steps:
                w.begin_step(step)
                # per-step exactness: exactly what the source step
                # recorded, not this writer's accumulation so far
                w.replace_attributes(reader.attributes(step))
                names = reader.var_names(step)
                for name in names:
                    info = reader.var_info(step, name)
                    gshape = tuple(info["shape"])
                    # one full-array read per variable: the multi-chunk
                    # plan is what the ReaderPool parallelises; each
                    # chunk is then re-put as a slice of it, preserving
                    # the (rank, offset, extent) chunk structure exactly
                    full = reader.read_var(step, name)
                    for ch in reader.iter_chunks(step, name):
                        sl = tuple(slice(o, o + e) for o, e in
                                   zip(ch.offset, ch.extent))
                        w.put(name, full[sl], global_shape=gshape,
                              offset=ch.offset, rank=ch.rank)
                        stats["chunks"] += 1
                    stats["bytes_raw"] += full.nbytes
                prof = w.end_step()
                stats["bytes_stored"] += prof["bytes_stored"]
                stats["steps"] += 1
                stats["vars"] = max(stats["vars"], len(names))
        except BaseException:
            try:
                w.close()
            except BaseException:        # noqa: BLE001
                pass
            raise
        w.close()
    stats["wall_s"] = time.perf_counter() - t0
    return stats


class RepackMismatch(AssertionError):
    """The repacked series is NOT byte-equivalent under the reader."""


def verify_equivalent(src, dst, *, parallel: int = 0) -> int:
    """Verify byte-equivalence under the reader: every committed step of
    `src` exists in `dst` and every variable reads back bit-identical
    (including dtype). Raises `RepackMismatch` on any divergence —
    explicit raises, not `assert`, so `python -O` cannot silently turn
    the paranoid mode into a no-op. Returns the arrays compared."""
    n = 0
    with BpReader(src, parallel=parallel) as a, \
            BpReader(dst, parallel=parallel) as b:
        if a.valid_steps() != b.valid_steps():
            raise RepackMismatch(f"step sets differ: {a.valid_steps()} "
                                 f"vs {b.valid_steps()}")
        for step in a.valid_steps():
            if a.var_names(step) != b.var_names(step):
                raise RepackMismatch(f"step {step}: variable sets differ")
            if a.attributes(step) != b.attributes(step):
                raise RepackMismatch(f"step {step}: attributes differ")
            for name in a.var_names(step):
                x = a.read_var(step, name)
                y = b.read_var(step, name)
                if x.dtype != y.dtype or x.shape != y.shape:
                    raise RepackMismatch(
                        f"step {step} var {name!r}: {x.dtype}{x.shape} "
                        f"vs {y.dtype}{y.shape}")
                if x.tobytes() != y.tobytes():
                    raise RepackMismatch(
                        f"step {step} var {name!r} differs after repack")
                n += 1
    return n


def _parse_stripe(spec: str) -> StripeConfig:
    count, size = spec.lower().split("x", 1)
    return StripeConfig(stripe_count=int(count), stripe_size=int(size))


def main(argv=None) -> int:
    ap = R.make_parser(
        "jbprepack", "rewrite a JBP (BP4) series at a new aggregator "
        "count / codec / striping — byte-equivalent under the reader",
        parallel_flag=True)
    ap.add_argument("src", help="source <name>.bp4 directory")
    ap.add_argument("dst", help="destination directory (created)")
    ap.add_argument("-w", "--writers", type=int, required=True,
                    help="output aggregator count W'")
    ap.add_argument("--codec", default=None,
                    choices=("none", "blosc", "zlib", "bzip2"),
                    help="recompress with this codec (default: keep)")
    ap.add_argument("--stripe", default=None, metavar="CxS",
                    help="stripe output subfiles: COUNTxSIZE, e.g. 2x65536")
    ap.add_argument("--n-osts", type=int, default=4, dest="n_osts")
    ap.add_argument("--workers", type=int, default=4,
                    help="writer-pool threads of the output engine")
    ap.add_argument("--verify", action="store_true",
                    help="re-read both series and assert bit parity")
    ap.add_argument("--force", action="store_true",
                    help="overwrite DST if it exists")
    args = ap.parse_args(argv)

    err = R.check_series(args.src)
    if err is not None:
        print(f"jbprepack: {err}", file=sys.stderr)
        return R.EXIT_USAGE
    if args.writers < 1:
        print("jbprepack: -w must be >= 1", file=sys.stderr)
        return R.EXIT_USAGE
    dst = pathlib.Path(args.dst)
    if dst.exists():
        if not args.force:
            print(f"jbprepack: {dst} exists (use --force)", file=sys.stderr)
            return R.EXIT_USAGE
        shutil.rmtree(dst)
    try:
        stripe = _parse_stripe(args.stripe) if args.stripe else None
    except ValueError:
        print(f"jbprepack: bad --stripe {args.stripe!r} "
              f"(expected COUNTxSIZE, e.g. 2x65536)", file=sys.stderr)
        return R.EXIT_USAGE

    stats = repack(args.src, dst, n_writers=args.writers, codec=args.codec,
                   stripe=stripe, n_osts=args.n_osts,
                   parallel=args.parallel, workers=args.workers)
    mib = stats["bytes_raw"] / max(stats["wall_s"], 1e-9) / 2**20
    print(f"jbprepack: {args.src} -> {dst}  W'={args.writers}"
          f"{' codec=' + args.codec if args.codec else ''}"
          f"{' stripe=' + args.stripe if args.stripe else ''}")
    print(f"  {stats['steps']} steps, {stats['chunks']} chunks, "
          f"{stats['bytes_raw'] / 2**20:.1f} MiB raw -> "
          f"{stats['bytes_stored'] / 2**20:.1f} MiB stored, "
          f"{stats['wall_s']:.3f}s ({mib:.0f} MiB/s)")
    if args.verify:
        n = verify_equivalent(args.src, dst, parallel=args.parallel)
        print(f"  verify: {n} arrays bit-identical under the reader")
    if args.io_report:
        R.io_report("jbprepack")
    return R.EXIT_OK


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
