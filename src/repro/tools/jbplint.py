"""jbplint — the project-invariant static analyzer (correctness plane).

Walks Python sources with `ast` and enforces the I/O-plane invariants the
repo has been burned by at review time (see `repro.analysis.checkers`):

    JBP001  bare `assert` as runtime validation (stripped under python -O)
    JBP002  raw open()/os.open/Path read-write helpers on the data planes
            (invisible to Darshan counters and DXT traces)
    JBP003  Darshan counter names as free literals (a typo silently mints
            a new counter; use the frozen `CTR` registry)
    JBP004  blocking calls inside a `with <lock>:` body
    JBP005  lambdas / nested functions handed to spawn-started workers

Exit codes follow the subsystem convention (fsck-flavoured, shared with
jbpfsck/jbpdxt): 0 clean, 1 findings, 2 usage error.

    python -m repro.tools.jbplint src/repro
    python -m repro.tools.jbplint --rules JBP004 src/repro/serve
    python -m repro.tools.jbplint --json src/repro > findings.json
    python -m repro.tools.jbplint --baseline jbplint-baseline.json src/repro
    python -m repro.tools.jbplint --write-baseline jbplint-baseline.json src

`--json` is what CI gates on (and uploads as an artifact); the baseline
flags park legacy findings so new code must come in clean.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis import (ALL_CHECKERS, analyze_paths, baseline_doc,
                            load_baseline, render_json, render_text)
from repro.tools import _runner as R


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="jbplint",
        description="static analyzer for the repo's I/O-plane invariants "
                    "(exit 0 clean / 1 findings / 2 usage)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to scan (e.g. src/repro)")
    ap.add_argument("--rules", metavar="JBPxxx[,JBPxxx]",
                    help="run only these rules")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--baseline", metavar="FILE",
                    help="ignore findings recorded in this baseline file")
    ap.add_argument("--write-baseline", metavar="FILE", dest="write_baseline",
                    help="record the current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true", dest="list_rules",
                    help="describe every rule and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for c in ALL_CHECKERS:
            print(f"{c.rule} [{c.name}]")
            print(f"    {c.description}")
        return R.EXIT_OK
    if not args.paths:
        print("jbplint: no paths given (try: jbplint src/repro)",
              file=sys.stderr)
        return R.EXIT_USAGE

    rules = None
    if args.rules:
        known = {c.rule for c in ALL_CHECKERS}
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        bad = sorted(rules - known)
        if bad:
            print(f"jbplint: unknown rules {bad} (known: {sorted(known)})",
                  file=sys.stderr)
            return R.EXIT_USAGE
    for p in args.paths:
        if not pathlib.Path(p).exists():
            print(f"jbplint: {p}: no such file or directory",
                  file=sys.stderr)
            return R.EXIT_USAGE

    baseline_keys = frozenset()
    if args.baseline:
        try:
            baseline_keys = load_baseline(args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"jbplint: cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return R.EXIT_USAGE

    res = analyze_paths(args.paths, rules=rules, baseline_keys=baseline_keys)

    if args.write_baseline:
        doc = baseline_doc(res.findings)
        # the baseline is a lint artifact, not series data
        pathlib.Path(args.write_baseline).write_text(   # jbplint: disable=JBP002
            json.dumps(doc, indent=1) + "\n")
        print(f"jbplint: wrote baseline with {len(res.findings)} "
              f"finding(s) -> {args.write_baseline}", file=sys.stderr)
        return R.EXIT_OK

    if args.as_json:
        print(json.dumps(render_json(res), indent=1))
    else:
        print(render_text(res))
    return R.EXIT_ISSUES if res.findings else R.EXIT_OK


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
