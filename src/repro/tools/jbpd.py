"""jbpd CLI — run (or administer) the JBP series data service.

Serve one or more series over a unix socket (local clients get zero-copy
shm responses) or a TCP port (remote clients, socket framing):

    PYTHONPATH=src python -m repro.tools.jbpd SERIES [SERIES...]
        --socket /tmp/jbpd.sock [--cache-mb 256] [--parallel N]
        [--ring-mb 64] [--no-shm] [--open-any] [--io-report]
    PYTHONPATH=src python -m repro.tools.jbpd SERIES --port 7454

The daemon pre-opens every listed series at startup (a bad path fails
fast, exit 2) and serves ONLY those unless `--open-any` lets clients name
arbitrary valid series. It runs until SIGINT/SIGTERM (or a client's
`shutdown` admin op), then prints its `--io-report` — the merged Darshan
counters including the service plane's SERVICE_CACHE_HIT/MISS,
SERVICE_COALESCED and SERVICE_SHM/SOCKET_BYTES.

With `--metrics-port PORT` the daemon also serves the Prometheus text
exposition of the metrics plane (`repro.core.metrics`) over plain HTTP —
`curl :PORT/metrics` — and enables histogram recording for its own
process (cache_fetch/serve/read latencies) if it was not already on.

Admin mode (against a RUNNING daemon; `SERIES` args are not needed):

    python -m repro.tools.jbpd --socket /tmp/jbpd.sock --stats
    python -m repro.tools.jbpd --socket /tmp/jbpd.sock --metrics
    python -m repro.tools.jbpd --socket /tmp/jbpd.sock --watch 5 --interval 2
    python -m repro.tools.jbpd --socket /tmp/jbpd.sock --shutdown

`--metrics` prints the `metrics` admin op's JSON (histogram cells,
percentile summaries, straggler report) — the same numbers the HTTP
exposition serves, over the framed socket protocol.

`--watch N` streams N live counter-DELTA frames from the daemon (the
`watch` op): each frame prints the non-zero deltas since the previous
frame plus cache occupancy — `watch`'s begin + the streamed deltas always
reconcile against a `--stats` taken at the same moment.

Shares the `repro.tools._runner` conventions (exit codes, --io-report)
with jbpls, jbprepack and jbpfsck.
"""
from __future__ import annotations

import json
import signal
import sys

from repro.core.metrics import METRICS
from repro.core.shm_transport import DEFAULT_RING_BYTES
from repro.serve.jbpd import (DEFAULT_CACHE_BYTES, DaemonDisconnectedError,
                              JbpDaemon, MetricsHttpShim, SeriesClient,
                              SeriesServer)
from repro.tools import _runner as R

MiB = 1024 ** 2


def main(argv=None) -> int:
    ap = R.make_parser(
        "jbpd", "long-lived series data service: jbpls-style metadata "
        "queries + read_var box reads over a socket, with an LRU "
        "decompressed-chunk cache, request coalescing and zero-copy shm "
        "responses", parallel_flag=True)
    ap.add_argument("series", nargs="*",
                    help="series to serve (pre-opened at startup)")
    ap.add_argument("--socket", default=None, metavar="PATH",
                    help="unix socket to listen on (local clients; enables "
                         "shm handoff)")
    ap.add_argument("--port", type=int, default=None,
                    help="TCP port to listen on instead of a unix socket")
    ap.add_argument("--host", default="127.0.0.1",
                    help="TCP bind address (with --port)")
    ap.add_argument("--cache-mb", type=int,
                    default=DEFAULT_CACHE_BYTES // MiB, metavar="MB",
                    help="decompressed-chunk cache budget (MiB)")
    ap.add_argument("--ring-mb", type=int,
                    default=DEFAULT_RING_BYTES // MiB, metavar="MB",
                    help="per-connection shm response ring size (MiB)")
    ap.add_argument("--no-shm", action="store_true",
                    help="disable shm handoff (socket framing only)")
    ap.add_argument("--open-any", action="store_true",
                    help="also serve valid series NOT listed at startup")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="also serve the Prometheus text exposition over "
                         "HTTP on this port (0 = ephemeral; enables "
                         "histogram recording)")
    ap.add_argument("--stats", action="store_true",
                    help="admin: query a running daemon's stats and exit")
    ap.add_argument("--metrics", action="store_true",
                    help="admin: print a running daemon's metrics op "
                         "(histograms, percentiles, stragglers) and exit")
    ap.add_argument("--watch", type=int, default=None, metavar="N",
                    help="admin: stream N live counter-delta frames from "
                         "a running daemon and exit")
    ap.add_argument("--interval", type=float, default=1.0, metavar="S",
                    help="seconds between --watch frames (default 1.0)")
    ap.add_argument("--shutdown", action="store_true",
                    help="admin: stop a running daemon and exit")
    args = ap.parse_args(argv)

    if (args.socket is None) == (args.port is None):
        print("jbpd: exactly one of --socket / --port is required",
              file=sys.stderr)
        return R.EXIT_USAGE
    address = args.socket if args.socket else (args.host, args.port)

    # ------------------------------------------------------------ admin mode
    if (args.stats or args.metrics or args.shutdown
            or args.watch is not None):
        try:
            with SeriesClient(address, shm=False) as c:
                if args.stats:
                    print(json.dumps(c.stats(), indent=1))
                if args.metrics:
                    print(json.dumps(c.metrics(), indent=1))
                if args.watch is not None:
                    def show(frame):
                        deltas = {k: v for k, v in frame["delta"].items()
                                  if v}
                        cache = frame["cache"]
                        strag = frame.get("stragglers") or []
                        tail = ""
                        if strag:
                            worst = strag[0]
                            tail = (f" STRAGGLER {worst['op']}/"
                                    f"{worst['key']} x{worst['ratio']:.1f}"
                                    + (f" (+{len(strag) - 1} more)"
                                       if len(strag) > 1 else ""))
                        print(f"jbpd watch #{frame['seq']}: "
                              f"{json.dumps(deltas) if deltas else 'idle'} "
                              f"cache={cache['entries']}e/"
                              f"{cache['bytes']}B{tail}", flush=True)
                    res = c.watch(interval_s=args.interval,
                                  count=max(1, args.watch), on_frame=show)
                    print(f"jbpd watch: {len(res['frames'])} frame(s); "
                          f"end counters: "
                          f"{json.dumps(res['end'])}", file=sys.stderr)
                if args.shutdown:
                    c.shutdown()
                    print("jbpd: daemon stopping", file=sys.stderr)
        except DaemonDisconnectedError as e:
            print(f"jbpd: {e}", file=sys.stderr)
            return R.EXIT_ISSUES
        return R.EXIT_OK

    # ------------------------------------------------------------ serve mode
    for s in args.series:
        err = R.check_series(s)
        if err is not None:
            print(f"jbpd: {err}", file=sys.stderr)
            return R.EXIT_USAGE
    try:
        server = SeriesServer(args.series, cache_bytes=args.cache_mb * MiB,
                              parallel=args.parallel,
                              open_any=args.open_any)
    except (OSError, ValueError) as e:
        print(f"jbpd: {e}", file=sys.stderr)
        return R.EXIT_USAGE
    daemon = JbpDaemon(server, socket_path=args.socket,
                       host=args.host, port=args.port,
                       shm=not args.no_shm, ring_bytes=args.ring_mb * MiB)
    shim = None
    if args.metrics_port is not None:
        METRICS.enable()                # a scrape surface implies recording
        shim = MetricsHttpShim(server, host=args.host,
                               port=args.metrics_port).start()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *a: daemon.stop())
    served = ", ".join(args.series) if args.series else "<any>"
    mtxt = (f", metrics http://{shim.host}:{shim.port}/metrics"
            if shim is not None else "")
    print(f"jbpd: listening on {daemon.address!r} serving {served} "
          f"(cache {args.cache_mb} MiB, parallel={args.parallel}, "
          f"shm={'off' if args.no_shm else 'on'}{mtxt})", file=sys.stderr,
          flush=True)
    try:
        daemon.serve_forever()
    finally:
        daemon.stop()
        if shim is not None:
            shim.stop()
    if args.io_report:
        R.io_report("jbpd")
    return R.EXIT_OK


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
