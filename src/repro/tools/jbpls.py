"""jbpls — bpls for the JBP engine: list a BP4-style series from metadata.

Mirrors ADIOS2's `bpls`: variables with dtype/shape/chunk counts, per-step
tables, attributes, per-aggregator subfile layout, compression ratios and
(with -l) min/max — all answered from `md.idx`/`md.0` ONLY. The paper's
"rapid metadata extraction" claim, as a tool: listing a 10k-step series
costs two metadata file reads and ZERO `data.*` subfile I/O (held by
`DarshanMonitor` counters in tests/test_insitu.py). The one exception is
`--dump VAR`, which by definition reads payload bytes.

    PYTHONPATH=src python -m repro.tools.jbpls <series.bp4> [options]

Options:
    -l            long listing: per-variable bytes (raw -> stored), ratio,
                  min/max from chunk statistics
    -s            per-step table (timestamp, #vars, raw/stored bytes)
    -A            series/step attributes
    -L            per-aggregator subfile layout (from chunk tables)
    --step N      restrict to one step
    --var SUBSTR  filter variables by substring
    --dump VAR    read and print a variable's values (touches data.*)
    --json        machine-readable output of everything listed
    --parallel N  ReaderPool workers for --dump reads
    --io-report   print this run's own Darshan counters to stderr

Shares the `repro.tools._runner` conventions (exit codes, --io-report)
with jbprepack and jbpfsck.
"""
from __future__ import annotations

import datetime
import json
import pathlib
import sys
from typing import Optional

import numpy as np

from repro.core.bp_engine import BpReader
from repro.core.darshan import open_file
from repro.tools import _runner as R


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def _step_span(steps: list) -> str:
    if not steps:
        return "none"
    lo, hi = steps[0], steps[-1]
    return f"{len(steps)} ({lo}..{hi})"


def _engine_info(path: pathlib.Path) -> dict:
    """Engine/codec from profiling.json when present (a metadata file,
    not a subfile — reading it keeps the O(metadata) guarantee)."""
    p = path / "profiling.json"
    if not p.exists():
        return {}
    try:
        with open_file(p, "r") as f:
            doc = json.loads(f.read())
    except (OSError, ValueError):
        return {}
    return {k: doc[k] for k in ("engine", "aggregators", "codec")
            if k in doc}


def survey(reader: BpReader, *, step: Optional[int] = None,
           var_filter: Optional[str] = None) -> dict:
    """Everything jbpls prints, as one metadata-only dict — a single
    `BpReader.scan()` pass over the chunk tables plus the series-level
    header info (engine knobs, attributes)."""
    steps = reader.valid_steps() if step is None else [step]
    # the filter goes INTO the scan so per-step totals, layout and minmax
    # all consistently cover exactly the listed variables
    flt = (lambda n: var_filter in n) if var_filter else None
    sc = reader.scan(steps=steps, name_filter=flt)
    return {"path": str(reader.path), "engine": _engine_info(reader.path),
            "steps": steps, "variables": sc["variables"],
            "per_step": sc["per_step"], "minmax": sc["minmax"],
            "layout": sc["layout"],
            "attrs": reader.attributes(steps[-1]) if steps else {}}


def format_listing(sv: dict, *, long_listing: bool = False,
                   show_steps: bool = False, show_attrs: bool = False,
                   show_layout: bool = False) -> str:
    lines = []
    eng = sv["engine"]
    eng_s = (f"  engine {eng.get('engine', '?')} aggregators="
             f"{eng.get('aggregators', '?')} codec={eng.get('codec', '?')}"
             if eng else "")
    lines.append(f"jbpls: {sv['path']}")
    lines.append(f"  steps: {_step_span(sv['steps'])}{eng_s}")
    raw = sum(v["raw"] for v in sv["variables"].values())
    stored = sum(v["stored"] for v in sv["variables"].values())
    ratio = raw / stored if stored else 1.0
    lines.append(f"  payload: {_fmt_bytes(raw)} raw -> "
                 f"{_fmt_bytes(stored)} stored ({ratio:.2f}x)")
    for name in sorted(sv["variables"]):
        v = sv["variables"][name]
        shape = "{" + ", ".join(str(x) for x in v["shape"]) + "}"
        if v.get("shape_varies"):
            shape += "*"                 # latest step's shape; varies
        row = (f"  {v['dtype']:>8}  {name:<40} {shape:<16} "
               f"{len(v['steps'])} steps  {v['chunks_per_step']} chunks/step")
        if long_listing:
            r = v["raw"] / v["stored"] if v["stored"] else 1.0
            row += (f"  {_fmt_bytes(v['raw'])} -> "
                    f"{_fmt_bytes(v['stored'])} ({r:.2f}x)")
            mm = sv["minmax"].get(name)
            row += (f"  min/max = {mm[0]:.6g} / {mm[1]:.6g}" if mm
                    else "  min/max = n/a")
        lines.append(row)
    if show_steps:
        lines.append("  --- steps ---")
        for ps in sv["per_step"]:
            t = datetime.datetime.fromtimestamp(ps["t_ns"] / 1e9)
            lines.append(f"  step {ps['step']:>6}  {t.isoformat()}  "
                         f"{ps['n_vars']} vars  "
                         f"{_fmt_bytes(ps['raw'])} -> "
                         f"{_fmt_bytes(ps['stored'])}")
    if show_attrs:
        lines.append("  --- attributes ---")
        for k in sorted(sv["attrs"]):
            lines.append(f"  {k} = {sv['attrs'][k]!r}")
    if show_layout:
        lines.append("  --- aggregator layout (from chunk tables) ---")
        for agg in sorted(sv["layout"]):
            d = sv["layout"][agg]
            lines.append(f"  data.{agg}: {d['chunks']} chunks  "
                         f"{_fmt_bytes(d['bytes'])}  "
                         f"end @ {_fmt_bytes(d['end'])}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = R.make_parser(
        "jbpls", "bpls-style metadata listing of a JBP "
        "(BP4) series — O(metadata) I/O, no subfile reads",
        parallel_flag=True)
    ap.add_argument("series", help="path to the <name>.bp4 directory")
    ap.add_argument("-l", action="store_true", dest="long_listing",
                    help="long listing (bytes, ratio, min/max)")
    ap.add_argument("-s", action="store_true", dest="show_steps",
                    help="per-step table")
    ap.add_argument("-A", action="store_true", dest="show_attrs",
                    help="attributes")
    ap.add_argument("-L", action="store_true", dest="show_layout",
                    help="per-aggregator subfile layout")
    ap.add_argument("--step", type=int, default=None,
                    help="restrict to one step")
    ap.add_argument("--var", default=None, help="substring variable filter")
    ap.add_argument("--dump", default=None, metavar="VAR",
                    help="read and print VAR's values (touches data.*)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    args = ap.parse_args(argv)

    path = pathlib.Path(args.series)
    reader = R.open_reader(path, parallel=args.parallel, prog="jbpls")
    if reader is None:
        return R.EXIT_USAGE
    with reader:
        if not reader.valid_steps():
            print(f"jbpls: {path}: no valid steps", file=sys.stderr)
            return R.EXIT_ISSUES
        if args.step is not None and args.step not in reader.idx_records:
            print(f"jbpls: {path}: no valid step {args.step} "
                  f"(have {_step_span(reader.valid_steps())})",
                  file=sys.stderr)
            return R.EXIT_ISSUES
        sv = survey(reader, step=args.step, var_filter=args.var)
        if args.as_json:
            print(json.dumps(sv, indent=1, default=_json_default))
        else:
            print(format_listing(sv, long_listing=args.long_listing,
                                 show_steps=args.show_steps,
                                 show_attrs=args.show_attrs,
                                 show_layout=args.show_layout))
        if args.dump:
            step = args.step if args.step is not None else sv["steps"][-1]
            try:
                arr = reader.read_var(step, args.dump)
            except KeyError:
                print(f"jbpls: no variable {args.dump!r} at step {step} "
                      f"(have {reader.var_names(step)})", file=sys.stderr)
                return R.EXIT_ISSUES
            print(f"  {args.dump} @ step {step}:")
            print(np.array2string(arr, threshold=64, precision=6))
    if args.io_report:
        R.io_report("jbpls")
    return R.EXIT_OK


def _json_default(o):
    if isinstance(o, (np.integer, np.floating)):
        return o.item()
    if isinstance(o, (tuple, set)):
        return list(o)
    raise TypeError(f"not JSON-serializable: {type(o)}")


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
