"""Command-line maintenance tools for JBP/openPMD series
(`python -m repro.tools.<x>`):

    jbpls      bpls-style metadata listing (O(metadata), zero data.* reads)
    jbprepack  rewrite a series at a new aggregator count / codec /
               striping — byte-equivalent under the reader
    jbpfsck    O(metadata) integrity scan; --repair truncates/reseals to
               the last consistent step
    jbpd       long-lived series data service: metadata queries + box
               reads over a socket for many concurrent clients, with an
               LRU decompressed-chunk cache, request coalescing and
               zero-copy shm responses (--stats/--shutdown administer a
               running daemon)

All four share the `repro.tools._runner` conventions: exit codes
(0 clean, 1 issues, 2 not-a-series), `--io-report` (the tool's own merged
Darshan counters), and `--parallel N` (ReaderPool fan-out) where payload
reads happen.
"""
