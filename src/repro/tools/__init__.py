"""Command-line tools for JBP/openPMD series (`python -m repro.tools.<x>`)."""
