"""Shared runner for the `repro.tools` CLIs (jbpls / jbprepack / jbpfsck).

One place for the things every series tool needs: the series-path sanity
check (exit code 2, fsck-style, when the argument is not a JBP series), the
common flags (`--io-report`, `--parallel`), the Darshan self-report, and
the `python -m repro.tools.<x>` entry-point guard.

Exit code convention (shared across the subsystem, fsck(8)-flavoured):

    0  clean / success
    1  issues found (fsck) or operation failed on a valid series
    2  usage error / not a JBP series

`--io-report` prints the tool's OWN merged Darshan counters to stderr at
exit — for jbpls that is the proof of the O(metadata) claim (zero data.*
reads); for jbprepack/jbpfsck it attributes the run's I/O to read/write/
meta time exactly like `parser_dump` does for the write plane. Counters
from ReaderPool worker threads land in the same process-wide MONITOR, so
the report always covers the whole read plane.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Optional

from repro.core.bp_engine import BpReader
from repro.core.darshan import CTR, MONITOR
from repro.core.metrics import METRICS, straggler_report, summarize_cell

EXIT_OK = 0
EXIT_ISSUES = 1
EXIT_USAGE = 2


def make_parser(prog: str, description: str, *,
                parallel_flag: bool = False) -> argparse.ArgumentParser:
    """ArgumentParser preloaded with the flags every tool shares."""
    ap = argparse.ArgumentParser(prog=prog, description=description)
    ap.add_argument("--io-report", action="store_true", dest="io_report",
                    help="print this run's own Darshan counters (reads/"
                         "writes/meta) to stderr on exit")
    if parallel_flag:
        ap.add_argument("--parallel", type=int, default=0, metavar="N",
                        help="fan chunk reads out over N ReaderPool workers "
                             "(0 = serial)")
    return ap


def check_series(path) -> Optional[str]:
    """None when `path` looks like a JBP series, else the complaint."""
    p = pathlib.Path(str(path))
    if not p.is_dir():
        return f"{p}: not a directory"
    if not (p / "md.idx").exists():
        return f"{p}: not a JBP series (no md.idx)"
    return None


def open_reader(path, *, parallel: int = 0, prog: str = "tool"):
    """BpReader on a validated series path, or None (after printing the
    complaint to stderr) — callers translate None to EXIT_USAGE."""
    err = check_series(path)
    if err is not None:
        print(f"{prog}: {err}", file=sys.stderr)
        return None
    return BpReader(path, parallel=parallel)


def io_report(prog: str):
    """The tool's own merged I/O counters, darshan-parser style, stderr."""
    rep = MONITOR.report()
    tot = rep["total"]
    print(f"# {prog} --io-report (merged, whole read/write plane)",
          file=sys.stderr)
    for k in (CTR.POSIX_OPENS, CTR.POSIX_READS, CTR.POSIX_BYTES_READ,
              CTR.POSIX_WRITES, CTR.POSIX_BYTES_WRITTEN, CTR.POSIX_SEEKS,
              CTR.POSIX_FLUSHES, CTR.POSIX_FSYNCS, CTR.POSIX_CLOSES):
        print(f"{prog}: {k} = {tot.get(k, 0.0):.0f}", file=sys.stderr)
    for k in (CTR.F_READ_TIME, CTR.F_WRITE_TIME, CTR.F_META_TIME):
        print(f"{prog}: {k} = {tot.get(k, 0.0):.6f}s", file=sys.stderr)
    # plane-specific counters (transport, served reads) print only when the
    # run exercised them — jbpls/jbpfsck output stays byte-stable
    for k in (CTR.TRANSPORT_SHM_BYTES, CTR.TRANSPORT_PICKLE_FALLBACK_BYTES,
              CTR.SERVICE_CACHE_HIT, CTR.SERVICE_CACHE_MISS,
              CTR.SERVICE_COALESCED, CTR.SERVICE_SHM_BYTES,
              CTR.SERVICE_SOCKET_BYTES):
        if tot.get(k, 0.0):
            print(f"{prog}: {k} = {tot[k]:.0f}", file=sys.stderr)
    # metrics plane (repro.core.metrics): per-op latency percentiles and
    # the straggler report — printed only when histograms were recorded,
    # so tool output with JBP_METRICS unset stays byte-stable
    cells = METRICS.merged() if METRICS.enabled else {}
    if cells:
        for ck in sorted(cells):
            s = summarize_cell(cells[ck])
            if not s["count"]:
                continue
            print(f"{prog}: metric {ck} n={s['count']} "
                  f"p50={s['p50_s'] * 1e3:.3f}ms "
                  f"p99={s['p99_s'] * 1e3:.3f}ms "
                  f"max={s['max_s'] * 1e3:.3f}ms", file=sys.stderr)
        for e in straggler_report(cells):
            print(f"{prog}: STRAGGLER {e['op']}/{e['key']} "
                  f"p99={e['p99_s'] * 1e3:.3f}ms = "
                  f"{e['ratio']:.1f}x peer median", file=sys.stderr)


def run_tool(main_fn, argv=None) -> int:
    """Uniform entry point: returns main_fn's exit code, mapping argparse
    SystemExit(2) through unchanged (usage errors share EXIT_USAGE)."""
    try:
        return int(main_fn(argv))
    except SystemExit as e:                      # argparse error paths
        return int(e.code or 0)
