"""jbpfsck — O(metadata) integrity scan & repair for a JBP (BP4) series.

fsck for the log-structured layout: everything the tool decides is decided
from `md.idx`, `md.0`, the `md.<w>.shard` logs and FILE SIZES (stat) —
payload bytes are never read. Checks, in dependency order:

  * structural: md.idx record granularity (a trailing partial record is a
    torn index tail — the classic crash signature),
  * per step: md.0 blob bounds + crc + JSON validity (torn/corrupt steps),
    duplicate step ids,
  * chunk extents: every committed chunk's [file_offset, +nbytes) must lie
    within its subfile's on-disk size (plain stat; striped layouts via the
    stat-only `striping.logical_size_of`) — a truncated subfile makes the
    step inconsistent even though its metadata seals validate,
  * shards: each md.<w>.shard replays to its sealed prefix
    (`iter_shard_records`); torn tail bytes are reported, and sealed
    records for steps that never committed are flagged as orphaned
    prepares (normal after a coordinator crash — dead weight, not damage),
  * orphaned payload/metadata bytes: subfile or md.0 bytes beyond the last
    committed reference (the two-phase-commit residue).

`--repair` truncates/reseals to the LAST CONSISTENT STEP: md.idx and md.0
are cut back to the longest prefix of steps that validate AND whose chunk
extents fit, and torn shard tails are cut back to their sealed prefix.
`--trim` additionally drops orphaned payload bytes from plain subfiles.
Repair never touches payload bytes of committed steps.

`--deep` additionally walks every committed chunk's JBPC block headers
(`compression.iter_block_headers`): magic, codec id (incl. the lossy id
and its sub-header), flags (the pre-shuffled bit), and the length chain
must tile the chunk's payload exactly, and the summed raw sizes must
equal the chunk extent's dtype x shape byte count — all WITHOUT
decompressing a single block. This is the only mode that reads payload
bytes (headers of each block, via ranged reads through BpReader, so
striped subfiles work too).

    PYTHONPATH=src python -m repro.tools.jbpfsck SERIES [--repair] [--trim]
        [--deep] [--json] [--io-report]

Exit codes: 0 clean (or fully repaired), 1 issues found (or remain),
2 not a JBP series.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import sys
import zlib
from typing import Optional

from repro.core.bp_engine import IDX_RECORD, IDX_SIZE
from repro.core.darshan import open_file
from repro.core.parallel_engine import SHARD_HDR
from repro.core.striping import OstPool, StripeConfig, logical_size_of
from repro.tools import _runner as R


def _subfile_size(path: pathlib.Path, agg: int) -> Optional[int]:
    """On-disk byte length of data.<agg> — plain stat, or the stat-only
    striped-layout recovery. None when the subfile does not exist at all."""
    plain = path / f"data.{agg}"
    if plain.exists():
        return plain.stat().st_size
    side = path / f"data.{agg}.stripe.json"
    osts = sorted(path.glob("ost*"))
    if not osts:
        return None
    if side.exists():
        with open_file(side, "r") as f:
            cfgd = json.loads(f.read())
        cfg = StripeConfig(cfgd["stripe_count"], cfgd["stripe_size"])
    else:
        objs = sorted(path.glob(f"ost*/data.{agg}.obj"))
        if not objs:
            return None
        cfg = StripeConfig(len(objs), 1 * 1024 * 1024)
    return logical_size_of(OstPool(path, len(osts)), f"data.{agg}", cfg)


def _sealed_shard_prefix(path: pathlib.Path, w: int) -> tuple[list, int]:
    """(sealed (step, record) list, sealed prefix BYTE length) of shard w —
    the same replay `iter_shard_records` does, but tracking the exact byte
    offset the sealed prefix ends at (what a tail truncation needs)."""
    with open_file(path / f"md.{w}.shard", "rb") as f:
        raw = f.read()
    sealed, off = [], 0
    while off + SHARD_HDR.size <= len(raw):
        step, ln, crc = SHARD_HDR.unpack_from(raw, off)
        blob = raw[off + SHARD_HDR.size:off + SHARD_HDR.size + ln]
        if len(blob) != ln or (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
            break
        sealed.append((step, json.loads(blob)))
        off += SHARD_HDR.size + ln
    return sealed, off


def scan(path) -> dict:
    """One O(metadata) pass -> the full fsck report (JSON-serializable)."""
    path = pathlib.Path(str(path))
    issues: list[dict] = []
    notes: list[dict] = []
    with open_file(path / "md.idx", "rb") as f:       # instrumented reads:
        idx_raw = f.read()                            # --io-report sees them
    if (path / "md.0").exists():
        with open_file(path / "md.0", "rb") as f:
            md_raw = f.read()
    else:
        md_raw = b""

    tail = len(idx_raw) % IDX_SIZE
    if tail:
        issues.append({"kind": "torn-idx-tail", "bytes": tail,
                       "detail": f"md.idx ends in {tail} bytes of a partial "
                                 f"record (crash during seal)"})

    # ---- per-record validation + the consistent prefix -------------------
    records = []            # (step, off, ln, ok, why, parsed)
    seen: set[int] = set()
    for i in range(0, len(idx_raw) - IDX_SIZE + 1, IDX_SIZE):
        step, off, ln, crc, flags, t_ns, _, _ = IDX_RECORD.unpack_from(
            idx_raw, i)
        blob = md_raw[off:off + ln]
        ok, why, parsed = True, None, None
        if len(blob) != ln or (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
            ok, why = False, "torn/corrupt md.0 blob (crc mismatch)"
        else:
            try:
                parsed = json.loads(blob)
            except ValueError:
                ok, why = False, "md.0 blob is not valid JSON"
        if ok and step in seen:
            ok, why = False, "duplicate step id in md.idx"
        if ok:
            seen.add(step)
        else:
            issues.append({"kind": "torn-step", "step": step, "detail": why})
        records.append((step, off, ln, ok, why, parsed))

    # ---- chunk extents vs subfile sizes ----------------------------------
    sizes: dict[int, Optional[int]] = {}
    max_end: dict[int, int] = {}
    for ri, (step, off, ln, ok, why, parsed) in enumerate(records):
        if not ok:
            continue
        bad = None
        for name, var in parsed.get("vars", {}).items():
            for ch in var["chunks"]:
                agg = ch["agg"]
                if agg not in sizes:
                    sizes[agg] = _subfile_size(path, agg)
                end = ch["foff"] + ch["nbytes"]
                sz = sizes[agg]
                if sz is None or end > sz:
                    bad = (f"chunk of {name!r} needs data.{agg}"
                           f"[..{end}] but subfile "
                           f"{'is missing' if sz is None else f'ends at {sz}'}")
                    break
                max_end[agg] = max(max_end.get(agg, 0), end)
            if bad:
                break
        if bad:
            issues.append({"kind": "orphaned-extent", "step": step,
                           "detail": bad})
            records[ri] = (step, off, ln, False, bad, parsed)

    # longest consistent PREFIX (repair truncates here)
    prefix = 0
    for step, off, ln, ok, why, parsed in records:
        if not ok:
            break
        prefix += 1
    committed = [r[0] for r in records if r[3]]

    # ---- orphaned bytes (dead weight, not damage) ------------------------
    md_end = max((off + ln for step, off, ln, ok, *_ in records if ok),
                 default=0)
    if len(md_raw) > md_end:
        notes.append({"kind": "orphan-md-bytes",
                      "bytes": len(md_raw) - md_end,
                      "detail": "md.0 bytes beyond the last committed "
                                "record (uncommitted/torn steps)"})
    for agg, sz in sorted(sizes.items()):
        if sz is not None and sz > max_end.get(agg, 0):
            notes.append({"kind": "orphan-payload", "agg": agg,
                          "bytes": sz - max_end.get(agg, 0),
                          "detail": f"data.{agg} holds "
                                    f"{sz - max_end.get(agg, 0)} bytes no "
                                    f"committed chunk references"})

    # ---- shards ----------------------------------------------------------
    shards = []
    for p in sorted(path.glob("md.*.shard")):
        m = re.fullmatch(r"md\.(\d+)\.shard", p.name)
        if not m:
            continue
        w = int(m.group(1))
        sealed, sealed_len = _sealed_shard_prefix(path, w)
        size = p.stat().st_size
        if size > sealed_len:
            issues.append({"kind": "torn-shard-tail", "shard": w,
                           "bytes": size - sealed_len,
                           "detail": f"md.{w}.shard has "
                                     f"{size - sealed_len} bytes past its "
                                     f"sealed prefix (writer crash during "
                                     f"prepare)"})
        orphans = [s for s, _ in sealed if s not in seen]
        if orphans:
            notes.append({"kind": "orphaned-prepare", "shard": w,
                          "steps": orphans,
                          "detail": f"md.{w}.shard sealed step(s) "
                                    f"{orphans} that never committed "
                                    f"(prepare succeeded, commit did not)"})
        shards.append({"shard": w, "sealed_steps": [s for s, _ in sealed],
                       "sealed_bytes": sealed_len, "file_bytes": size})

    return {"path": str(path), "committed_steps": committed,
            "consistent_prefix_steps": [r[0] for r in records[:prefix]],
            "issues": issues, "notes": notes, "shards": shards,
            "_records": records, "_sizes": sizes, "_max_end": max_end}


def deep_scan(path, report: dict) -> list[dict]:
    """`--deep`: walk every committed chunk's JBPC block headers without
    decompressing. Validates per block: magic, codec id (incl. lossy and
    its sub-header length), known flag bits (the pre-shuffled bit), and
    the length chain tiling the chunk payload exactly; per chunk: the
    summed raw sizes must equal extent x dtype.itemsize. Ranged payload
    reads go through BpReader, so striped subfiles work unchanged."""
    import numpy as np

    from repro.core import compression as C
    from repro.core.bp_engine import BpReader
    issues: list[dict] = []
    known_flags = C.FLAG_PRESHUFFLED
    with BpReader(path) as reader:
        for step, _off, _ln, ok, _why, parsed in report["_records"]:
            if not ok:
                continue
            for name, var in parsed.get("vars", {}).items():
                itemsize = np.dtype(var["dtype"]).itemsize
                for ch in var["chunks"]:
                    where = (f"step {step} var {name!r} "
                             f"data.{ch['agg']}[{ch['foff']}..]")
                    try:
                        payload = reader._read_payload(
                            ch["agg"], ch["foff"], ch["nbytes"])
                        blocks = list(C.iter_block_headers(payload))
                    except C.CorruptPayloadError as e:
                        issues.append({"kind": "corrupt-chunk", "step": step,
                                       "var": name, "agg": ch["agg"],
                                       "detail": f"{where}: {e}"})
                        continue
                    bad = None
                    raw_sum = 0
                    for boff, _cid, _isz, flags, raw, _comp in blocks:
                        raw_sum += raw
                        if flags & ~known_flags:
                            bad = (f"{where}: block at {boff} carries "
                                   f"unknown flag bits 0x{flags:02x}")
                            break
                    n_el = 1
                    for s in ch["extent"]:
                        n_el *= int(s)
                    if bad is None and raw_sum != n_el * itemsize:
                        bad = (f"{where}: blocks decode to {raw_sum} bytes, "
                               f"extent {tuple(ch['extent'])} x "
                               f"{var['dtype']} needs {n_el * itemsize}")
                    if bad:
                        issues.append({"kind": "corrupt-chunk", "step": step,
                                       "var": name, "agg": ch["agg"],
                                       "detail": bad})
    return issues


def repair(path, report: dict, *, trim: bool = False) -> list[str]:
    """Truncate/reseal to the last consistent step. Returns action log."""
    path = pathlib.Path(str(path))
    actions: list[str] = []
    records = report["_records"]
    prefix = len(report["consistent_prefix_steps"])
    if prefix < len(records) \
            or any(i["kind"] == "torn-idx-tail" for i in report["issues"]):
        idx_len = prefix * IDX_SIZE
        md_len = max((off + ln for step, off, ln, ok, *_ in
                      records[:prefix]), default=0)
        os.truncate(path / "md.idx", idx_len)
        if (path / "md.0").exists():    # scan tolerates a lost md.0 too
            os.truncate(path / "md.0", md_len)
        actions.append(f"resealed md.idx/md.0 to the first {prefix} "
                       f"consistent step(s) ({idx_len}/{md_len} bytes)")
    for sh in report["shards"]:
        if sh["file_bytes"] > sh["sealed_bytes"]:
            os.truncate(path / f"md.{sh['shard']}.shard", sh["sealed_bytes"])
            actions.append(f"truncated md.{sh['shard']}.shard torn tail "
                           f"({sh['file_bytes'] - sh['sealed_bytes']} bytes)")
    if trim:
        # recompute referenced ends over the KEPT records only
        keep_end: dict[int, int] = {}
        for step, off, ln, ok, why, parsed in records[:prefix]:
            for var in parsed.get("vars", {}).values():
                for ch in var["chunks"]:
                    keep_end[ch["agg"]] = max(keep_end.get(ch["agg"], 0),
                                              ch["foff"] + ch["nbytes"])
        for agg, sz in sorted(report["_sizes"].items()):
            plain = path / f"data.{agg}"
            end = keep_end.get(agg, 0)
            if not plain.exists():
                if sz is not None and sz > end:
                    actions.append(f"skipped trim of striped data.{agg} "
                                   f"(trim supports plain subfiles only)")
                continue
            if plain.stat().st_size > end:
                os.truncate(plain, end)
                actions.append(f"trimmed data.{agg} orphan payload to "
                               f"{end} bytes")
    return actions


def _public(report: dict) -> dict:
    return {k: v for k, v in report.items() if not k.startswith("_")}


def main(argv=None) -> int:
    ap = R.make_parser(
        "jbpfsck", "O(metadata) integrity scan & repair of a JBP (BP4) "
        "series — torn steps, orphaned extents, shard damage")
    ap.add_argument("series", help="path to the <name>.bp4 directory")
    ap.add_argument("--repair", action="store_true",
                    help="truncate/reseal to the last consistent step")
    ap.add_argument("--trim", action="store_true",
                    help="with --repair: drop orphaned payload bytes from "
                         "plain subfiles")
    ap.add_argument("--deep", action="store_true",
                    help="also walk every committed chunk's JBPC block "
                         "headers (no decompression)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report")
    args = ap.parse_args(argv)

    err = R.check_series(args.series)
    if err is not None:
        print(f"jbpfsck: {err}", file=sys.stderr)
        return R.EXIT_USAGE

    report = scan(args.series)
    repaired: list[str] = []
    if args.repair and report["issues"]:
        repaired = repair(args.series, report, trim=args.trim)
        report = scan(args.series)               # verify the repair took
    elif args.repair and args.trim:
        repaired = repair(args.series, report, trim=True)
        report = scan(args.series)
    if args.deep:
        # after any repair: deep-walk only what is (now) committed. Deep
        # findings are payload damage repair cannot fix — report only.
        report["issues"].extend(deep_scan(args.series, report))

    out = _public(report)
    out["repaired"] = repaired
    if args.as_json:
        print(json.dumps(out, indent=1))
    else:
        print(f"jbpfsck: {report['path']}")
        print(f"  committed steps: {len(report['committed_steps'])} "
              f"{report['committed_steps']}")
        for i in report["issues"]:
            print(f"  ISSUE [{i['kind']}] {i['detail']}")
        for n in report["notes"]:
            print(f"  note  [{n['kind']}] {n['detail']}")
        for a in repaired:
            print(f"  repair: {a}")
        if not report["issues"]:
            print("  clean")
    if args.io_report:
        R.io_report("jbpfsck")
    return R.EXIT_OK if not report["issues"] else R.EXIT_ISSUES


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
