"""jbpstat — analyze a series' metrics journal (metrics.jsonl).

The journal is written by the engines when the metrics plane is on
(`JBP_METRICS=1` or `METRICS.enable()`): one JSON frame per committed
step with the step's profiling numbers, Darshan counter deltas, the
coordinator's per-step histogram cells and every worker's shipped shard
(see `repro.core.metrics.StepJournal`). `jbpstat` reads it back:

    PYTHONPATH=src python -m repro.tools.jbpstat SERIES[/metrics.jsonl]
        [--json] [--stragglers] [--per-worker]
    PYTHONPATH=src python -m repro.tools.jbpstat --diff A B

Default report: the per-step throughput table (step, wall stamp, write
seconds, raw/stored MiB, MiB/s), then the cumulative per-op latency
percentiles (p50/p95/p99/max — DETERMINISTIC functions of the log2
buckets, so they are identical to what the live `jbpd` `metrics` op
reports for the same run), then the straggler report over the whole run.

`--diff A B` compares two journals (two runs of the same workload): per-
op p50/p95/p99 percentage deltas and the throughput delta — the
regression-bisection view.

Exit codes follow the `_runner` convention: 0 ok, 1 regressions found
with --diff (any op slower by >2x), 2 usage / no journal.
"""
from __future__ import annotations

import json
import sys

from repro.core.metrics import (load_journal, merge_cells, straggler_report,
                                sum_journal_hists, summarize_cell)
from repro.tools import _runner as R

MiB = 1024.0 ** 2

#: --diff regression threshold: an op whose p99 grew past this ratio
#: flips the exit code to EXIT_ISSUES
DIFF_REGRESSION_RATIO = 2.0


def _fmt_ms(v) -> str:
    return "-" if v is None else f"{v * 1e3:.3f}"


def _pct(new, old) -> str:
    if old is None or new is None or old == 0:
        return "-"
    return f"{(new - old) / old * 100.0:+.1f}%"


def step_table(frames: list) -> list[dict]:
    """One row per committed step (the close-time residual frame, step -1,
    is excluded — it has no throughput)."""
    rows = []
    for fr in frames:
        if fr.get("step", -1) < 0:
            continue
        prof = fr.get("prof", {})
        w_s = prof.get("write_s", 0.0)
        raw = prof.get("bytes_raw", 0)
        rows.append({"step": fr["step"], "t": fr.get("t"),
                     "write_s": w_s, "bytes_raw": raw,
                     "bytes_stored": prof.get("bytes_stored", 0),
                     "mib_s": (raw / MiB / w_s) if w_s else 0.0})
    return rows


def summarize_journal(frames: list, *, per_worker: bool = False) -> dict:
    """The whole-run analysis document (what --json prints)."""
    cum = sum_journal_hists(frames)                # own + worker cells
    doc = {
        "frames": len(frames),
        "steps": step_table(frames),
        "ops": {ck: summarize_cell(c) for ck, c in sorted(cum.items())},
        "stragglers": straggler_report(cum),
        "counters": _sum_counters(frames),
    }
    if per_worker:
        per_w: dict[str, dict] = {}
        for fr in frames:
            for wid, cells in fr.get("workers", {}).items():
                merge_cells(per_w.setdefault(str(wid), {}), cells)
        doc["workers"] = {wid: {ck: summarize_cell(c)
                                for ck, c in sorted(cells.items())}
                          for wid, cells in sorted(per_w.items())}
    return doc


def _sum_counters(frames: list) -> dict:
    out: dict[str, float] = {}
    for fr in frames:
        for k, v in fr.get("counters", {}).items():
            out[k] = out.get(k, 0.0) + v
    return out


def print_report(doc: dict, *, stragglers_only: bool = False):
    if not stragglers_only:
        print("step        t(wall)     write_s    raw MiB  stored MiB"
              "     MiB/s")
        for row in doc["steps"]:
            print(f"{row['step']:>4}  {row['t']:>14.3f}  "
                  f"{row['write_s']:>9.4f}  {row['bytes_raw'] / MiB:>9.2f}"
                  f"  {row['bytes_stored'] / MiB:>10.2f}"
                  f"  {row['mib_s']:>8.1f}")
        print()
        print(f"{'op|key':<44} {'n':>7} {'p50 ms':>9} {'p95 ms':>9} "
              f"{'p99 ms':>9} {'max ms':>9}")
        for ck, s in doc["ops"].items():
            if not s["count"]:
                continue
            print(f"{ck:<44} {s['count']:>7} {_fmt_ms(s['p50_s']):>9} "
                  f"{_fmt_ms(s['p95_s']):>9} {_fmt_ms(s['p99_s']):>9} "
                  f"{_fmt_ms(s['max_s']):>9}")
        for wid, ops in doc.get("workers", {}).items():
            print(f"\nworker {wid}:")
            for ck, s in ops.items():
                if s["count"]:
                    print(f"  {ck:<42} {s['count']:>7} "
                          f"{_fmt_ms(s['p50_s']):>9} "
                          f"{_fmt_ms(s['p95_s']):>9} "
                          f"{_fmt_ms(s['p99_s']):>9} "
                          f"{_fmt_ms(s['max_s']):>9}")
        print()
    if doc["stragglers"]:
        print("stragglers (p99 vs peer-median p99):")
        for e in doc["stragglers"]:
            base = ("rolling baseline" if e.get("vs_baseline")
                    else "peer median")
            print(f"  {e['op']}/{e['key']}: p99 {_fmt_ms(e['p99_s'])}ms = "
                  f"{e['ratio']:.1f}x {base} (n={e['count']})")
    elif stragglers_only:
        print("no stragglers detected")


def diff_journals(a_frames: list, b_frames: list) -> tuple[dict, bool]:
    """Per-op percentile deltas B vs A; returns (doc, regressed)."""
    a = {ck: summarize_cell(c)
         for ck, c in sum_journal_hists(a_frames).items()}
    b = {ck: summarize_cell(c)
         for ck, c in sum_journal_hists(b_frames).items()}
    rows = []
    regressed = False
    for ck in sorted(set(a) | set(b)):
        sa, sb = a.get(ck), b.get(ck)
        row = {"op": ck,
               "a": sa, "b": sb,
               "p50_pct": _pct(sb and sb["p50_s"], sa and sa["p50_s"]),
               "p99_pct": _pct(sb and sb["p99_s"], sa and sa["p99_s"])}
        if (sa and sb and sa["p99_s"] and sb["p99_s"]
                and sb["p99_s"] / sa["p99_s"] >= DIFF_REGRESSION_RATIO):
            row["regression"] = True
            regressed = True
        rows.append(row)
    ta = step_table(a_frames)
    tb = step_table(b_frames)

    def thr(rows_):
        t = sum(r["write_s"] for r in rows_)
        raw = sum(r["bytes_raw"] for r in rows_)
        return (raw / MiB / t) if t else 0.0

    return {"ops": rows, "throughput_a_mib_s": thr(ta),
            "throughput_b_mib_s": thr(tb)}, regressed


def _load(path, prog: str):
    try:
        return load_journal(path)
    except FileNotFoundError:
        print(f"{prog}: {path}: no metrics journal (run with JBP_METRICS=1 "
              f"to record one)", file=sys.stderr)
        return None
    except ValueError as e:
        print(f"{prog}: {e}", file=sys.stderr)
        return None


def main(argv=None) -> int:
    ap = R.make_parser(
        "jbpstat", "analyze a series' metrics journal (metrics.jsonl): "
        "per-step throughput, per-op latency percentiles, straggler "
        "report, run-vs-run regression diff")
    ap.add_argument("journal", nargs="*",
                    help="series directory or metrics.jsonl path")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print the full analysis as JSON")
    ap.add_argument("--stragglers", action="store_true",
                    help="print only the straggler report")
    ap.add_argument("--per-worker", action="store_true", dest="per_worker",
                    help="also summarize each worker's shipped histograms")
    ap.add_argument("--diff", nargs=2, metavar=("A", "B"), default=None,
                    help="compare two journals (exit 1 when any op's p99 "
                         f"regressed >= {DIFF_REGRESSION_RATIO}x)")
    args = ap.parse_args(argv)

    if args.diff is not None:
        a = _load(args.diff[0], "jbpstat")
        b = _load(args.diff[1], "jbpstat")
        if a is None or b is None:
            return R.EXIT_USAGE
        doc, regressed = diff_journals(a, b)
        if args.as_json:
            print(json.dumps(doc, indent=1))
        else:
            print(f"throughput: A {doc['throughput_a_mib_s']:.1f} MiB/s"
                  f" -> B {doc['throughput_b_mib_s']:.1f} MiB/s")
            print(f"{'op|key':<44} {'A p99 ms':>10} {'B p99 ms':>10} "
                  f"{'d p50':>8} {'d p99':>8}")
            for row in doc["ops"]:
                sa, sb = row["a"], row["b"]
                mark = "  << REGRESSION" if row.get("regression") else ""
                print(f"{row['op']:<44} "
                      f"{_fmt_ms(sa and sa['p99_s']):>10} "
                      f"{_fmt_ms(sb and sb['p99_s']):>10} "
                      f"{row['p50_pct']:>8} {row['p99_pct']:>8}{mark}")
        return R.EXIT_ISSUES if regressed else R.EXIT_OK

    if len(args.journal) != 1:
        print("jbpstat: exactly one journal (or --diff A B) required",
              file=sys.stderr)
        return R.EXIT_USAGE
    frames = _load(args.journal[0], "jbpstat")
    if frames is None:
        return R.EXIT_USAGE
    doc = summarize_journal(frames, per_worker=args.per_worker)
    if args.as_json:
        print(json.dumps(doc, indent=1))
    else:
        print_report(doc, stragglers_only=args.stragglers)
    if args.io_report:
        R.io_report("jbpstat")
    return R.EXIT_OK


if __name__ == "__main__":
    raise SystemExit(R.run_tool(main))
