"""Batched serving engine: continuous prefill + decode over a fixed-capacity
KV/SSM cache, with request queueing — the serving-side driver for the
decode dry-run shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serve.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    max_new_tokens: int = 32


class ServeEngine:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.prefill = jax.jit(make_prefill_step(
            cfg, q_chunk=min(256, scfg.max_seq),
            kv_chunk=min(256, scfg.max_seq)))
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, *, new_tokens: Optional[int] = None,
                 vision_embeds=None) -> np.ndarray:
        """prompts: [B, S_prompt] int32 (right-aligned, same length).
        Greedy decode `new_tokens` continuations for the whole batch."""
        B, Sp = prompts.shape
        n_new = new_tokens or self.scfg.max_new_tokens
        if Sp + n_new > self.scfg.max_seq:
            raise ValueError(
                f"prompt length {Sp} + new tokens {n_new} exceeds the "
                f"serve cache budget max_seq={self.scfg.max_seq} — "
                f"shorten the prompt or raise ServeConfig.max_seq")

        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.asarray(vision_embeds)
        logits, cache = self.prefill(self.params, batch)
        # grow the prefill cache to max_seq capacity
        cache = self._grow_cache(cache, Sp)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for i in range(n_new - 1):
            tok, cache = self.decode(self.params, cache, tok,
                                     jnp.asarray(Sp + i, jnp.int32))
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)

    def _grow_cache(self, cache, cur_len: int):
        """Pad seq-capacity dims (attention caches) out to max_seq."""
        full = M.make_decode_cache_spec(self.cfg, cache_batch(cache),
                                        self.scfg.max_seq)

        def grow(src, spec):
            if src.shape == spec.shape:
                return src.astype(spec.dtype)
            pads = [(0, t - s) for s, t in zip(src.shape, spec.shape)]
            return jnp.pad(src.astype(spec.dtype), pads)

        return jax.tree_util.tree_map(grow, cache, full)


def cache_batch(cache) -> int:
    leaves = jax.tree_util.tree_leaves(cache)
    # all cache leaves carry batch right after the stack dims; infer from the
    # ssm/conv/k layout used in transformer.cache_spec
    shapes = [l.shape for l in leaves]
    # k/v: [L,B,S,H,D] (rank5) or [U,I,B,...]; ssm [L,B,H,P,N]
    for s in shapes:
        if len(s) == 5:
            return s[1]
    return shapes[0][2] if len(shapes[0]) >= 3 else shapes[0][0]
