"""Serving steps: prefill (full-sequence, builds the cache) and decode
(one token against the cache). These are the functions the decode/long
dry-run shapes lower, and what serve/engine.py drives."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M


def make_prefill_step(cfg, *, q_chunk: int = 1024, kv_chunk: int = 1024,
                      ssd_chunk: int = 128):
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, cfg, batch, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk, ssd_chunk=ssd_chunk)
        # return only the final position's logits — next-token distribution
        return logits[:, -1:], cache
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, cache, token, cache_len, embeds=None):
        logits, cache = M.decode_step(params, cfg, token, cache, cache_len,
                                      embeds=embeds)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache
    return decode_step
