"""jbpd — the JBP series data service (the served read plane).

Until now every consumer of a series was its own process: it opened the
series, parsed the metadata, read + decompressed every payload byte it
wanted — and the next consumer did it all again (the exact cost the
Darshan instrumentation follow-up attributes to analysis pipelines that
re-open their inputs per tool). `jbpd` is the long-lived gateway in front
of `BpReader` that the ROADMAP's "millions of users" plane calls for:

    client                gateway (JbpDaemon)        server (SeriesServer)
    ------                -------------------        ---------------------
    SeriesClient  --sock-->  accept / framing  --->  one BpReader per series
      variables()            per-conn thread         one shared ReaderPool
      layout()               per-conn ShmRing        one ChunkCache (LRU +
      var_minmax()           (zero-copy responses)    request coalescing)
      iter_chunks()
      read_var()     <--shm-- response slot / socket frame fallback

The split mirrors the hyadmin gateway/server/admin layering (SNIPPETS §2):
the GATEWAY owns connections, framing and per-connection pre-provisioned
response rings; the SERVER owns the readers, the pool and the cache; the
ADMIN surface (`stats`, `ping`, `watch`, `shutdown`) is how operators and
the CLI observe and drive a running daemon — `watch` streams periodic
counter DELTAS (SERVICE_*/TRANSPORT_*/POSIX_* + cache + DXT stats) over
the same framed protocol, the live feed the ROADMAP's autotuning
controller reads next.

What the daemon adds over N independent readers:

  * open-once: each series' md.idx/md.0 is scanned and parsed once for
    every client that will ever ask,
  * `ChunkCache` — an LRU of DECOMPRESSED chunks keyed by
    (series, step, var, agg, file_offset) under a byte budget: a re-read
    is a memcpy, not a payload read + decompress,
  * request coalescing — concurrent clients asking for overlapping boxes
    need the same chunks; followers of an in-flight fetch wait on the
    leader's result instead of issuing N identical read+decompress passes
    (`SERVICE_COALESCED` counts every avoided fetch),
  * zero-copy handoff — a local client's `read_var` response is written
    once into the connection's `ShmRing` slot and the client maps it
    (`ShmRing.attach`, the non-child topology); oversized/ring-full
    responses and remote (TCP) clients fall back to socket framing. The
    transport degrades, it never fails.

Protocol: length-prefixed frames — `<II` (json_len, body_len), a JSON
header, then an optional binary body. One request at a time per
connection; `release` (slot free) and `hello` are one-way/handshake ops.
Every data-plane error (unknown variable, unregistered series, a
`CorruptPayloadError` from a bit-rotted chunk) maps to a clean
`{"ok": false, "error": {kind, msg}}` response — the connection survives.

Counters (`repro.core.darshan.MONITOR`): SERVICE_CACHE_HIT/MISS,
SERVICE_COALESCED, SERVICE_SHM_BYTES, SERVICE_SOCKET_BYTES — the service
plane is observable exactly like the write plane, and `--io-report` on
the CLI prints them at exit.
"""
from __future__ import annotations

import json
import pathlib
import socket
import struct
import threading
import time
import weakref
from collections import OrderedDict
from typing import Any, Optional, Union

import numpy as np

from repro.core.bp_engine import BpReader
from repro.core.compression import CorruptPayloadError
from repro.core.darshan import CTR, MONITOR
from repro.core.dxt import TRACER
from repro.core.metrics import (METRICS, RollingBaseline, summarize_cell,
                                to_prometheus)
from repro.core.shm_transport import (DEFAULT_RING_BYTES, ShmHeader, ShmRing,
                                      unlink_rings)

DEFAULT_CACHE_BYTES = 256 * 1024 ** 2
FRAME = struct.Struct("<II")             # json header bytes, binary body bytes

# the counter families `stats` reports and `watch` streams deltas of — one
# list, so a watch's begin + Σ(deltas) always reconciles against --stats
WATCH_COUNTERS = (CTR.SERVICE_CACHE_HIT, CTR.SERVICE_CACHE_MISS,
                  CTR.SERVICE_COALESCED, CTR.SERVICE_SHM_BYTES,
                  CTR.SERVICE_SOCKET_BYTES, CTR.TRANSPORT_SHM_BYTES,
                  CTR.TRANSPORT_PICKLE_FALLBACK_BYTES,
                  CTR.POSIX_READS, CTR.POSIX_WRITES,
                  CTR.POSIX_BYTES_READ, CTR.POSIX_BYTES_WRITTEN)


# ---------------------------------------------------------------------- errors
class JbpdRequestError(RuntimeError):
    """The daemon answered `{"ok": false}`: the request failed but the
    connection (and the daemon) are fine. `kind` is the machine-readable
    class — "not-found", "not-served", "corrupt-payload", "bad-request"."""

    def __init__(self, kind: str, msg: str):
        self.kind = kind
        super().__init__(f"[{kind}] {msg}")


class DaemonDisconnectedError(ConnectionError):
    """The daemon went away mid-conversation (restarted, crashed, or was
    shut down). The client drops its socket and shm attachments; the NEXT
    call transparently reconnects — callers that can retry, should."""


def _error_kind(e: BaseException) -> str:
    if isinstance(e, CorruptPayloadError):
        return "corrupt-payload"
    if isinstance(e, (KeyError, FileNotFoundError)):
        return "not-found"
    if isinstance(e, PermissionError):
        return "not-served"
    if isinstance(e, (ValueError, TypeError)):
        return "bad-request"
    return type(e).__name__


# --------------------------------------------------------------------- framing
def _json_default(o):
    if isinstance(o, (np.integer, np.floating)):
        return o.item()
    if isinstance(o, (tuple, set)):
        return list(o)
    raise TypeError(f"not JSON-serializable: {type(o)}")


def send_msg(sock: socket.socket, hdr: dict, body: bytes = b""):
    blob = json.dumps(hdr, default=_json_default).encode()
    sock.sendall(FRAME.pack(len(blob), len(body)) + blob)
    if body:
        sock.sendall(body)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None                        # orderly EOF
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket) -> tuple[Optional[dict], bytes]:
    """(header, body); (None, b"") on EOF at a frame boundary. A torn frame
    (EOF mid-message) raises DaemonDisconnectedError — the peer died."""
    raw = _recv_exact(sock, FRAME.size)
    if raw is None:
        return None, b""
    hl, bl = FRAME.unpack(raw)
    blob = _recv_exact(sock, hl)
    if blob is None:
        raise DaemonDisconnectedError("peer closed mid-frame")
    body = _recv_exact(sock, bl) if bl else b""
    if bl and body is None:
        raise DaemonDisconnectedError("peer closed mid-frame")
    return json.loads(blob), body or b""


# ----------------------------------------------------------------- chunk cache
class _Fetch:
    """One in-flight chunk fetch: the leader resolves it, followers wait."""

    __slots__ = ("event", "result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None


class ChunkCache:
    """LRU of decompressed chunk arrays under a byte budget, with request
    coalescing. Plugs into `BpReader(chunk_cache=...)` — see
    `BpReader.read_chunk` for the key contract. Thread-safe; the fetch
    itself runs OUTSIDE the lock (reads and decompression overlap across
    distinct chunks; identical chunks coalesce onto one leader)."""

    def __init__(self, budget_bytes: int = DEFAULT_CACHE_BYTES,
                 monitor=MONITOR):
        self.budget = int(budget_bytes)
        self.mon = monitor
        self._lock = threading.Lock()
        self._lru: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._inflight: dict[tuple, _Fetch] = {}
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.coalesced = 0
        self.evictions = 0

    def get_or_fetch(self, key: tuple, fetch, nbytes: int) -> np.ndarray:
        series = str(key[0])
        while True:
            with self._lock:
                arr = self._lru.get(key)
                if arr is not None:
                    self._lru.move_to_end(key)
                    self.hits += 1
                    self.mon.record(0, series, CTR.SERVICE_CACHE_HIT)
                    return arr
                fl = self._inflight.get(key)
                if fl is None:
                    fl = self._inflight[key] = _Fetch()
                    leader = True
                else:
                    leader = False
                    self.coalesced += 1
                    self.mon.record(0, series, CTR.SERVICE_COALESCED)
            if not leader:
                fl.event.wait()
                if fl.error is not None:
                    raise fl.error
                return fl.result
            try:
                tf = time.perf_counter()
                with TRACER.span("cache_fetch", path=series) as sp:
                    arr = fetch()
                    sp.length = arr.nbytes
                if METRICS.enabled:
                    METRICS.observe("cache_fetch",
                                    time.perf_counter() - tf,
                                    nbytes=arr.nbytes, key=series)
                if arr.flags.writeable:        # cached objects are shared
                    arr = arr.copy()
                arr.flags.writeable = False
                fl.result = arr
            except BaseException as e:
                fl.error = e
                with self._lock:
                    self._inflight.pop(key, None)
                fl.event.set()
                raise
            with self._lock:
                self.misses += 1
                self.mon.record(0, series, CTR.SERVICE_CACHE_MISS)
                if arr.nbytes <= self.budget:  # oversized: serve, don't cache
                    self._lru[key] = arr
                    self.bytes += arr.nbytes
                    while self.bytes > self.budget:
                        _, old = self._lru.popitem(last=False)
                        self.bytes -= old.nbytes
                        self.evictions += 1
                self._inflight.pop(key, None)
            fl.event.set()
            return arr

    def invalidate(self, series: Optional[str] = None):
        """Drop cached chunks (of one series, or everything) — the admin
        hook for a series that was repacked/rewritten under the daemon."""
        with self._lock:
            if series is None:
                self._lru.clear()
                self.bytes = 0
                return
            s = str(series)
            for k in [k for k in self._lru if str(k[0]) == s]:
                self.bytes -= self._lru.pop(k).nbytes

    def stats(self) -> dict:
        with self._lock:
            return {"budget_bytes": self.budget, "bytes": self.bytes,
                    "entries": len(self._lru), "hits": self.hits,
                    "misses": self.misses, "coalesced": self.coalesced,
                    "evictions": self.evictions}


# ---------------------------------------------------------------------- server
class SeriesServer:
    """The query-execution half: one `BpReader` per served series (opened
    once, shared by every connection), one `ChunkCache`, one ReaderPool
    fan-out setting. Knows nothing about sockets — `JbpDaemon` (or a test)
    drives it directly via `query()`."""

    def __init__(self, series=(), *, cache_bytes: int = DEFAULT_CACHE_BYTES,
                 parallel: int = 0, open_any: bool = False):
        # uptime is a DURATION: measured on the monotonic clock (jbplint
        # JBP006 — wall clock is for epoch stamps only, it can step)
        self.t0 = time.perf_counter()
        self.baseline = RollingBaseline()      # straggler EWMA per (op, key)
        self.cache = ChunkCache(cache_bytes)
        self.parallel = int(parallel)
        self.registered = {str(pathlib.Path(str(s)).resolve())
                           for s in series}
        # no pre-registered series -> serve whatever valid series is asked
        self.open_any = bool(open_any) or not self.registered
        self._readers: dict[str, BpReader] = {}
        self._lock = threading.Lock()
        for s in sorted(self.registered):      # pre-open: fail at startup,
            self.reader(s)                     # not on the first request

    def reader(self, series) -> BpReader:
        if series is None:
            raise ValueError("request names no series")
        key = str(pathlib.Path(str(series)).resolve())
        with self._lock:
            r = self._readers.get(key)
            if r is not None:
                return r
        if not self.open_any and key not in self.registered:
            raise PermissionError(
                f"series {key} is not served by this daemon "
                f"(serving: {sorted(self.registered)})")
        if not (pathlib.Path(key) / "md.idx").exists():
            raise FileNotFoundError(f"{key}: not a JBP series (no md.idx)")
        r = BpReader(key, parallel=self.parallel, chunk_cache=self.cache)
        with self._lock:
            # two threads may have opened concurrently; keep the first
            r = self._readers.setdefault(key, r)
        return r

    # ------------------------------------------------------------- dispatch
    def query(self, req: dict) -> Union[dict, np.ndarray]:
        """Execute one request. Returns a JSON-able dict, or an ndarray
        (read_var) that the gateway ships shm/framed. Raises on bad
        requests — the gateway maps exceptions to error responses."""
        op = req.get("op")
        if op == "ping":
            return {"pong": True}
        if op == "stats":
            return self.stats()
        if op == "metrics":
            return self.metrics()
        r = self.reader(req.get("series"))
        if op == "steps":
            return {"steps": r.valid_steps()}
        if op == "variables":
            return {"variables": r.variables(req.get("steps"))}
        if op == "layout":
            return {"layout": r.layout(req.get("steps"))}
        if op == "attributes":
            return {"attrs": r.attributes(int(req["step"]))}
        if op == "var_minmax":
            return {"minmax": r.var_minmax(int(req["step"]), req["name"])}
        if op == "iter_chunks":
            return {"chunks": [c.to_json() for c in
                               r.iter_chunks(int(req["step"]), req["name"])]}
        if op == "read_var":
            off = req.get("offset")
            ext = req.get("extent")
            return r.read_var(int(req["step"]), req["name"],
                              tuple(off) if off is not None else None,
                              tuple(ext) if ext is not None else None)
        raise ValueError(f"unknown op {op!r}")

    def counters(self) -> dict:
        """Absolute values of the watched counter families — the ONE
        source both `stats` and the `watch` delta stream read, so they
        can never disagree."""
        tot = MONITOR.report()["total"]
        return {k: tot.get(k, 0.0) for k in WATCH_COUNTERS}

    def stats(self) -> dict:
        with self._lock:
            series = sorted(self._readers)
        return {"series": series, "cache": self.cache.stats(),
                "parallel": self.parallel,
                "uptime_s": time.perf_counter() - self.t0,
                "dxt": TRACER.stats(),
                "metrics": METRICS.stats(),
                "counters": self.counters()}

    # -------------------------------------------------------- metrics plane
    def stragglers(self) -> list[dict]:
        """Current straggler/anomaly report over the live histogram cells
        (peer-median p99 ratio + rolling EWMA baseline). Serialized: the
        baseline's history is shared mutable state."""
        cells = METRICS.merged()
        with self._lock:
            return self.baseline.update(cells)

    def metrics(self) -> dict:
        """The `metrics` admin op: every consumer view of the histogram
        plane in one response — raw cells (additive, journal-compatible),
        deterministic percentile summaries (identical to what `jbpstat`
        derives from a journal of the same run), the straggler report,
        and the Prometheus text exposition the HTTP shim serves."""
        cells = METRICS.merged()
        with self._lock:
            stragglers = self.baseline.update(cells)
        return {"enabled": METRICS.enabled,
                "counters": MONITOR.report()["total"],
                "hists": cells,
                "percentiles": {ck: summarize_cell(c)
                                for ck, c in cells.items()},
                "stragglers": stragglers,
                "text": self.metrics_text(cells)}

    def metrics_text(self, cells: Optional[dict] = None) -> str:
        """Prometheus text-format exposition (0.0.4) of counters, service
        gauges and the latency/size histogram families."""
        if cells is None:
            cells = METRICS.merged()
        cs = self.cache.stats()
        gauges = {"uptime_seconds": time.perf_counter() - self.t0,
                  "cache_bytes": cs["bytes"],
                  "cache_entries": cs["entries"],
                  "metrics_enabled": 1 if METRICS.enabled else 0}
        return to_prometheus(cells, counters=MONITOR.report()["total"],
                             gauges=gauges)

    def close(self):
        with self._lock:
            readers, self._readers = list(self._readers.values()), {}
        for r in readers:
            r.close()


# --------------------------------------------------------------------- gateway
class JbpDaemon:
    """The connection half: listening socket (AF_UNIX path or TCP port),
    one thread per client, per-connection response rings. `serve_forever`
    blocks (the CLI); `start()` runs it on a daemon thread (tests,
    benchmarks, embedding)."""

    def __init__(self, server: SeriesServer, *,
                 socket_path=None, host: str = "127.0.0.1",
                 port: Optional[int] = None, shm: bool = True,
                 ring_bytes: int = DEFAULT_RING_BYTES):
        if (socket_path is None) == (port is None):
            raise ValueError("exactly one of socket_path / port required")
        self.server = server
        self.shm_enabled = bool(shm) and socket_path is not None
        self.ring_bytes = int(ring_bytes)
        if socket_path is not None:
            self.socket_path = str(socket_path)
            pathlib.Path(self.socket_path).unlink(missing_ok=True)
            self._listener = socket.socket(socket.AF_UNIX)
            self._listener.bind(self.socket_path)
            self.address: Any = self.socket_path
        else:
            self.socket_path = None
            self._listener = socket.socket(socket.AF_INET)
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            self.address = self._listener.getsockname()
        self._listener.listen(64)
        self._stopping = threading.Event()
        self._lock = threading.Lock()
        self._conn_seq = 0                     # trace tid <-> connection
        self._conns: list[socket.socket] = []
        self._threads: list[threading.Thread] = []
        self._rings: list[ShmRing] = []
        self._accept_thread: Optional[threading.Thread] = None
        # abnormal exit must not leak /dev/shm — same discipline as the
        # write plane's ring owners
        self._finalizer = weakref.finalize(self, unlink_rings, self._rings)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "JbpDaemon":
        """Accept loop on a background thread; the listener is already
        bound+listening, so a client may connect the moment this returns."""
        t = threading.Thread(target=self.serve_forever, name="jbpd-accept",
                             daemon=True)
        self._accept_thread = t
        t.start()
        return self

    def serve_forever(self):
        while not self._stopping.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:                    # listener closed by stop()
                break
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="jbpd-conn", daemon=True)
            with self._lock:
                self._conns.append(conn)
                self._threads.append(t)
            t.start()

    def stop(self):
        """Close the listener and every live connection, join the workers,
        unlink the rings. Idempotent; callable from a connection thread
        (the `shutdown` op) or any other."""
        if self._stopping.is_set():
            return
        self._stopping.set()
        # shutdown() BEFORE close(): on Linux, closing an fd another thread
        # is blocked in accept() on does not wake it — shutdown() does
        try:
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass
        if self.socket_path:
            pathlib.Path(self.socket_path).unlink(missing_ok=True)
        with self._lock:
            conns, self._conns = self._conns, []
            threads, self._threads = self._threads, []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        me = threading.current_thread()
        for t in threads + ([self._accept_thread] if self._accept_thread
                            else []):
            if t is not me:
                t.join(timeout=2.0)
        unlink_rings(self._rings)
        self._rings.clear()
        self.server.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.stop()

    # ----------------------------------------------------------- connection
    def _serve_conn(self, conn: socket.socket):
        ring: Optional[ShmRing] = None
        use_shm = False
        with self._lock:
            self._conn_seq += 1
            cid = self._conn_seq               # rank/tid of this connection
        try:
            while True:
                try:
                    hdr, _ = recv_msg(conn)
                except (DaemonDisconnectedError, OSError):
                    break
                if hdr is None:
                    break
                op = hdr.get("op")
                if op == "hello":
                    use_shm = bool(hdr.get("shm")) and self.shm_enabled
                    if use_shm and ring is None:
                        # pre-provision the connection's response ring NOW
                        # (hyadmin-style per-concurrency provisioning): the
                        # first read_var pays no setup, and ring creation
                        # failures surface at handshake time
                        ring = ShmRing(self.ring_bytes)
                        self._rings.append(ring)
                    send_msg(conn, {"ok": True, "server": "jbpd",
                                    "shm": use_shm,
                                    "ring": ring.name if use_shm else None})
                    continue
                if op == "release":
                    if ring is not None:
                        ring.free(int(hdr["offset"]))
                    continue
                if op == "shutdown":
                    send_msg(conn, {"ok": True, "stopping": True})
                    threading.Thread(target=self.stop, daemon=True).start()
                    break
                if op == "watch":
                    try:
                        self._serve_watch(conn, hdr)
                    except OSError:
                        break                  # client went away mid-stream
                    continue
                try:
                    tq = time.perf_counter()
                    with TRACER.span("serve", path=str(op), rank=cid):
                        res = self.server.query(hdr)
                    if METRICS.enabled:
                        METRICS.observe("serve", time.perf_counter() - tq,
                                        key=str(op))
                except BaseException as e:     # noqa: BLE001 — conn survives
                    send_msg(conn, {"ok": False,
                                    "error": {"kind": _error_kind(e),
                                              "msg": str(e)}})
                    continue
                if isinstance(res, np.ndarray):
                    self._send_array(conn, ring if use_shm else None, res,
                                     str(hdr.get("series")))
                else:
                    send_msg(conn, {"ok": True, "result": res})
        finally:
            try:
                conn.close()
            except OSError:
                pass
            if ring is not None:
                ring.close()
                ring.unlink()
                with self._lock:
                    if ring in self._rings:
                        self._rings.remove(ring)

    def _serve_watch(self, conn: socket.socket, hdr: dict):
        """The live metrics stream: one "watch" request, many response
        frames on the same framed protocol. Frame sequence:

            {"ok": true, "watch": {"begin": <abs counters>, ...}}
            {"ok": true, "frame": {"seq", "t", "counters", "delta",
                                   "cache", "dxt", "stragglers"}}  x count
            {"ok": true, "done": true, "counters": <abs counters>}

        Invariant (the autotuning contract): begin + Σ(frame deltas) ==
        done counters == what `stats` reports at that moment — `counters`
        is the same `SeriesServer.counters()` everywhere."""
        interval = max(0.01, float(hdr.get("interval_s", 1.0)))
        count = max(1, min(int(hdr.get("count", 2)), 100000))
        prev = self.server.counters()
        send_msg(conn, {"ok": True, "watch": {"begin": prev,
                                              "interval_s": interval,
                                              "count": count}})
        for seq in range(count):
            if self._stopping.wait(interval):
                break                          # daemon stopping: end early
            cur = self.server.counters()
            send_msg(conn, {"ok": True, "frame": {
                "seq": seq, "t": time.time(), "counters": cur,
                "delta": {k: cur[k] - prev.get(k, 0.0) for k in cur},
                "cache": self.server.cache.stats(),
                "dxt": TRACER.stats(),
                "stragglers": self.server.stragglers()}})
            prev = cur
        send_msg(conn, {"ok": True, "done": True, "counters": prev})

    def _send_array(self, conn, ring: Optional[ShmRing], arr: np.ndarray,
                    series: str):
        """Zero-copy handoff when the connection has a ring with room;
        socket framing otherwise (remote client, oversized response, or a
        ring still full of unreleased slots)."""
        if ring is not None:
            shdr = ring.write_array(np.ascontiguousarray(arr))
            if shdr is not None:
                MONITOR.record(0, series, CTR.SERVICE_SHM_BYTES,
                               float(arr.nbytes))
                send_msg(conn, {"ok": True,
                                "shm": {"ring": ring.name,
                                        "offset": shdr.offset,
                                        "nbytes": shdr.nbytes,
                                        "dtype": shdr.dtype,
                                        "shape": list(shdr.shape)}})
                return
        MONITOR.record(0, series, CTR.SERVICE_SOCKET_BYTES, float(arr.nbytes))
        send_msg(conn, {"ok": True, "array": {"dtype": arr.dtype.str,
                                              "shape": list(arr.shape)}},
                 np.ascontiguousarray(arr).tobytes())


# ----------------------------------------------------------------- http shim
class MetricsHttpShim:
    """Minimal HTTP exposition endpoint for standard scrapers: GET `/` or
    `/metrics` returns `SeriesServer.metrics_text()` (Prometheus text
    format 0.0.4). Deliberately NOT a web framework — one handler, one
    content type, bound to loopback by default; the framed-socket
    `metrics` op remains the full-fidelity admin surface. `port=0` binds
    an ephemeral port (tests); `.port` is the bound port either way."""

    def __init__(self, server: SeriesServer, *, host: str = "127.0.0.1",
                 port: int = 0):
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
        srv = server

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):                  # noqa: N802 — stdlib API name
                if self.path.split("?", 1)[0] not in ("/", "/metrics"):
                    self.send_error(404, "only /metrics is served")
                    return
                body = srv.metrics_text().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):         # scrapes are periodic noise
                pass

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="jbpd-metrics-http", daemon=True)

    def start(self) -> "MetricsHttpShim":
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self.start()

    def __exit__(self, *a):
        self.stop()


# ---------------------------------------------------------------------- client
class SeriesClient:
    """One connection to a running jbpd. `address` is a unix-socket path
    (str/Path) or a (host, port) tuple. `series` fixes the series every
    query names (pass per-call to override).

    Local clients negotiate shm at hello: `read_var` responses arrive as a
    ring slot the client maps via `ShmRing.attach` — one copy out of
    shared pages instead of a socket stream. The client releases each slot
    right after copying (the FIFO free discipline needs nothing more,
    because the protocol is one request at a time per connection).

    If the daemon restarts, the NEXT call raises DaemonDisconnectedError
    (clear, not a bare EPIPE) and drops the dead socket + stale ring
    attachments; the call after that reconnects transparently."""

    def __init__(self, address, series=None, *, shm: Optional[bool] = None,
                 timeout: float = 30.0):
        self.address = (str(address) if isinstance(address, (str, pathlib.Path))
                        else tuple(address))
        self.series = str(series) if series is not None else None
        self.want_shm = (shm if shm is not None
                         else isinstance(self.address, str))
        self.timeout = timeout
        self._sock: Optional[socket.socket] = None
        self._shm_ok = False
        self._rings: dict[str, ShmRing] = {}
        self._lock = threading.Lock()          # one request at a time

    # ----------------------------------------------------------- transport
    def _dial(self, *, shm: bool) -> tuple[socket.socket, bool]:
        """Open ONE handshaken connection to the daemon and return
        (socket, shm_granted). Owns nothing on self — `_connect` installs
        the result as the client's request connection; `watch()` dials its
        own so a long stream never starves concurrent `_call`s."""
        try:
            if isinstance(self.address, str):
                s = socket.socket(socket.AF_UNIX)
                s.settimeout(self.timeout)
                s.connect(self.address)
            else:
                s = socket.create_connection(self.address,
                                             timeout=self.timeout)
        except OSError as e:
            raise DaemonDisconnectedError(
                f"cannot reach jbpd at {self.address!r}: {e} "
                f"(daemon not running, or restarted on another address)"
            ) from e
        try:
            send_msg(s, {"op": "hello", "shm": shm})
            hdr, _ = recv_msg(s)
            if hdr is None:
                raise DaemonDisconnectedError(
                    f"jbpd at {self.address!r} closed the connection during "
                    f"handshake")
        except BaseException as e:
            # close on EVERY failed handshake. `except OSError` alone used
            # to leak the freshly dialed socket when the daemon died in a
            # way that didn't surface as an OSError — e.g. a garbage frame
            # from a half-dead peer raising JSONDecodeError inside
            # recv_msg. One socket per watch() retry loop adds up to fd
            # exhaustion in a long-lived client.
            try:
                s.close()
            except OSError:
                pass
            if isinstance(e, DaemonDisconnectedError):
                raise
            if isinstance(e, OSError):
                raise DaemonDisconnectedError(
                    f"jbpd at {self.address!r} dropped the connection "
                    f"during handshake") from e
            raise
        return s, bool(hdr.get("shm"))

    def _connect(self):
        self._sock, self._shm_ok = self._dial(shm=self.want_shm)

    def _drop(self):
        """Forget the dead connection and every shm attachment made through
        it (a restarted daemon owns brand-new rings)."""
        s, self._sock = self._sock, None
        if s is not None:
            try:
                s.close()
            except OSError:
                pass
        rings, self._rings = self._rings, {}
        for r in rings.values():
            r.close()

    def _call(self, req: dict) -> tuple[dict, bytes]:
        with self._lock:
            if self._sock is None:
                self._connect()
            try:
                # blocking under _lock is this protocol's design: ONE
                # framed request in flight per connection, and the lock is
                # exactly that serialization (bounded by the socket
                # timeout). Streams (watch) dial their own connection.
                send_msg(self._sock, req)            # jbplint: disable=JBP004
                hdr, body = recv_msg(self._sock)     # jbplint: disable=JBP004
            except (OSError, DaemonDisconnectedError) as e:
                self._drop()
                raise DaemonDisconnectedError(
                    f"jbpd at {self.address!r} went away mid-request "
                    f"(restarted?) — the connection was dropped; the next "
                    f"call reconnects") from e
            if hdr is None:
                self._drop()
                raise DaemonDisconnectedError(
                    f"jbpd at {self.address!r} closed the connection "
                    f"(shut down or restarted); the next call reconnects")
            if not hdr.get("ok"):
                err = hdr.get("error", {})
                raise JbpdRequestError(err.get("kind", "error"),
                                       err.get("msg", "request failed"))
            if "shm" in hdr:
                return hdr, self._read_shm(hdr["shm"])
            return hdr, body

    def _read_shm(self, s: dict) -> bytes:
        """Copy the response out of the daemon's ring slot, then release
        it. Returns raw bytes (the caller reshapes)."""
        name = s["ring"]
        ring = self._rings.get(name)
        try:
            if ring is None:
                ring = self._rings[name] = ShmRing.attach(name)
            view = ring.view(ShmHeader(s["offset"], s["nbytes"], s["dtype"],
                                       tuple(s["shape"])))
            data = view.tobytes()
            del view
        finally:
            # release even on a failed attach/copy: the slot must not leak
            try:
                send_msg(self._sock, {"op": "release",
                                      "offset": s["offset"]})
            except OSError:
                pass
        return data

    # -------------------------------------------------------------- queries
    def _series(self, series) -> str:
        s = series if series is not None else self.series
        if s is None:
            raise ValueError("no series bound to this client and none given")
        return str(s)

    def ping(self) -> bool:
        hdr, _ = self._call({"op": "ping"})
        return bool(hdr["result"]["pong"])

    def stats(self) -> dict:
        hdr, _ = self._call({"op": "stats"})
        return hdr["result"]

    def metrics(self) -> dict:
        """The daemon's histogram plane: cells, percentile summaries,
        stragglers, Prometheus text (the `metrics` admin op)."""
        hdr, _ = self._call({"op": "metrics"})
        return hdr["result"]

    def watch(self, interval_s: float = 1.0, count: int = 2,
              on_frame=None) -> dict:
        """Stream `count` periodic counter-delta frames from the daemon
        (the `watch` op). Returns {"begin": <abs counters>, "frames":
        [frame, ...], "end": <abs counters>}; `on_frame(frame)` is called
        live per frame (the CLI prints from it). Blocking, but on a
        DEDICATED connection dialed for the stream — it never takes the
        client's request lock, so stats()/read() from other threads keep
        answering while a watch runs (a count*interval stream under
        `_lock` used to starve every concurrent call — jbplint JBP004)."""
        sock, _ = self._dial(shm=False)
        try:
            send_msg(sock, {"op": "watch",
                            "interval_s": float(interval_s),
                            "count": int(count)})
            frames: list[dict] = []
            begin = None
            while True:
                hdr, _ = recv_msg(sock)
                if hdr is None:
                    raise DaemonDisconnectedError(
                        f"jbpd at {self.address!r} closed the "
                        f"connection mid-watch")
                if not hdr.get("ok"):
                    err = hdr.get("error", {})
                    raise JbpdRequestError(err.get("kind", "error"),
                                           err.get("msg", "watch failed"))
                if "watch" in hdr:
                    begin = hdr["watch"]["begin"]
                    continue
                if hdr.get("done"):
                    return {"begin": begin, "frames": frames,
                            "end": hdr.get("counters")}
                frames.append(hdr["frame"])
                if on_frame is not None:
                    on_frame(hdr["frame"])
        except OSError as e:
            raise DaemonDisconnectedError(
                f"jbpd at {self.address!r} went away mid-watch") from e
        finally:
            try:
                sock.close()
            except OSError:
                pass

    def shutdown(self):
        """Admin: ask the daemon to stop (the response races the daemon's
        own teardown; either way the daemon is going down)."""
        try:
            self._call({"op": "shutdown"})
        except DaemonDisconnectedError:
            pass
        self._drop()

    def steps(self, series=None) -> list[int]:
        hdr, _ = self._call({"op": "steps", "series": self._series(series)})
        return hdr["result"]["steps"]

    def variables(self, steps=None, *, series=None) -> dict:
        hdr, _ = self._call({"op": "variables", "steps": steps,
                             "series": self._series(series)})
        return hdr["result"]["variables"]

    def layout(self, steps=None, *, series=None) -> dict[int, dict]:
        hdr, _ = self._call({"op": "layout", "steps": steps,
                             "series": self._series(series)})
        return {int(k): v for k, v in hdr["result"]["layout"].items()}

    def attributes(self, step: int, *, series=None) -> dict:
        hdr, _ = self._call({"op": "attributes", "step": int(step),
                             "series": self._series(series)})
        return hdr["result"]["attrs"]

    def var_minmax(self, step: int, name: str, *,
                   series=None) -> Optional[tuple]:
        hdr, _ = self._call({"op": "var_minmax", "step": int(step),
                             "name": name, "series": self._series(series)})
        mm = hdr["result"]["minmax"]
        return tuple(mm) if mm is not None else None

    def iter_chunks(self, step: int, name: str, *, series=None) -> list[dict]:
        hdr, _ = self._call({"op": "iter_chunks", "step": int(step),
                             "name": name, "series": self._series(series)})
        return hdr["result"]["chunks"]

    def read_var(self, step: int, name: str, offset=None, extent=None, *,
                 series=None) -> np.ndarray:
        hdr, data = self._call({
            "op": "read_var", "step": int(step), "name": name,
            "offset": list(offset) if offset is not None else None,
            "extent": list(extent) if extent is not None else None,
            "series": self._series(series)})
        meta = hdr.get("shm") or hdr["array"]
        arr = np.frombuffer(data, dtype=np.dtype(meta["dtype"]))
        return arr.reshape(tuple(meta["shape"])).copy()

    # ------------------------------------------------------------ lifecycle
    def close(self):
        self._drop()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
