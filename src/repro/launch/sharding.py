"""Partition rules: param/opt/cache pytrees -> NamedShardings.

Policy (DESIGN.md §4):
  * `model` (tp): attention heads OR head_dim (per-arch, see attn_layout),
    d_ff, vocab, experts, SSM heads.
  * `data` (fsdp): the complementary weight dim (ZeRO-3-style); batch.
  * `pod`: pure data parallel — batch only, params replicated across pods.

Every rule is divisibility-guarded: a dim that doesn't divide the axis size
falls back to replicated on that axis (e.g. smollm's 15 heads).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP = "data"
TP = "model"
BATCH = ("pod", "data")


def attn_layout(cfg, tp_size: int) -> str:
    """Legacy single-layout summary (tests/reporting)."""
    q, kv = attn_layouts(cfg, tp_size)
    if q == (TP, None):
        return "heads"
    if q == (None, TP):
        return "head_dim"
    return "replicated"


def attn_layouts(cfg, tp_size: int):
    """((q_heads_spec, q_hd_spec), (kv_heads_spec, kv_hd_spec)).

    Query heads shard over `model` whenever H divides; KV heads shard only
    when Hkv divides — otherwise KV projections/caches stay REPLICATED over
    `model` (they are G-times smaller than Q, and replication avoids the
    per-layer resharding all-to-all that a mismatched head_dim layout costs
    — see EXPERIMENTS.md §Perf hillclimb B). Archs where H doesn't divide
    (arctic 56, smollm 15) fall back to head_dim sharding for both."""
    if not cfg.n_heads:
        return (None, None), (None, None)
    hd_ok = cfg.resolved_head_dim % tp_size == 0
    if cfg.n_heads % tp_size == 0:
        q = (TP, None)
        kv = (TP, None) if cfg.n_kv_heads % tp_size == 0 else (None, None)
        return q, kv
    if hd_ok:
        return (None, TP), (None, TP)
    return (None, None), (None, None)


# --------------------------------------------------------------------------
# base specs keyed by (tail-of-path pattern). Leaves with extra leading stack
# dims get Nones prepended.
# --------------------------------------------------------------------------
def _param_base_spec(path: tuple[str, ...], cfg, tp_size: int):
    j = "/".join(path)
    (qh, qd), (kh, kd) = attn_layouts(cfg, tp_size)

    if path[-1] == "table":                       # embed / lm_head [V, d]
        return (TP, FSDP)
    if path[-2:] == ("wo", "w"):                  # [H, hd, d_model]
        return (qh, qd, FSDP)
    if len(path) >= 2 and path[-2] in ("wq",):
        if path[-1] == "w":                       # [d_model, H, hd]
            return (FSDP, qh, qd)
        return (qh, qd)                           # bias [H, hd]
    if len(path) >= 2 and path[-2] in ("wk", "wv"):
        if path[-1] == "w":                       # [d_model, Hkv, hd]
            return (FSDP, kh, kd)
        return (kh, kd)
    if path[-1] == "router":                      # [d_model, E]
        return (FSDP, None)
    if "experts" in path:
        # expert-parallel over `model` + Megatron col/row parallel over
        # `data` WITHIN each expert: weights are fully sharded with NO
        # ZeRO-3 per-microbatch re-gathers (§Perf hillclimb A it.6)
        if path[-1] in ("gate", "up"):            # [E, d_model, d_ff]
            return (TP, None, FSDP)
        return (TP, FSDP, None)                   # down [E, d_ff, d_model]
    if path[-2:] == ("gate", "w") or path[-2:] == ("up", "w"):
        return (FSDP, TP)                         # ffn in [d_model, d_ff]
    if path[-2:] == ("down", "w"):
        return (TP, FSDP)                         # ffn out [d_ff, d_model]
    if path[-2:] == ("gate", "b") or path[-2:] == ("up", "b"):
        return (TP,)
    if path[-2:] == ("down", "b"):
        return (FSDP,)
    # ---- mamba2 -------------------------------------------------------------
    if path[-2:] == ("wz", "w") or path[-2:] == ("wx", "w"):
        return (FSDP, TP)                         # [d_model, d_inner]
    if path[-2:] == ("wB", "w") or path[-2:] == ("wC", "w"):
        return (FSDP, None)                       # [d_model, N] group-shared
    if path[-2:] == ("wdt", "w"):
        return (FSDP, TP)                         # [d_model, H]
    if path[-2:] == ("out_proj", "w"):
        return (TP, FSDP)                         # [d_inner, d_model]
    if path[-2:] == ("conv_x", "w"):
        return (None, TP)                         # [K, d_inner]
    if path[-2:] == ("conv_x", "b"):
        return (TP,)
    if len(path) >= 2 and path[-2] in ("conv_B", "conv_C"):
        return (None, None) if path[-1] == "w" else (None,)
    if path[-1] in ("A_log", "D", "dt_bias"):
        return (TP,)                              # [H_ssm]
    if path[-2:] == ("wz", "b") or path[-2:] == ("wx", "b"):
        return (TP,)
    if path[-1] in ("b",):                        # remaining 1-D biases
        return (None,)
    if path[-1] == "scale":                       # norms
        shape_hint = None
        return None                               # rank-resolved below (replicate)
    if path[-1] in ("attn_gate", "ffn_gate"):
        return None
    return None                                   # default: replicate


def _guard(spec_entries, shape, mesh) -> P:
    """Drop axes that don't divide the dim; filter axes absent from mesh."""
    sizes = dict(mesh.shape)
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in sizes)
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if not axes or dim % total != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def _pad_entries(names, shape, base) -> tuple:
    """Left-pad a sharding rule's spec entries with None to the array's
    rank. A base spec LONGER than the rank means the sharding table names
    more axes than the tensor has — a table bug, not a caller error."""
    base = tuple(base)
    pad = len(shape) - len(base)
    if pad < 0:
        raise RuntimeError(
            f"sharding rule for {'/'.join(names)} names {len(base)} axes "
            f"{base} but the array only has rank {len(shape)} "
            f"(shape {tuple(shape)}) — fix the param sharding table")
    return (None,) * pad + base


def param_pspec_tree(cfg, mesh, shapes_tree):
    """PartitionSpec pytree matching `shapes_tree` (from model.param_shapes)."""
    tp_size = int(mesh.shape[TP]) if TP in mesh.axis_names else 1

    def rule(path, leaf):
        names = tuple(getattr(k, "key", str(k)) for k in path)
        base = _param_base_spec(names, cfg, tp_size)
        if base is None:
            base = ()
        entries = _pad_entries(names, leaf.shape, base)
        return _guard(entries, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, shapes_tree)


def param_sharding_tree(cfg, mesh, shapes_tree):
    specs = param_pspec_tree(cfg, mesh, shapes_tree)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def opt_sharding_tree(cfg, mesh, shapes_tree):
    """Optimizer-moment shardings: param specs with the FSDP axis widened to
    ('pod', FSDP) — ZeRO-1 across pods (no-op on single-pod meshes)."""
    if "pod" not in mesh.axis_names:
        return param_sharding_tree(cfg, mesh, shapes_tree)
    specs = param_pspec_tree(cfg, mesh, shapes_tree)

    def widen(path, spec):
        leaf = _lookup(shapes_tree, path)
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = []
        widened = False
        for dim, e in zip(leaf.shape, entries):
            axes = e if isinstance(e, tuple) else ((e,) if e else ())
            if not widened and FSDP in axes:
                cand = ("pod",) + axes
                total = int(np.prod([dict(mesh.shape)[a] for a in cand]))
                if dim % total == 0:
                    out.append(cand)
                    widened = True
                    continue
            out.append(e)
        return P(*out)

    widened = jax.tree_util.tree_map_with_path(widen, specs)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), widened)


def _lookup(tree, path):
    node = tree
    for k in path:
        key = getattr(k, "key", getattr(k, "idx", None))
        node = node[key]
    return node


# --------------------------------------------------------------------------
# activations / batches / caches
# --------------------------------------------------------------------------
def batch_spec(mesh, rank: int, *, batch_axes=BATCH) -> NamedSharding:
    """Shard dim 0 over the batch axes present in the mesh (guarded)."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    return NamedSharding(mesh, P(axes if axes else None, *([None] * (rank - 1))))


def batch_sharding_for(mesh, sds, *, batch_axes=BATCH):
    sizes = dict(mesh.shape)
    axes = tuple(a for a in batch_axes if a in sizes)
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1
    if not axes or sds.shape[0] % total != 0:
        return NamedSharding(mesh, P(*([None] * len(sds.shape))))
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0],
                                 *([None] * (len(sds.shape) - 1))))


def cache_pspec_tree(cfg, mesh, cache_spec_tree):
    """Decode-cache shardings: batch dim over `data`, heads/head_dim over
    `model` per attn_layout; SSM heads over `model`."""
    tp_size = int(mesh.shape[TP]) if TP in mesh.axis_names else 1
    _, (kh, kd) = attn_layouts(cfg, tp_size)
    # decode caches are the capacity-critical tensors: even when the (small)
    # KV *weights* stay replicated for GQA, the 32k cache must shard — fall
    # back to head_dim sharding (partial-dot + tiny score all-reduce).
    if kh is None and kd is None and cfg.n_heads \
            and cfg.resolved_head_dim % tp_size == 0 and tp_size > 1:
        kd = TP

    def rule(path, leaf):
        names = tuple(getattr(k, "key", str(k)) for k in path)
        rank = len(leaf.shape)
        key = names[-1] if names else ""
        if key in ("k", "v", "cross_k", "cross_v"):
            # [..., B, S, Hkv, hd] — batch at rank-4, heads at rank-2
            entries = [None] * rank
            entries[rank - 4] = FSDP
            entries[rank - 2] = kh
            entries[rank - 1] = kd
        elif key == "ssm":
            # [..., B, H, P, N]
            entries = [None] * rank
            entries[rank - 4] = FSDP
            entries[rank - 3] = TP
        else:
            # conv tails (tuple leaves): [..., B, K-1, C]; C = d_inner -> TP
            entries = [None] * rank
            entries[rank - 3] = FSDP
            if leaf.shape[-1] == cfg.d_inner:
                entries[rank - 1] = TP
        return _guard(tuple(entries), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache_spec_tree)


def cache_sharding_tree(cfg, mesh, cache_spec_tree):
    specs = cache_pspec_tree(cfg, mesh, cache_spec_tree)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def replicated(mesh):
    return NamedSharding(mesh, P())
