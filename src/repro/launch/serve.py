"""Serving launcher: restore a checkpoint and run batched greedy generation.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --ckpt-dir /tmp/repro-ckpt --smoke --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs.base import get_config, reduce_for_smoke
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored = mgr.restore_latest({"params": params})
        if restored is not None:
            params = restored[0]["params"]
            print(f"restored checkpoint step {restored[1]}")

    eng = ServeEngine(cfg, params, ServeConfig(max_batch=args.batch,
                                               max_seq=args.max_seq,
                                               max_new_tokens=args.new_tokens))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    toks = eng.generate(prompts, new_tokens=args.new_tokens)
    for i, row in enumerate(toks.tolist()):
        print(f"req{i}: {row}")


if __name__ == "__main__":
    main()
