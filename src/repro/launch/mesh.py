"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across JAX versions: newer releases take (and want)
    explicit axis_types; older ones (<= 0.4.x) reject the kwarg and have no
    jax.sharding.AxisType at all."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: `pod` (DCN, pure data-parallel replicas), `data` (ICI, batch +
    FSDP/ZeRO shards), `model` (ICI, tensor/expert parallel).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False, devices=None):
    """Small-device-count mesh with the same axis names (tests / CI)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if multi_pod:
        if n % 2 or n < 8:
            raise ValueError(f"multi-pod debug mesh needs an even device "
                             f"count >= 8, got {n}")
        shape = (2, n // 4, 2)
        axes = ("pod", "data", "model")
    else:
        if n % 2:
            raise ValueError(
                f"debug mesh needs an even device count, got {n}")
        shape = (n // 2, 2)
        axes = ("data", "model")
    return compat_make_mesh(shape, axes)


def mesh_summary(mesh) -> dict:
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
            "n_devices": int(mesh.size)}
