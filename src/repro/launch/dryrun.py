import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with ShapeDtypeStruct stand-ins, then derive roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results accumulate in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json —
reruns are incremental (use --force to recompute).
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs.base import get_config, list_configs
from repro.launch import shapes as SH
from repro.launch.mesh import make_production_mesh, mesh_summary
from repro.launch.sharding import attn_layout
from repro.meshctx import use_mesh
from repro.optim.adamw import AdamWConfig
from repro.roofline.analysis import build_report
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# Per-shape chunking (memory-lean attention for the 32k shapes) +
# gradient-accumulation depth for training (fits 16 GB HBM — §Perf it.5).
CHUNKS = {
    "train_4k": dict(q_chunk=1024, kv_chunk=1024, ssd_chunk=128,
                     microbatches=8),
    "prefill_32k": dict(q_chunk=1024, kv_chunk=1024, ssd_chunk=128),
    "decode_32k": dict(),
    "long_500k": dict(),
}


def step_fn_for(cfg, kind: str, shape_name: str, tuning: dict | None = None):
    ch = dict(CHUNKS.get(shape_name, {}))
    if tuning:
        ch.update(tuning)
    if kind == "train":
        return make_train_step(cfg, AdamWConfig(), **ch)
    if kind == "prefill":
        return make_prefill_step(cfg, **ch)
    return make_decode_step(cfg)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, tuning=None,
             verbose=True) -> dict:
    cfg = get_config(arch)
    ok, why = SH.applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    spec = SH.input_specs(cfg, shape_name, mesh)
    fn = step_fn_for(cfg, spec["kind"], shape_name, tuning)

    t0 = time.time()
    with use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=spec["in_shardings"],
                         donate_argnums=spec["donate_argnums"])
        lowered = jitted.lower(*spec["args"])
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # older JAX: one dict per device
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    case = spec["case"]
    rep = build_report(arch=arch, shape=shape_name, mesh_name=mesh_kind,
                       n_devices=int(mesh.size), hlo_text=hlo, cfg=cfg,
                       kind=case.kind, seq=case.seq, batch=case.batch,
                       mem_stats=mem, xla_cost=cost)
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "ok", "kind": case.kind,
           "mesh_info": mesh_summary(mesh),
           "attn_layout": attn_layout(cfg, int(mesh.shape["model"])),
           "compile_s": t1 - t0,
           "memory_analysis": {
               "argument_bytes": float(mem.argument_size_in_bytes),
               "output_bytes": float(mem.output_size_in_bytes),
               "temp_bytes": float(mem.temp_size_in_bytes),
               "alias_bytes": float(mem.alias_size_in_bytes),
           },
           "roofline": rep.to_dict()}
    if verbose:
        r = rep
        print(f"[{arch} x {shape_name} x {mesh_kind}] compile={t1-t0:.1f}s "
              f"compute={r.compute_s*1e3:.3f}ms memory={r.memory_s*1e3:.3f}ms "
              f"collective={r.collective_s*1e3:.3f}ms dominant={r.dominant} "
              f"useful={r.useful_flops_ratio:.3f} mfu_bound={r.mfu_bound:.3f} "
              f"args={out['memory_analysis']['argument_bytes']/2**30:.2f}GiB "
              f"temp={out['memory_analysis']['temp_bytes']/2**30:.2f}GiB "
              f"fits={r.fits_hbm}")
    return out


def cell_path(arch, shape, mesh_kind) -> pathlib.Path:
    return RESULTS_DIR / f"{arch}__{shape}__{mesh_kind}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SH.SHAPE_TABLE) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(arch, shape, mesh_kind)
                if path.exists() and not args.force:
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[{arch} x {shape} x {mesh_kind}] cached "
                              f"({prev['status']})")
                        continue
                try:
                    out = run_cell(arch, shape, mesh_kind)
                except Exception as e:  # noqa: BLE001
                    out = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    print(f"[{arch} x {shape} x {mesh_kind}] ERROR: {e!r}")
                path.write_text(json.dumps(out, indent=1))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\ndry-run complete: all requested cells OK")


if __name__ == "__main__":
    main()
