"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 100 --seq 256 --batch 8 --ckpt-dir /tmp/ckpt [--smoke]

On a TPU pod this process runs once per host under `jax.distributed` and the
production mesh shards the TrainState per launch/sharding.py; on this CPU
container --smoke substitutes the reduced config (same code path).
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_config, reduce_for_smoke
from repro.core.bp_engine import EngineConfig
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--aggregators", type=int, default=4)
    ap.add_argument("--codec", default="blosc")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    tcfg = TrainerConfig(steps=args.steps, log_every=10,
                         ckpt_every=args.ckpt_every, seq_len=args.seq,
                         global_batch=args.batch,
                         grad_compression=args.grad_compression)
    hp = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                     total_steps=args.steps)
    engine = EngineConfig(aggregators=args.aggregators, codec=args.codec,
                          workers=4)
    tr = Trainer(cfg, tcfg, hp, args.ckpt_dir, engine_config=engine)
    out = tr.run()
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
