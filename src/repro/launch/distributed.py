"""Multi-host bring-up for real pods (the 1000+-node path).

On a TPU pod slice each host runs this once before anything else; the
single-controller code in train.py/serve.py then works unchanged —
jax.make_mesh sees the global device set, arrays are globally sharded, and
each host's data loader reads its shard (data/pipeline.py n_shards/shard_id).

This container has one process/one device, so initialize() degrades to a
no-op — but the contract (env-driven, idempotent, crash-barrier on restart)
is the deployable one:

  * COORDINATOR failure: jax.distributed heartbeats fail fast; the job
    controller restarts all processes, which re-enter through
    `Trainer.run()` -> `CheckpointManager.restore_latest()` — the newest
    crc-valid checkpoint wins, torn writes are skipped (ckpt/manager.py).
  * ELASTIC restart at a different world size: restore_sharded() reads
    per-shard boxes from the chunk table, so N->M rescale reads
    min(bytes-needed), not the full state.
  * STRAGGLERS: per-host JBP writer pools absorb slow OSTs (work stealing);
    async checkpointing keeps slow storage off the step path; cross-pod
    gradient traffic can run int8 error-feedback compressed
    (optim/grad_compress.py).
"""
from __future__ import annotations

import multiprocessing
import multiprocessing.connection
import os
import queue as _queue
import time
from typing import Callable, Optional


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> dict:
    """Idempotent jax.distributed bring-up from args or env
    (JAX_COORDINATOR / JAX_NUM_PROCESSES / JAX_PROCESS_ID)."""
    import jax

    coordinator = coordinator or os.environ.get("JAX_COORDINATOR")
    num_processes = num_processes or int(os.environ.get("JAX_NUM_PROCESSES", "1"))
    process_id = (process_id if process_id is not None
                  else int(os.environ.get("JAX_PROCESS_ID", "0")))
    if num_processes > 1:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
    return {"process_id": process_id, "num_processes": num_processes,
            "local_devices": len(jax.local_devices()),
            "global_devices": len(jax.devices())}


def io_rank_range(n_io_ranks: int, process_id: int, num_processes: int):
    """Which logical I/O ranks this host owns (block assignment, mirroring
    aggregation.aggregator_of so rank->aggregator locality is preserved)."""
    lo = process_id * n_io_ranks // num_processes
    hi = (process_id + 1) * n_io_ranks // num_processes
    return range(lo, hi)


def writer_rank_range(w: int, n_ranks: int, n_writers: int) -> range:
    """Ranks owned by writer/aggregator `w` — the exact inverse image of
    `aggregation.aggregator_of`'s contiguous block assignment, so a writer
    process knows up front which ranks' chunks it will receive."""
    m = min(n_writers, max(n_ranks, 1))
    lo = -(-w * n_ranks // m)              # ceil(w * n_ranks / m)
    hi = -(-(w + 1) * n_ranks // m)
    return range(lo, hi)


class WorkerAckQueue:
    """Coordinator-side fan-in over one result queue PER worker.

    A single shared `mp.Queue` has one pipe write-lock shared by every
    worker's feeder thread. A worker SIGKILLed inside the
    `send_bytes`..`release` window abandons that lock and every
    SURVIVING worker's acks wedge behind it forever — `close()` then
    times out instead of returning. With one queue per worker the
    abandoned lock dies with its owner; peers keep acking.

    Exposes the `get(timeout=)` / `get_nowait()` subset the coordinator
    uses, so call sites treat it exactly like the old shared queue.
    """

    def __init__(self, queues):
        self.queues = list(queues)
        self._next = 0

    def get_nowait(self):
        for _ in range(len(self.queues)):
            q = self.queues[self._next]
            self._next = (self._next + 1) % len(self.queues)
            try:
                return q.get_nowait()
            except _queue.Empty:
                continue
        raise _queue.Empty

    def get(self, timeout: Optional[float] = None):
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            try:
                return self.get_nowait()
            except _queue.Empty:
                if deadline is not None and time.monotonic() >= deadline:
                    raise
            readers = [q._reader for q in self.queues]
            wait_t = (0.1 if deadline is None
                      else max(0.0, min(0.1, deadline - time.monotonic())))
            multiprocessing.connection.wait(readers, timeout=wait_t)


def spawn_io_workers(n_workers: int, target: Callable, make_args: Callable,
                     *, method: str = "spawn"):
    """Spawn REAL I/O writer processes (the multi-process write plane of
    repro.core.parallel_engine — this is the layer io_rank_range used to
    stub out with logical threads).

    `target` must be a module-level function (picklable by reference under
    the spawn start method — spawn, not fork, because the parent may hold
    JAX/XLA runtime threads that do not survive a fork). `make_args(w,
    task_q, result_q)` builds the argument tuple for worker `w`.

    Returns ([(process, task_queue)], ack_queue): one task queue per
    worker (commands flow down) and a `WorkerAckQueue` fan-in over one
    private result queue per worker (acks flow up — private so a killed
    worker cannot wedge its peers' acks behind an abandoned pipe lock).
    Workers are daemonic, so an abnormal parent exit reaps them.
    """
    ctx = multiprocessing.get_context(method)
    workers = []
    result_qs = []
    for w in range(n_workers):
        task_q = ctx.Queue()
        result_q = ctx.Queue()
        p = ctx.Process(target=target, args=make_args(w, task_q, result_q),
                        name=f"jbp-io-{w}", daemon=True)
        p.start()
        workers.append((p, task_q))
        result_qs.append(result_q)
    return workers, WorkerAckQueue(result_qs)
