"""Assigned input shapes and ShapeDtypeStruct stand-ins per (arch x shape).

input_specs() returns everything the dry-run needs to lower a cell: the step
kind, positional ShapeDtypeStruct args, matching in_shardings, and donation
indices — no device allocation ever happens (the shannon/kernels pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as S
from repro.models import model as M
from repro.models.layers import COMPUTE_DTYPE
from repro.train.state import train_state_shapes, train_state_shardings

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPE_TABLE = {
    "train_4k": ShapeCase("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524288, 1),
}


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    case = SHAPE_TABLE[shape_name]
    if case.name == "long_500k" and not cfg.supports_long_context:
        return False, ("skipped: pure full-attention arch — O(S^2) attention at "
                       "S=524288 is not deployable (DESIGN.md §5)")
    return True, ""


def _batch_struct(cfg, case: ShapeCase, mesh):
    """(batch_sds_dict, batch_sharding_dict) for train/prefill."""
    B, Sq = case.batch, case.seq
    sds: dict[str, Any] = {}
    if cfg.family == "audio":
        sds["embeds"] = SDS((B, Sq, cfg.d_model), COMPUTE_DTYPE)
    else:
        sds["tokens"] = SDS((B, Sq), jnp.int32)
    if case.kind == "train":
        sds["labels"] = SDS((B, Sq), jnp.int32)
    if cfg.family == "vlm":
        sds["vision_embeds"] = SDS((B, cfg.n_vision_tokens, cfg.d_model),
                                   COMPUTE_DTYPE)
    shardings = {k: S.batch_sharding_for(mesh, v) for k, v in sds.items()}
    return sds, shardings


def input_specs(cfg, shape_name: str, mesh, *, grad_compression=False) -> dict:
    """Returns {kind, args, in_shardings, donate_argnums, case}."""
    case = SHAPE_TABLE[shape_name]
    ok, why = applicable(cfg, shape_name)
    if not ok:
        raise ValueError(why)

    if case.kind == "train":
        state_sds = train_state_shapes(cfg, grad_compression=grad_compression)
        state_sh = train_state_shardings(cfg, mesh,
                                         grad_compression=grad_compression)
        batch_sds, batch_sh = _batch_struct(cfg, case, mesh)
        return dict(kind="train", case=case,
                    args=(state_sds, batch_sds),
                    in_shardings=(state_sh, batch_sh),
                    donate_argnums=(0,))

    params_sds = M.param_shapes(cfg)
    params_sh = S.param_sharding_tree(cfg, mesh, params_sds)

    if case.kind == "prefill":
        batch_sds, batch_sh = _batch_struct(cfg, case, mesh)
        return dict(kind="prefill", case=case,
                    args=(params_sds, batch_sds),
                    in_shardings=(params_sh, batch_sh),
                    donate_argnums=())

    # ---- decode: one new token against a seq_len cache ----------------------
    B, Sq = case.batch, case.seq
    cache_sds = M.make_decode_cache_spec(cfg, B, Sq)
    cache_sh = S.cache_sharding_tree(cfg, mesh, cache_sds)
    tok_sds = SDS((B, 1), jnp.int32)
    tok_sh = S.batch_sharding_for(mesh, tok_sds, batch_axes=("data",))
    len_sds = SDS((), jnp.int32)
    args = [params_sds, cache_sds, tok_sds, len_sds]
    shardings = [params_sh, cache_sh, tok_sh, S.replicated(mesh)]
    if cfg.family == "audio":
        emb = SDS((B, 1, cfg.d_model), COMPUTE_DTYPE)
        args.append(emb)
        shardings.append(S.batch_sharding_for(mesh, emb, batch_axes=("data",)))
    return dict(kind="decode", case=case, args=tuple(args),
                in_shardings=tuple(shardings), donate_argnums=(1,))
