"""Ambient mesh for in-model sharding hints.

Model code calls `shard_hint(x, 'axis', ...)` to constrain intermediate
layouts (e.g. the MoE dispatch buffer). Outside a mesh context (unit tests,
single-device smoke runs) hints are no-ops, so the same code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return int(mesh.shape[name])


def shard_hint(x, *spec):
    """with_sharding_constraint if a mesh is active, else identity.

    Axis names absent from the active mesh are dropped (lets the same hint
    serve single-pod and multi-pod meshes).
    """
    mesh = current_mesh()
    if mesh is None:
        return x

    def _filter(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            return kept if kept else None
        return entry if entry in mesh.axis_names else None

    fspec = P(*[_filter(e) for e in spec])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fspec))
