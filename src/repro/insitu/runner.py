"""Run reducers in-situ (over a live SstStream) or post-hoc (over a
BpReader series), plus the parity check that ties the two together.

The canonical wiring for "analyze live AND keep the data" is a teed stream:

    writer = AsyncBpWriter(path, n_ranks, cfg)
    stream = SstStream(queue_depth=2, tee=writer)
    rset   = ReducerSet([...])
    t      = attach_reducers(stream, rset)
    ... producer put()/end_step() loop ...
    stream.close(); t.join()
    live = rset.results()

and afterwards `reduce_posthoc(path, fresh_rset)` over the teed series must
equal `live` exactly — `assert_parity(live, posthoc)` is the guarantee.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional, Union

import numpy as np

from repro.core.bp_engine import BpReader
from repro.core.sst_engine import SstStream, attach_consumer
from repro.insitu.reducers import ReducerSet


def attach_reducers(stream: SstStream, rset: ReducerSet,
                    *, daemon: bool = True) -> threading.Thread:
    """Consume the stream in a background thread, updating every reducer
    with each step as it arrives (in-situ: no filesystem in the loop)."""
    return attach_consumer(stream, rset.update, daemon=daemon)


def reduce_posthoc(series: Union[str, BpReader], rset: ReducerSet,
                   *, steps: Optional[list] = None,
                   parallel: Optional[int] = None) -> dict:
    """Replay a series on disk through the reducers, in sorted step order
    (the same order a live FIFO consumer observed). Only the variables the
    reducers declare in `needs` are read from the subfiles. `parallel=N`
    fans each variable's chunk reads over a ReaderPool; the default
    (None) leaves a caller-owned reader's own configured parallelism in
    charge. A reader WE open is managed as a context (pool + subfile
    handles released even when a reducer or a corrupt chunk raises
    mid-replay); a caller-owned reader is left open for the caller."""
    own = not isinstance(series, BpReader)
    cm = (BpReader(series, parallel=parallel or 0) if own
          else contextlib.nullcontext(series))
    needed = rset.needed_vars
    with cm as reader:
        for step in (reader.valid_steps() if steps is None else steps):
            names = reader.var_names(step)
            if needed is not None:
                names = [n for n in names if n in needed]
            rset.update(step, {n: reader.read_var(step, n, parallel=parallel)
                               for n in names})
    return rset.results()


def assert_parity(live: dict, posthoc: dict, path: str = "results",
                  atol: float = 0.0):
    """Equality of two reducer result trees; raises AssertionError naming
    the first diverging leaf. `atol=0` (default) demands exact, bitwise
    equality for arrays. A positive `atol` is parity-within-bounds: the
    contract when the teed series was stored through an error-bounded
    lossy codec ("lossy:<bound>") — post-hoc replay then reconstructs
    values within the codec bound, and so must every reduced scalar."""
    # explicit raises (not bare asserts): the documented AssertionError
    # contract must hold under `python -O` too
    if isinstance(live, dict) and isinstance(posthoc, dict):
        if live.keys() != posthoc.keys():
            raise AssertionError(
                f"{path}: keys {sorted(live)} != {sorted(posthoc)}")
        for k in live:
            assert_parity(live[k], posthoc[k], f"{path}/{k}", atol=atol)
        return
    if isinstance(live, np.ndarray) or isinstance(posthoc, np.ndarray):
        a, b = np.asarray(live), np.asarray(posthoc)
        if a.dtype != b.dtype or a.shape != b.shape:
            raise AssertionError(f"{path}: arrays differ")
        if atol > 0.0 and a.dtype.kind == "f":
            if not np.allclose(a, b, rtol=0.0, atol=atol, equal_nan=True):
                err = float(np.nanmax(np.abs(
                    a.astype(np.float64) - b.astype(np.float64))))
                raise AssertionError(
                    f"{path}: arrays differ by {err:g} > atol={atol:g}")
        elif not np.array_equal(a, b, equal_nan=True):
            raise AssertionError(f"{path}: arrays differ")
        return
    if atol > 0.0 and isinstance(live, float) and isinstance(posthoc, float):
        if not (abs(live - posthoc) <= atol
                or (math.isnan(live) and math.isnan(posthoc))):
            raise AssertionError(
                f"{path}: {live!r} != {posthoc!r} (atol={atol:g})")
        return
    if live != posthoc:
        raise AssertionError(f"{path}: {live!r} != {posthoc!r}")
