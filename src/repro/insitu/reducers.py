"""Streaming reduction framework for in-situ analysis (paper §VI future
work; the follow-up study arXiv:2406.19058 makes it explicit).

A `Reducer` consumes one step at a time — `update(step, vars)` where `vars`
is the step's assembled `{name: np.ndarray}` — and produces an accumulated
`result()`. The SAME reducer runs in two places:

  * live, attached to an `SstStream` consumer thread (in-situ: no
    filesystem in the loop, data reduced the moment the producer emits it —
    the scalability story of Huebl et al., arXiv:1706.00522), or
  * post-hoc, replayed over a `BpReader` series on disk.

Parity guarantee: every reducer here is a DETERMINISTIC function of the
(step, vars) sequence — accumulation is float64 in array order, histograms
are summed step by step — and both paths deliver identical arrays in
identical step order (the stream queue is FIFO; the reader replays
`valid_steps()` in sorted order; the JBP codecs are lossless). Therefore a
live run over a teed stream and a post-hoc run over the teed series produce
bit-identical results — `tests/test_insitu.py::test_parity_*` holds this.

Reducers tolerate missing variables (a step that doesn't carry `var` is
skipped), so mvstep/dmpstep-style mixed series reduce cleanly.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np


class Reducer:
    """Protocol: update(step, vars) -> None, result() -> dict, reset()."""

    #: variables this reducer consumes; None means "needs every variable"
    #: (post-hoc runners use this to read only the needed bytes).
    needs: Optional[tuple] = None
    name: str = "reducer"

    def update(self, step: int, vars: dict) -> None:
        raise NotImplementedError

    def result(self) -> dict:
        raise NotImplementedError

    def reset(self) -> None:
        raise NotImplementedError


class Moments(Reducer):
    """Particle moments of one variable: count, mean, variance, min/max.

    Exact accumulation (float64 sums of x and x^2 in array order) rather
    than a running mean — determinism is what makes the stream/post-hoc
    parity guarantee bitwise, not approximate.
    """

    def __init__(self, var: str, name: Optional[str] = None):
        self.var = var
        self.needs = (var,)
        self.name = name or f"moments({var})"
        self.reset()

    def reset(self):
        self._n = 0
        self._s1 = 0.0
        self._s2 = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._steps = 0

    def update(self, step, vars):
        arr = vars.get(self.var)
        if arr is None:
            return
        a = np.asarray(arr)
        if a.size == 0:
            return
        self._steps += 1
        self._n += int(a.size)
        self._s1 += float(np.sum(a, dtype=np.float64))
        self._s2 += float(np.sum(np.square(a, dtype=np.float64),
                                 dtype=np.float64))
        lo, hi = float(a.min()), float(a.max())
        self._min = lo if self._min is None else min(self._min, lo)
        self._max = hi if self._max is None else max(self._max, hi)

    def result(self):
        if self._n == 0:
            return {"n": 0, "steps": 0}
        mean = self._s1 / self._n
        var = max(self._s2 / self._n - mean * mean, 0.0)
        return {"n": self._n, "steps": self._steps, "mean": mean,
                "var": var, "std": var ** 0.5,
                "min": self._min, "max": self._max}


class Histogram(Reducer):
    """Accumulated histogram of a variable's values (energy / velocity
    distribution over the whole run). Fixed bin edges keep accumulation a
    plain float64 add — deterministic."""

    def __init__(self, var: str, bins: int = 64, range: tuple = (0.0, 1.0),
                 weight_var: Optional[str] = None, name: Optional[str] = None):
        self.var = var
        self.weight_var = weight_var
        self.bins = int(bins)
        self.range = (float(range[0]), float(range[1]))
        self.needs = (var,) if weight_var is None else (var, weight_var)
        self.name = name or f"hist({var})"
        self.reset()

    def reset(self):
        self._counts = np.zeros(self.bins, np.float64)
        self._steps = 0

    def update(self, step, vars):
        arr = vars.get(self.var)
        if arr is None:
            return
        a = np.asarray(arr).reshape(-1)
        w = None
        if self.weight_var is not None:
            w = vars.get(self.weight_var)
            if w is None:
                return
            w = np.asarray(w).reshape(-1)
        h, _ = np.histogram(a, bins=self.bins, range=self.range, weights=w)
        self._counts += h.astype(np.float64)
        self._steps += 1

    def result(self):
        edges = np.linspace(self.range[0], self.range[1], self.bins + 1)
        return {"counts": self._counts.copy(), "edges": edges,
                "steps": self._steps}


class PhaseSpace2D(Reducer):
    """Accumulated 2D phase-space histogram (e.g. x vs v_x) from two
    equal-length flat arrays."""

    def __init__(self, x_var: str, y_var: str, bins: tuple = (64, 64),
                 range: tuple = ((0.0, 1.0), (-1.0, 1.0)),
                 name: Optional[str] = None):
        self.x_var, self.y_var = x_var, y_var
        self.bins = (int(bins[0]), int(bins[1]))
        self.range = tuple((float(lo), float(hi)) for lo, hi in range)
        self.needs = (x_var, y_var)
        self.name = name or f"phasespace({x_var},{y_var})"
        self.reset()

    def reset(self):
        self._counts = np.zeros(self.bins, np.float64)
        self._steps = 0

    def update(self, step, vars):
        x, y = vars.get(self.x_var), vars.get(self.y_var)
        if x is None or y is None:
            return
        h, _, _ = np.histogram2d(np.asarray(x).reshape(-1),
                                 np.asarray(y).reshape(-1),
                                 bins=self.bins, range=self.range)
        self._counts += h.astype(np.float64)
        self._steps += 1

    def result(self):
        return {"counts": self._counts.copy(), "steps": self._steps}


class FieldEnergy(Reducer):
    """Per-step field energy time series: 0.5 * sum(field^2) * cell_volume."""

    def __init__(self, var: str, cell_volume: float = 1.0,
                 name: Optional[str] = None):
        self.var = var
        self.cell_volume = float(cell_volume)
        self.needs = (var,)
        self.name = name or f"field_energy({var})"
        self.reset()

    def reset(self):
        self._steps: list = []
        self._energy: list = []

    def update(self, step, vars):
        arr = vars.get(self.var)
        if arr is None:
            return
        a = np.asarray(arr)
        e = 0.5 * float(np.sum(np.square(a, dtype=np.float64),
                               dtype=np.float64)) * self.cell_volume
        self._steps.append(int(step))
        self._energy.append(e)

    def result(self):
        return {"steps": np.array(self._steps, np.int64),
                "energy": np.array(self._energy, np.float64)}


class SpeciesCount(Reducer):
    """Per-step weighted count time series (e.g. sum of a density profile
    times dx, or of a weighting record) — BIT1's particle-balance diagnostic."""

    def __init__(self, var: str, scale: float = 1.0,
                 name: Optional[str] = None):
        self.var = var
        self.scale = float(scale)
        self.needs = (var,)
        self.name = name or f"count({var})"
        self.reset()

    def reset(self):
        self._steps: list = []
        self._counts: list = []

    def update(self, step, vars):
        arr = vars.get(self.var)
        if arr is None:
            return
        self._steps.append(int(step))
        self._counts.append(
            float(np.sum(np.asarray(arr), dtype=np.float64)) * self.scale)

    def result(self):
        return {"steps": np.array(self._steps, np.int64),
                "counts": np.array(self._counts, np.float64)}


class ReducerSet:
    """A named bundle of reducers sharing one update stream."""

    def __init__(self, reducers: Iterable[Reducer]):
        self.reducers = list(reducers)
        names = [r.name for r in self.reducers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate reducer names {names} — results "
                             f"are keyed by name, so duplicates would "
                             f"silently overwrite each other")

    @property
    def needed_vars(self) -> Optional[set]:
        """Union of variables the set consumes; None when any reducer needs
        everything (post-hoc runners then read every variable)."""
        out: set = set()
        for r in self.reducers:
            if r.needs is None:
                return None
            out.update(r.needs)
        return out

    def update(self, step: int, vars: dict) -> None:
        for r in self.reducers:
            r.update(step, vars)

    def results(self) -> dict:
        return {r.name: r.result() for r in self.reducers}

    def reset(self) -> None:
        for r in self.reducers:
            r.reset()
