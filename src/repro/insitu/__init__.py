"""In-situ analysis & rapid metadata extraction (paper §V/§VI; follow-up
study arXiv:2406.19058).

Three planes:
  * `repro.insitu.reducers` — streaming reductions (moments, histograms,
    phase space, field energy, species counts) with a common
    `update(step, vars)/result()` protocol,
  * `repro.insitu.runner` — the same reducers run live over an `SstStream`
    or post-hoc over a `BpReader`, with an exact-parity guarantee,
  * `repro.tools.jbpls` — bpls-style metadata-only series inspection built
    on the `BpReader` query layer.
"""
from repro.insitu.reducers import (FieldEnergy, Histogram, Moments,
                                   PhaseSpace2D, Reducer, ReducerSet,
                                   SpeciesCount)
from repro.insitu.runner import (assert_parity, attach_reducers,
                                 reduce_posthoc)

__all__ = [
    "Reducer", "ReducerSet", "Moments", "Histogram", "PhaseSpace2D",
    "FieldEnergy", "SpeciesCount", "attach_reducers", "reduce_posthoc",
    "assert_parity",
]
