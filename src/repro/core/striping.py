"""Lustre-style file striping over emulated OSTs.

A logical file is split into `stripe_size` stripes distributed round-robin
(raid0 pattern) over `stripe_count` object storage targets. OSTs are
emulated as object files in per-OST directories — the layout math, the
alignment behaviour, and the count x size performance tradeoff (paper Fig 9)
all reproduce structurally; a `getstripe()` introspection mirrors
`lfs getstripe` (paper Listing 1).
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import threading
from typing import Optional

from repro.core.darshan import MONITOR, open_file


@dataclasses.dataclass(frozen=True)
class StripeConfig:
    stripe_count: int = 1
    stripe_size: int = 1 * 1024 * 1024          # bytes
    pattern: str = "raid0"

    def ost_of(self, stripe_idx: int) -> int:
        return stripe_idx % self.stripe_count

    def object_offset(self, stripe_idx: int) -> int:
        return (stripe_idx // self.stripe_count) * self.stripe_size


class OstPool:
    """A set of emulated OSTs rooted under `root/ost<k>/`."""

    def __init__(self, root, n_osts: int, *, slow_osts: Optional[dict] = None):
        self.root = pathlib.Path(root)
        self.n_osts = n_osts
        self.slow_osts = slow_osts or {}        # ost_id -> extra seconds/write
        for k in range(n_osts):
            (self.root / f"ost{k}").mkdir(parents=True, exist_ok=True)

    def object_path(self, ost: int, obj_name: str) -> pathlib.Path:
        return self.root / f"ost{ost}" / obj_name


class StripedFile:
    """Write/read a logical byte stream striped across an OstPool."""

    def __init__(self, pool: OstPool, name: str, cfg: StripeConfig,
                 rank: int = 0, mode: str = "w"):
        assert cfg.stripe_count <= pool.n_osts, (cfg.stripe_count, pool.n_osts)
        self.pool = pool
        self.name = name
        self.cfg = cfg
        self.rank = rank
        self._lock = threading.Lock()
        self.logical_size = 0
        self._handles = {}
        self._mode = mode
        if mode == "w":
            for k in range(cfg.stripe_count):
                p = pool.object_path(k, f"{name}.obj")
                self._handles[k] = open_file(p, "wb", rank=rank)

    # ----------------------------------------------------------------- write
    def write(self, data: bytes, offset: Optional[int] = None) -> int:
        """Stripe-split `data` at logical `offset` (default: append)."""
        import time as _time
        with self._lock:
            off = self.logical_size if offset is None else offset
            ss = self.cfg.stripe_size
            pos = 0
            while pos < len(data):
                stripe_idx = (off + pos) // ss
                intra = (off + pos) % ss
                take = min(ss - intra, len(data) - pos)
                ost = self.cfg.ost_of(stripe_idx)
                h = self._handles[ost]
                h.seek(self.cfg.object_offset(stripe_idx) + intra)
                slow = self.pool.slow_osts.get(ost, 0.0)
                if slow:
                    _time.sleep(slow)            # straggler-OST simulation
                h.write(data[pos:pos + take])
                pos += take
            self.logical_size = max(self.logical_size, off + len(data))
            return len(data)

    def fsync(self):
        for h in self._handles.values():
            h.fsync()

    def close(self):
        for h in self._handles.values():
            h.close()
        self._handles.clear()

    # ------------------------------------------------------------------ read
    def read(self, offset: int, length: int) -> bytes:
        ss = self.cfg.stripe_size
        out = bytearray()
        pos = 0
        while pos < length:
            stripe_idx = (offset + pos) // ss
            intra = (offset + pos) % ss
            take = min(ss - intra, length - pos)
            ost = self.cfg.ost_of(stripe_idx)
            p = self.pool.object_path(ost, f"{self.name}.obj")
            with open_file(p, "rb", rank=self.rank) as h:
                h.seek(self.cfg.object_offset(stripe_idx) + intra)
                out += h.read(take)
            pos += take
        return bytes(out)

    # ------------------------------------------------------------- introspect
    def getstripe(self) -> dict:
        """`lfs getstripe` analogue (paper Listing 1)."""
        objs = []
        for k in range(self.cfg.stripe_count):
            p = self.pool.object_path(k, f"{self.name}.obj")
            objs.append({"obdidx": k, "objid": f"{abs(hash(str(p))) & 0xffffffff:#x}",
                         "path": str(p),
                         "size": p.stat().st_size if p.exists() else 0})
        return {"lmm_stripe_count": self.cfg.stripe_count,
                "lmm_stripe_size": self.cfg.stripe_size,
                "lmm_pattern": self.cfg.pattern,
                "lmm_layout_gen": 0,
                "lmm_stripe_offset": 0,
                "objects": objs,
                "logical_size": self.logical_size}
