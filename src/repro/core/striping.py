"""Lustre-style file striping over emulated OSTs.

A logical file is split into `stripe_size` stripes distributed round-robin
(raid0 pattern) over `stripe_count` object storage targets. OSTs are
emulated as object files in per-OST directories — the layout math, the
alignment behaviour, and the count x size performance tradeoff (paper Fig 9)
all reproduce structurally; a `getstripe()` introspection mirrors
`lfs getstripe` (paper Listing 1).

`StripedFile.write` flushes the per-OST segments of one logical write IN
PARALLEL (one flusher per OST touched — for large writes and whenever a
slow OST is involved; small all-fast writes stay inline), so a straggler
OST costs max(ost latencies), not their sum — the striping analogue of
the work-stealing aggregator pool. `mode="r"` opens an existing striped layout for reading
with cached per-OST handles (no re-open per segment) and a `logical_size`
recovered from the object files, so `getstripe()` works on readers too.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import threading
import time as _time
from typing import Optional

from repro.core.darshan import MONITOR, open_file

# Below this size a multi-OST write is flushed inline: the segments are
# page-cache memcpys, so per-call thread create/join would cost more than
# the overlap buys. Slow (straggler) OSTs always take the parallel path.
PARALLEL_FLUSH_MIN_BYTES = 4 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class StripeConfig:
    stripe_count: int = 1
    stripe_size: int = 1 * 1024 * 1024          # bytes
    pattern: str = "raid0"

    def ost_of(self, stripe_idx: int) -> int:
        return stripe_idx % self.stripe_count

    def object_offset(self, stripe_idx: int) -> int:
        return (stripe_idx // self.stripe_count) * self.stripe_size


class OstPool:
    """A set of emulated OSTs rooted under `root/ost<k>/`."""

    def __init__(self, root, n_osts: int, *, slow_osts: Optional[dict] = None):
        self.root = pathlib.Path(root)
        self.n_osts = n_osts
        self.slow_osts = slow_osts or {}        # ost_id -> extra seconds/write
        for k in range(n_osts):
            (self.root / f"ost{k}").mkdir(parents=True, exist_ok=True)

    def object_path(self, ost: int, obj_name: str) -> pathlib.Path:
        return self.root / f"ost{ost}" / obj_name


def logical_size_of(pool: OstPool, name: str, cfg: StripeConfig) -> int:
    """Logical byte length of a striped layout recovered from the on-disk
    object sizes alone (stat-only — no object file is opened). raid0: the
    exact value is the max over OSTs of the logical span its object
    extends to. Shared by read-mode `StripedFile` and `jbpfsck`'s
    O(metadata) extent checks."""
    size = 0
    for k in range(cfg.stripe_count):
        p = pool.object_path(k, f"{name}.obj")
        if not p.exists():
            continue
        osz = p.stat().st_size
        if osz == 0:
            continue
        full, tail = divmod(osz, cfg.stripe_size)
        last = full - (0 if tail else 1)           # last stripe idx on k
        span = ((last * cfg.stripe_count + k) * cfg.stripe_size +
                (tail or cfg.stripe_size))
        size = max(size, span)
    return size


class StripedFile:
    """Write/read a logical byte stream striped across an OstPool.

    mode="w": creates/truncates the object files and accepts write()s.
    mode="r": opens an EXISTING striped layout — object files are never
    created or truncated, `logical_size` is recovered from their on-disk
    sizes, and read() reuses cached per-OST handles instead of re-opening
    an object file per segment.
    """

    def __init__(self, pool: OstPool, name: str, cfg: StripeConfig,
                 rank: int = 0, mode: str = "w"):
        if cfg.stripe_count > pool.n_osts:
            raise ValueError(
                f"stripe_count={cfg.stripe_count} exceeds the pool's "
                f"{pool.n_osts} OST(s) — a layout cannot stripe wider than "
                f"the targets that exist")
        if mode not in ("w", "r"):
            raise ValueError(f"mode must be 'w' or 'r', got {mode!r}")
        self.pool = pool
        self.name = name
        self.cfg = cfg
        self.rank = rank
        self._lock = threading.Lock()
        self.logical_size = 0
        self._handles = {}                      # ost -> write handle
        self._rhandles = {}                     # ost -> cached read handle
        self._mode = mode
        if mode == "w":
            for k in range(cfg.stripe_count):
                p = pool.object_path(k, f"{name}.obj")
                self._handles[k] = open_file(p, "wb", rank=rank)
        else:
            self.logical_size = logical_size_of(pool, name, cfg)

    # ----------------------------------------------------------------- write
    def write(self, data: bytes, offset: Optional[int] = None) -> int:
        """Stripe-split `data` at logical `offset` (default: append).

        The split is planned first, then the per-OST segment lists are
        flushed CONCURRENTLY (one flusher thread per OST touched, inline
        when only one OST is involved) — a slow OST no longer serialises
        the whole logical write behind it."""
        if self._mode != "w":
            raise ValueError(f"{self.name} is not open for writing")
        with self._lock:
            off = self.logical_size if offset is None else offset
            ss = self.cfg.stripe_size
            mv = memoryview(data)
            plans: dict[int, list] = {}        # ost -> [(obj_off, segment)]
            pos = 0
            while pos < len(data):
                stripe_idx = (off + pos) // ss
                intra = (off + pos) % ss
                take = min(ss - intra, len(data) - pos)
                ost = self.cfg.ost_of(stripe_idx)
                plans.setdefault(ost, []).append(
                    (self.cfg.object_offset(stripe_idx) + intra,
                     mv[pos:pos + take]))
                pos += take

            def flush_ost(ost, segments):
                h = self._handles[ost]
                slow = self.pool.slow_osts.get(ost, 0.0)
                for obj_off, seg in segments:
                    h.seek(obj_off)
                    if slow:
                        _time.sleep(slow)        # straggler-OST simulation
                    h.write(seg)

            items = sorted(plans.items())
            use_threads = len(items) > 1 and (
                len(data) >= PARALLEL_FLUSH_MIN_BYTES
                or any(self.pool.slow_osts.get(ost, 0.0) for ost, _ in items))
            if not use_threads:
                for ost, segments in items:
                    flush_ost(ost, segments)
            else:
                errors: list[BaseException] = []

                def runner(ost, segments):
                    try:
                        flush_ost(ost, segments)
                    except BaseException as e:   # noqa: BLE001
                        errors.append(e)

                threads = [threading.Thread(target=runner, args=it,
                                            name=f"jbp-ost-{it[0]}",
                                            daemon=True)
                           for it in items]
                for t in threads:
                    t.start()
                for t in threads:
                    # joining under the lock IS the contract: write()
                    # returns only after every OST flush landed, and the
                    # lock orders whole writes (no interleaved stripes)
                    t.join()   # jbplint: disable=JBP004
                if errors:
                    raise errors[0]
            self.logical_size = max(self.logical_size, off + len(data))
            return len(data)

    def flush(self):
        for h in self._handles.values():
            h.flush()

    def fsync(self):
        for h in self._handles.values():
            h.fsync()

    def close(self):
        for h in self._handles.values():
            h.close()
        self._handles.clear()
        for h in self._rhandles.values():
            h.close()
        self._rhandles.clear()

    # ------------------------------------------------------------------ read
    def _read_handle(self, ost: int):
        h = self._rhandles.get(ost)
        if h is None:
            p = self.pool.object_path(ost, f"{self.name}.obj")
            h = open_file(p, "rb", rank=self.rank)
            self._rhandles[ost] = h
        return h

    def read(self, offset: int, length: int) -> bytes:
        ss = self.cfg.stripe_size
        out = bytearray()
        pos = 0
        with self._lock:
            while pos < length:
                stripe_idx = (offset + pos) // ss
                intra = (offset + pos) % ss
                take = min(ss - intra, length - pos)
                h = self._read_handle(self.cfg.ost_of(stripe_idx))
                h.seek(self.cfg.object_offset(stripe_idx) + intra)
                out += h.read(take)
                pos += take
        return bytes(out)

    # ------------------------------------------------------------- introspect
    def getstripe(self) -> dict:
        """`lfs getstripe` analogue (paper Listing 1)."""
        objs = []
        for k in range(self.cfg.stripe_count):
            p = self.pool.object_path(k, f"{self.name}.obj")
            objs.append({"obdidx": k, "objid": f"{abs(hash(str(p))) & 0xffffffff:#x}",
                         "path": str(p),
                         "size": p.stat().st_size if p.exists() else 0})
        return {"lmm_stripe_count": self.cfg.stripe_count,
                "lmm_stripe_size": self.cfg.stripe_size,
                "lmm_pattern": self.cfg.pattern,
                "lmm_layout_gen": 0,
                "lmm_stripe_offset": 0,
                "objects": objs,
                "logical_size": self.logical_size}
