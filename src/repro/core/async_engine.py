"""Async double-buffered write pipeline for the JBP engine (paper §V).

The paper's throughput story is that I/O must become a *background
activity*: the PIC cycle keeps pushing/depositing while the previous step's
diagnostics are still being compressed, aggregated and appended. The sync
`BpWriter` stalls the producer for the whole of `end_step()`;
`AsyncBpWriter` splits the step into

    producer thread                      writer thread
    ---------------                      -------------
    put() ... put()
    end_step(blocking=False)
      -> _take_snapshot(copy=True)
      -> bounded in-flight queue  ---->  _write_step(snapshot):
    (compute next step overlaps)           compress -> aggregator assignment
                                           -> subfile appends -> md.0 append
                                           -> crc-sealed md.idx record

Snapshots are deep copies, so the producer may reuse its buffers the moment
`end_step` returns (the relaxation of the openPMD "unmodified until flush"
contract that makes overlap possible). The queue is bounded
(`queue_depth`, default 2): when the writer falls behind, `end_step`
BLOCKS, so at most `queue_depth` snapshots sit queued plus one being
written — the producer never runs more than `queue_depth + 1` steps ahead
of storage, which bounds peak host memory at `queue_depth + 1` step
payloads (back-pressure, like SST's reliable mode).

Ordering + durability: a single dedicated writer thread pops snapshots
FIFO, so md.0/md.idx grow in submission order and the on-disk layout is
byte-identical to a sync write of the same puts (data.* and md.0 exactly;
md.idx differs only in its wall-clock timestamp field). A step is durable
iff its crc-sealed md.idx record validates — unchanged from BpWriter.
`fsync_policy="step"` implies a BLOCKING seal: `end_step` waits until the
background fsync of md.0+md.idx has completed, so checkpoint writers keep
their crash-consistency guarantee.

The bounded-queue/drain core lives in `_PipelinedCommitter` so the
composed parallel plane (`ParallelBpWriter(async_commit=True)`) reuses the
exact same discipline in front of its two-phase commit: one committer
thread, FIFO seals, drop-after-failure, `drain()` barrier, error latching
surfaced at the next producer call.

`profiling.json` gains per-step `backlog` / `queue_wait_s` /
`queue_delay_s` fields and an `"async"` summary with the compute-overlap
fraction (what share of write time the producer did NOT spend blocked).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional

from repro.core.bp_engine import BpWriter, EngineConfig, StepSnapshot
from repro.core.dxt import TRACER


class _PipelinedCommitter:
    """Bounded snapshot queue + one committer thread — the async pipeline's
    core, engine-agnostic: `commit_fn(snapshot) -> profile` is the only
    contract (BpWriter._write_step for the thread engine, the two-phase
    ParallelBpWriter._commit_step for the process plane).

    Discipline shared by every user:
      * FIFO: one thread pops, so steps seal in submission order;
      * back-pressure: `submit` blocks once `queue_depth` snapshots queue;
      * drop-after-failure: once a step failed, later queued snapshots are
        discarded, never sealed — a gapped series must not look durable;
      * error latching: the first failure is re-raised (fresh exception,
        chained via __cause__) at the next submit/drain/check.
    """

    def __init__(self, commit_fn: Callable[[StepSnapshot], dict], *,
                 queue_depth: int = 2, name: str = "jbp-async-seal"):
        self.queue_depth = max(1, int(queue_depth))
        self._commit_fn = commit_fn
        self._q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        self._error: Optional[BaseException] = None
        self._stats_lock = threading.Lock()
        self._blocked_s = 0.0      # producer time lost to back-pressure/seals
        self._stopped = False
        self._halt = False         # interrupt path: stop committing NOW
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    # -------------------------------------------------------------- producer
    def submit(self, snap: StepSnapshot, *, blocking: bool) -> dict:
        """Enqueue one snapshot; blocks on back-pressure, and (with
        `blocking`) until the step's seal completed — then returns the real
        profile. Non-blocking returns a {"queued": True} placeholder."""
        # error check AFTER the caller snapshotted: like the sync writer, a
        # failing end_step discards the step and leaves the engine ready
        # for begin_step — it must not wedge the producer protocol
        self.check_error()
        snap.extra["backlog"] = self._q.qsize()
        snap.extra["t_submit"] = time.perf_counter()
        sealed = threading.Event()
        holder: dict = {}
        t0 = time.perf_counter()
        self._q.put((snap, sealed, holder))    # blocks when queue_depth deep
        queue_wait = time.perf_counter() - t0
        if blocking:
            sealed.wait()
        blocked = (time.perf_counter() - t0) if blocking else queue_wait
        with self._stats_lock:
            self._blocked_s += blocked
        if blocking:
            self.check_error()
            return holder["prof"]
        return {"step": snap.step, "queued": True,
                "backlog": snap.extra["backlog"], "queue_wait_s": queue_wait}

    def drain(self):
        """Barrier: returns once every submitted step is committed (per the
        owning engine's fsync policy); raises a latched failure."""
        t0 = time.perf_counter()
        self._q.join()
        with self._stats_lock:
            self._blocked_s += time.perf_counter() - t0
        self.check_error()

    def shutdown(self):
        """Drain WITHOUT raising, then stop the committer thread — the
        engine's close() calls this first so teardown always completes;
        it checks the latched error itself once handles are released.
        The stop half runs even when the drain is INTERRUPTED
        (KeyboardInterrupt escaping the queue join): the owning engine is
        about to close the md handles, so the thread must be dead — or at
        least halted — before that, never left sealing underneath them."""
        if self._stopped:
            return
        t0 = time.perf_counter()
        try:
            self._q.join()         # like drain(), but never raises early
        finally:
            with self._stats_lock:
                self._blocked_s += time.perf_counter() - t0
            self._stopped = True
            self._halt = True      # belt for the interrupted-drain path
            try:
                self._q.put_nowait(None)   # empty after a clean join
            except queue.Full:
                pass               # interrupted: _halt is the wake-up
            self._thread.join(timeout=10.0)

    @property
    def blocked_s(self) -> float:
        with self._stats_lock:
            return self._blocked_s

    # --------------------------------------------------------------- thread
    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            snap, sealed, holder = item
            try:
                # after a failed step, later queued snapshots are DROPPED,
                # not written: sealing step N+1 when step N is missing would
                # present a gapped series as durable — a sync writer raises
                # at N and never reaches N+1, and async must match. A halted
                # committer (interrupted shutdown) drops for the same reason.
                if self._error is None and not self._halt:
                    snap.extra["queue_delay_s"] = (time.perf_counter() -
                                                   snap.extra.pop("t_submit"))
                    with TRACER.span("pipeline", path=f"step.{snap.step}"):
                        holder["prof"] = self._commit_fn(snap)
            except BaseException as e:     # noqa: BLE001 — surfaced to producer
                self._error = e            # first failure is the root cause
            finally:
                sealed.set()
                self._q.task_done()
                if self._halt:
                    return         # owner is tearing the engine down NOW

    def check_error(self):
        """Surface a background commit failure to the producer. Each call
        raises a FRESH exception chained to the original via __cause__ —
        re-raising the stored object itself would accrete a new traceback
        per call site (end_step, drain, close all check) and misreport
        where the failure happened."""
        err = self._error
        if err is None:
            return
        try:
            fresh = type(err)(*err.args)
        except Exception:                      # noqa: BLE001 — odd signature
            fresh = RuntimeError(f"async writer failed: {err!r}")
        raise fresh from err

    def stats_doc(self) -> dict:
        """The profiling.json "async" block, minus the engine-side totals."""
        return {"queue_depth": self.queue_depth,
                "producer_blocked_s": self.blocked_s}

    def profile_block(self, profile_steps) -> dict:
        """The full profiling.json "async" block for an engine whose
        per-step profiles are `profile_steps` — overlap accounting lives
        HERE so both engines report the same formula (overlap = share of
        commit time the producer did NOT spend blocked)."""
        write_s = sum(p.get("write_s", 0.0) for p in profile_steps)
        blocked = self.blocked_s
        overlap = max(0.0, 1.0 - blocked / write_s) if write_s > 0 else 0.0
        return dict(self.stats_doc(), write_s=write_s,
                    overlap_fraction=overlap)


class AsyncBpWriter(BpWriter):
    """Drop-in BpWriter with a background write pipeline.

    end_step(blocking=False) -> snapshot + enqueue, returns a placeholder
                                profile ({"queued": True, ...}).
    end_step(blocking=True)  -> waits for the step's seal; returns the real
                                profile (forced when fsync_policy="step").
    drain()                  -> barrier: every queued step sealed on disk.
    close()                  -> drain, stop the writer thread, then the
                                normal BpWriter close (fsync + profiling).
    """

    def __init__(self, path, n_ranks: int, cfg: EngineConfig = EngineConfig(),
                 *, queue_depth: int = 2):
        super().__init__(path, n_ranks, cfg)
        self._committer = _PipelinedCommitter(self._write_step,
                                              queue_depth=queue_depth)
        self.queue_depth = self._committer.queue_depth
        self._closed = False

    # -------------------------------------------------------------- producer
    def end_step(self, blocking: bool = False) -> dict:
        if self.cfg.fsync_policy == "step":
            blocking = True            # durable seal must precede the return
        # a blocking end_step holds the producer until the write completes,
        # so the chunk views stay valid — skip the deep copy (checkpoints
        # of model-sized state must not double peak host memory)
        snap = self._take_snapshot(copy=not blocking)
        return self._committer.submit(snap, blocking=blocking)

    def drain(self):
        """Barrier: returns once every submitted step is written AND sealed
        (its md.idx record on disk per the engine's fsync policy)."""
        self._committer.drain()

    def close(self):
        """Drain, stop the writer thread, then the normal BpWriter close.
        A failed background write must NOT leak the thread or the md.0/
        md.idx handles: shutdown always completes, the error is raised
        once at the end (subsequent close() calls are no-ops)."""
        if self._closed:
            return
        try:
            self._committer.shutdown()
        finally:
            self._closed = True
            super().close()
        self._committer.check_error()

    # ------------------------------------------------- committer pass-throughs
    @property
    def _writer_error(self) -> Optional[BaseException]:
        return self._committer._error

    @property
    def _writer_thread(self) -> threading.Thread:
        return self._committer._thread

    def _check_error(self):
        self._committer.check_error()

    # -------------------------------------------------------------- profiling
    def _profile_doc(self) -> dict:
        doc = super()._profile_doc()
        doc["async"] = self._committer.profile_block(self._profile)
        return doc

    def overlap_stats(self) -> dict:
        """Live view of the compute/I-O overlap accounting."""
        return dict(self._profile_doc()["async"], steps=len(self._profile))
