"""Async double-buffered write pipeline for the JBP engine (paper §V).

The paper's throughput story is that I/O must become a *background
activity*: the PIC cycle keeps pushing/depositing while the previous step's
diagnostics are still being compressed, aggregated and appended. The sync
`BpWriter` stalls the producer for the whole of `end_step()`;
`AsyncBpWriter` splits the step into

    producer thread                      writer thread
    ---------------                      -------------
    put() ... put()
    end_step(blocking=False)
      -> _take_snapshot(copy=True)
      -> bounded in-flight queue  ---->  _write_step(snapshot):
    (compute next step overlaps)           compress -> aggregator assignment
                                           -> subfile appends -> md.0 append
                                           -> crc-sealed md.idx record

Snapshots are deep copies, so the producer may reuse its buffers the moment
`end_step` returns (the relaxation of the openPMD "unmodified until flush"
contract that makes overlap possible). The queue is bounded
(`queue_depth`, default 2): when the writer falls behind, `end_step`
BLOCKS, so at most `queue_depth` snapshots sit queued plus one being
written — the producer never runs more than `queue_depth + 1` steps ahead
of storage, which bounds peak host memory at `queue_depth + 1` step
payloads (back-pressure, like SST's reliable mode).

Ordering + durability: a single dedicated writer thread pops snapshots
FIFO, so md.0/md.idx grow in submission order and the on-disk layout is
byte-identical to a sync write of the same puts (data.* and md.0 exactly;
md.idx differs only in its wall-clock timestamp field). A step is durable
iff its crc-sealed md.idx record validates — unchanged from BpWriter.
`fsync_policy="step"` implies a BLOCKING seal: `end_step` waits until the
background fsync of md.0+md.idx has completed, so checkpoint writers keep
their crash-consistency guarantee.

`profiling.json` gains per-step `backlog` / `queue_wait_s` /
`queue_delay_s` fields and an `"async"` summary with the compute-overlap
fraction (what share of write time the producer did NOT spend blocked).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Optional

from repro.core.bp_engine import BpWriter, EngineConfig


class AsyncBpWriter(BpWriter):
    """Drop-in BpWriter with a background write pipeline.

    end_step(blocking=False) -> snapshot + enqueue, returns a placeholder
                                profile ({"queued": True, ...}).
    end_step(blocking=True)  -> waits for the step's seal; returns the real
                                profile (forced when fsync_policy="step").
    drain()                  -> barrier: every queued step sealed on disk.
    close()                  -> drain, stop the writer thread, then the
                                normal BpWriter close (fsync + profiling).
    """

    def __init__(self, path, n_ranks: int, cfg: EngineConfig = EngineConfig(),
                 *, queue_depth: int = 2):
        super().__init__(path, n_ranks, cfg)
        self.queue_depth = max(1, int(queue_depth))
        self._q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        self._writer_error: Optional[BaseException] = None
        self._stats_lock = threading.Lock()
        self._blocked_s = 0.0          # producer time lost to back-pressure/seals
        self._closed = False
        self._writer_thread = threading.Thread(
            target=self._writer_loop, name="jbp-async-seal", daemon=True)
        self._writer_thread.start()

    # -------------------------------------------------------------- producer
    def end_step(self, blocking: bool = False) -> dict:
        if self.cfg.fsync_policy == "step":
            blocking = True            # durable seal must precede the return
        # a blocking end_step holds the producer until the write completes,
        # so the chunk views stay valid — skip the deep copy (checkpoints
        # of model-sized state must not double peak host memory)
        snap = self._take_snapshot(copy=not blocking)
        # snapshot FIRST, error check second: like the sync writer, a
        # failing end_step discards the step and leaves the engine ready
        # for begin_step — it must not wedge the producer protocol
        self._check_error()
        snap.extra["backlog"] = self._q.qsize()
        snap.extra["t_submit"] = time.perf_counter()
        sealed = threading.Event()
        holder: dict = {}
        t0 = time.perf_counter()
        self._q.put((snap, sealed, holder))    # blocks when queue_depth deep
        queue_wait = time.perf_counter() - t0
        if blocking:
            sealed.wait()
        blocked = (time.perf_counter() - t0) if blocking else queue_wait
        with self._stats_lock:
            self._blocked_s += blocked
        if blocking:
            self._check_error()
            return holder["prof"]
        return {"step": snap.step, "queued": True,
                "backlog": snap.extra["backlog"], "queue_wait_s": queue_wait}

    def drain(self):
        """Barrier: returns once every submitted step is written AND sealed
        (its md.idx record on disk per the engine's fsync policy)."""
        t0 = time.perf_counter()
        self._q.join()
        with self._stats_lock:
            self._blocked_s += time.perf_counter() - t0
        self._check_error()

    def close(self):
        """Drain, stop the writer thread, then the normal BpWriter close.
        A failed background write must NOT leak the thread or the md.0/
        md.idx handles: shutdown always completes, the error is raised
        once at the end (subsequent close() calls are no-ops)."""
        if self._closed:
            return
        try:
            t0 = time.perf_counter()
            self._q.join()             # like drain(), but never raises early
            with self._stats_lock:
                self._blocked_s += time.perf_counter() - t0
        finally:
            self._closed = True
            self._q.put(None)          # queue empty post-join: never blocks
            self._writer_thread.join(timeout=10.0)
            super().close()
        self._check_error()

    # ---------------------------------------------------------------- writer
    def _writer_loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            snap, sealed, holder = item
            try:
                # after a failed step, later queued snapshots are DROPPED,
                # not written: sealing step N+1 when step N is missing would
                # present a gapped series as durable — a sync writer raises
                # at N and never reaches N+1, and async must match
                if self._writer_error is None:
                    snap.extra["queue_delay_s"] = (time.perf_counter() -
                                                   snap.extra.pop("t_submit"))
                    holder["prof"] = self._write_step(snap)
            except BaseException as e:     # noqa: BLE001 — surfaced to producer
                self._writer_error = e     # first failure is the root cause
            finally:
                sealed.set()
                self._q.task_done()

    def _check_error(self):
        """Surface a background write failure to the producer. Each call
        raises a FRESH exception chained to the original via __cause__ —
        re-raising the stored object itself would accrete a new traceback
        per call site (end_step, drain, close all check) and misreport
        where the failure happened."""
        err = self._writer_error
        if err is None:
            return
        try:
            fresh = type(err)(*err.args)
        except Exception:                      # noqa: BLE001 — odd signature
            fresh = RuntimeError(f"async writer failed: {err!r}")
        raise fresh from err

    # -------------------------------------------------------------- profiling
    def _profile_doc(self) -> dict:
        doc = super()._profile_doc()
        write_s = sum(p.get("write_s", 0.0) for p in self._profile)
        with self._stats_lock:
            blocked = self._blocked_s
        overlap = max(0.0, 1.0 - blocked / write_s) if write_s > 0 else 0.0
        doc["async"] = {"queue_depth": self.queue_depth,
                        "producer_blocked_s": blocked,
                        "write_s": write_s,
                        "overlap_fraction": overlap}
        return doc

    def overlap_stats(self) -> dict:
        """Live view of the compute/I-O overlap accounting."""
        return dict(self._profile_doc()["async"], steps=len(self._profile))
