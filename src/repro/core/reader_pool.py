"""ReaderPool — the read-side mirror of the work-stealing writer pool.

`BpReader.read_var` assembles a box selection chunk by chunk: payload read,
decompress, scatter into the output array. Serially that is bounded by one
core even though (a) the payload reads hit M independent subfiles and (b)
zlib/bz2 release the GIL while decompressing. The pool fans the per-chunk
work out over worker threads with PER-AGGREGATOR AFFINITY:

  * `submit(affinity, fn, *args)` routes a task to worker `affinity % N`,
    so one subfile's chunks land on one worker — its cached file handle is
    reused and the reads stay sequential within the subfile (the access
    pattern aggregation exists to create is preserved on the read side),
  * an idle worker STEALS from the longest other queue (back-of-deque, the
    opposite end from the owner), so a straggler aggregator — a big
    compressed chunk, a slow OST behind a striped subfile — is absorbed by
    the rest of the pool exactly like the writer pool absorbs slow
    aggregators,
  * a failing task never kills its worker: the first error is recorded and
    re-raised from the barrier (the WriterPool lesson, applied to reads).

One pool may serve CONCURRENT read_var calls (restore_sharded fetch
callbacks run on several threads): each call submits its tasks under a
`ReadBatch`, and `drain_batch` waits on — and raises errors of — that
batch alone, so one caller's failed chunk can never surface in another
caller's read (or worse, vanish while the victim returns zero-filled
data). The pool also GROWS in place (`ensure`) instead of being torn down
and recreated, so a caller holding a reference mid-read never races a
shutdown.

Handle affinity is the reader's side of the contract: `BpReader` keeps one
payload handle per (worker thread, aggregator), so no lock is ever taken
around seek+read — affinity makes the common case one handle per subfile,
and stealing at worst opens one extra handle on the stealing thread.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Optional

from repro.core.dxt import TRACER
from repro.core.metrics import METRICS


class ReadBatch:
    """Completion tracker for one caller's group of tasks: its own
    outstanding count and its own first-error slot."""

    def __init__(self):
        self.outstanding = 0
        self.error: Optional[BaseException] = None


class ReaderPool:
    """Affinity-scheduled, work-stealing thread pool for chunk reads."""

    def __init__(self, n_workers: int):
        self._cond = threading.Condition()
        self._queues: list[deque] = []
        self._outstanding = 0                 # submitted, not yet finished
        self._stop = False
        self._error: Optional[BaseException] = None   # batch-less tasks
        self._threads: list[threading.Thread] = []
        # worker wake-ups since construction. Waits are purely
        # notification-driven (submit/finish/stop notify; NO wait timeout),
        # so an idle pool must show ZERO wakeups — a daemon hosting a
        # resident pool sits at 0% CPU between requests. The counter is the
        # observable that keeps it that way (tests assert on it).
        self.wakeups = 0
        self.ensure(max(1, int(n_workers)))

    @property
    def n_workers(self) -> int:
        return len(self._threads)

    def ensure(self, n_workers: int):
        """Grow the pool to at least `n_workers` threads, in place — never
        torn down and recreated, so concurrent callers holding a reference
        cannot race a shutdown."""
        with self._cond:
            if self._stop:
                raise RuntimeError("ReaderPool is shut down")
            while len(self._threads) < n_workers:
                i = len(self._threads)
                self._queues.append(deque())
                t = threading.Thread(target=self._worker, args=(i,),
                                     name=f"jbp-reader-{i}", daemon=True)
                self._threads.append(t)
                t.start()

    # ------------------------------------------------------------- scheduling
    def batch(self) -> ReadBatch:
        return ReadBatch()

    def submit(self, affinity: int, fn: Callable, *args,
               batch: Optional[ReadBatch] = None):
        """Queue one task on the worker owning `affinity` (e.g. the chunk's
        aggregator id) — same affinity, same worker, same cached handle.
        With `batch`, completion and errors are tracked per batch."""
        with self._cond:
            if self._stop:
                raise RuntimeError("ReaderPool is shut down")
            self._queues[affinity % len(self._queues)].append(
                (fn, args, batch))
            self._outstanding += 1
            if batch is not None:
                batch.outstanding += 1
            self._cond.notify_all()

    def _take(self, i: int):
        """Own queue first (front); else steal the tail of the longest other
        queue — stolen work is the work least likely to be reached soon by
        its owner."""
        q = self._queues[i]
        if q:
            return q.popleft()
        victim = max((v for v in self._queues if v), key=len, default=None)
        if victim is not None:
            return victim.pop()
        return None

    def _worker(self, i: int):
        while True:
            with self._cond:
                task = self._take(i)
                while task is None and not self._stop:
                    self._cond.wait()         # notification-driven: no spin
                    self.wakeups += 1
                    task = self._take(i)
                if task is None:              # stopped and drained
                    return
            fn, args, batch = task
            try:
                with TRACER.span("read_task", rank=i), \
                        METRICS.timer("read_task", key=f"w{i}"):
                    fn(*args)
            except BaseException as e:        # noqa: BLE001 — raised at barrier
                with self._cond:
                    if batch is not None:
                        if batch.error is None:
                            batch.error = e
                    elif self._error is None:  # first failure = root cause
                        self._error = e
            finally:
                with self._cond:
                    self._outstanding -= 1
                    if batch is not None:
                        batch.outstanding -= 1
                    self._cond.notify_all()

    # --------------------------------------------------------------- barriers
    def drain_batch(self, batch: ReadBatch):
        """Barrier for ONE caller's tasks; raises that batch's first error
        (another caller's failures are invisible here, and vice versa)."""
        with self._cond:
            while batch.outstanding:
                self._cond.wait()
            err, batch.error = batch.error, None
        if err is not None:
            raise err

    def drain(self):
        """Global barrier: every submitted task has run. Raises the first
        BATCH-LESS task error recorded since the last drain (the pool stays
        usable)."""
        with self._cond:
            while self._outstanding:
                self._cond.wait()
            err, self._error = self._error, None
        if err is not None:
            raise err

    def shutdown(self):
        try:
            self.drain()
        finally:
            with self._cond:
                self._stop = True
                self._cond.notify_all()
            for t in self._threads:
                t.join(timeout=2.0)
