"""Darshan-style I/O monitoring.

The paper (§III-D) uses Darshan's LD_PRELOAD interposition to attribute I/O
time per process to reads / writes / metadata. We own the whole I/O stack, so
instrumentation is explicit: every file op in the framework goes through
`InstrumentedFile`, and `DarshanMonitor` keeps darshan-parser-style counters
per (rank, file) — POSIX_OPENS, POSIX_WRITES, POSIX_BYTES_WRITTEN,
F_WRITE_TIME, F_META_TIME, ... plus access-size histograms and a time heatmap.

Thread-safe: aggregator writer pools hammer this concurrently.
"""
from __future__ import annotations

import difflib
import os
import threading
import time
from collections import defaultdict
from typing import Optional

from repro.core.dxt import TRACER
from repro.core.metrics import METRICS


class _FrozenCounterRegistry:
    """The single source of truth for every legal counter name. A typo'd
    literal at a call site used to silently mint a brand-new counter —
    now `record()` validates against `KNOWN_COUNTERS` at runtime, jbplint
    (JBP003) keeps call sites on these constants statically, and the
    namespace itself is frozen so nobody grows it from the outside."""

    # POSIX op/byte counters (darshan-parser names)
    POSIX_OPENS = "POSIX_OPENS"
    POSIX_READS = "POSIX_READS"
    POSIX_WRITES = "POSIX_WRITES"
    POSIX_SEEKS = "POSIX_SEEKS"
    POSIX_FLUSHES = "POSIX_FLUSHES"
    POSIX_FSYNCS = "POSIX_FSYNCS"
    POSIX_CLOSES = "POSIX_CLOSES"
    POSIX_STATS = "POSIX_STATS"
    POSIX_BYTES_READ = "POSIX_BYTES_READ"
    POSIX_BYTES_WRITTEN = "POSIX_BYTES_WRITTEN"
    # per-class time accumulators (Fig-5-style read/write/meta attribution)
    F_READ_TIME = "F_READ_TIME"
    F_WRITE_TIME = "F_WRITE_TIME"
    F_META_TIME = "F_META_TIME"
    # chunk-transport accounting for the parallel write plane: bytes that
    # moved coordinator->worker through shared-memory rings vs the pickle
    # fallback (recorded by the WORKER, shipped home on its ack and merged)
    TRANSPORT_SHM_BYTES = "TRANSPORT_SHM_BYTES"
    TRANSPORT_PICKLE_FALLBACK_BYTES = "TRANSPORT_PICKLE_FALLBACK_BYTES"
    # served-read accounting for the jbpd data service: decompressed-chunk
    # cache hits/misses, requests COALESCED onto another client's in-flight
    # fetch, and response bytes handed off zero-copy via ShmRing vs framed
    SERVICE_CACHE_HIT = "SERVICE_CACHE_HIT"
    SERVICE_CACHE_MISS = "SERVICE_CACHE_MISS"
    SERVICE_COALESCED = "SERVICE_COALESCED"
    SERVICE_SHM_BYTES = "SERVICE_SHM_BYTES"
    SERVICE_SOCKET_BYTES = "SERVICE_SOCKET_BYTES"
    # device-side compression plane (repro.core.compression device path):
    # bytes byte-shuffled on-accelerator before the host LZ stage, host-LZ
    # seconds that ran while a later block was still in the device/D2H
    # stage (the double-buffered overlap win), and raw-minus-stored bytes
    # for payloads encoded by the error-bounded lossy codec
    COMPRESS_DEVICE_BYTES = "COMPRESS_DEVICE_BYTES"
    COMPRESS_OVERLAP_TIME = "COMPRESS_OVERLAP_TIME"
    LOSSY_BYTES_SAVED = "LOSSY_BYTES_SAVED"
    # DXT trace summary fields (parser_dump / jbpd watch frames). These are
    # REPORT keys, never recorded directly, so they are excluded from
    # KNOWN_COUNTERS below.
    DXT_ENABLED = "dxt_enabled"
    DXT_EVENTS = "dxt_events"
    DXT_DROPPED = "dxt_dropped"
    DXT_OP = "dxt_op"

    def __setattr__(self, name, value):
        raise AttributeError(
            "the counter registry is frozen — add new counters in "
            "repro.core.darshan._FrozenCounterRegistry, not at call sites")


CTR = _FrozenCounterRegistry()

#: every name `record()` accepts (the recordable counter families)
KNOWN_COUNTERS = frozenset(
    v for k, v in vars(_FrozenCounterRegistry).items()
    if k.isupper() and isinstance(v, str) and not v.startswith("dxt_"))


def _unknown_counter(name) -> str:
    close = difflib.get_close_matches(str(name), sorted(KNOWN_COUNTERS), n=1)
    hint = f" — did you mean {close[0]!r}?" if close else ""
    return (f"unknown Darshan counter {name!r}; counters are frozen in "
            f"repro.core.darshan.CTR{hint}")


_COUNTER_KEYS = (
    CTR.POSIX_OPENS, CTR.POSIX_READS, CTR.POSIX_WRITES, CTR.POSIX_SEEKS,
    CTR.POSIX_FLUSHES, CTR.POSIX_FSYNCS, CTR.POSIX_CLOSES, CTR.POSIX_STATS,
    CTR.POSIX_BYTES_READ, CTR.POSIX_BYTES_WRITTEN,
)
_TIME_KEYS = (CTR.F_READ_TIME, CTR.F_WRITE_TIME, CTR.F_META_TIME)
_TRANSPORT_KEYS = (CTR.TRANSPORT_SHM_BYTES,
                   CTR.TRANSPORT_PICKLE_FALLBACK_BYTES)
_SERVICE_KEYS = (CTR.SERVICE_CACHE_HIT, CTR.SERVICE_CACHE_MISS,
                 CTR.SERVICE_COALESCED, CTR.SERVICE_SHM_BYTES,
                 CTR.SERVICE_SOCKET_BYTES)
_COMPRESS_KEYS = (CTR.COMPRESS_DEVICE_BYTES, CTR.COMPRESS_OVERLAP_TIME,
                  CTR.LOSSY_BYTES_SAVED)

_SIZE_BINS = (100, 1024, 10 * 1024, 100 * 1024, 1024**2, 4 * 1024**2,
              10 * 1024**2, 100 * 1024**2)


def _size_bin(n: int) -> str:
    lo = 0
    for hi in _SIZE_BINS:
        if n <= hi:
            return f"{lo}-{hi}"
        lo = hi
    return f">{_SIZE_BINS[-1]}"


class DarshanMonitor:
    """Global singleton registry of I/O counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self):
        with getattr(self, "_lock", threading.Lock()):
            self._t0 = time.perf_counter()
            # wall-clock instant of _t0: shipped in snapshot() so merge()
            # can rebase another process's heatmap bins onto THIS monitor's
            # time base (each process's bins are relative to its private
            # _t0 — superimposing them raw misaligns the timelines)
            self._t0_epoch = time.time()
            self._per_rank = defaultdict(lambda: defaultdict(float))
            self._per_file = defaultdict(lambda: defaultdict(float))
            self._size_hist = defaultdict(float)
            self._heatmap = defaultdict(float)      # (rank, time_bin) -> bytes
            self.heatmap_bin_s = 0.1

    # ------------------------------------------------------------------ record
    def record(self, rank: int, path: str, counter: str, inc: float = 1.0,
               tkey: Optional[str] = None, dt: float = 0.0, nbytes: int = 0):
        if counter not in KNOWN_COUNTERS:
            raise KeyError(_unknown_counter(counter))
        if tkey is not None and tkey not in KNOWN_COUNTERS:
            raise KeyError(_unknown_counter(tkey))
        with self._lock:
            r = self._per_rank[rank]
            f = self._per_file[path]
            r[counter] += inc
            f[counter] += inc
            if tkey:
                r[tkey] += dt
                f[tkey] += dt
            if nbytes:
                bkey = (CTR.POSIX_BYTES_WRITTEN if "WRITE" in counter
                        else CTR.POSIX_BYTES_READ)
                r[bkey] += nbytes
                f[bkey] += nbytes
                self._size_hist[_size_bin(nbytes)] += 1
                tbin = int((time.perf_counter() - self._t0) / self.heatmap_bin_s)
                self._heatmap[(rank, tbin)] += nbytes

    # -------------------------------------------------------- snapshot / merge
    def snapshot(self) -> dict:
        """Plain-dict (picklable) dump of every raw counter — what a writer/
        reader WORKER PROCESS ships back to the coordinator on its ack, so
        `parser_dump` in the parent covers the whole I/O plane, not just the
        coordinator's own file ops."""
        with self._lock:
            return {
                "per_rank": {r: dict(c) for r, c in self._per_rank.items()},
                "per_file": {p: dict(c) for p, c in self._per_file.items()},
                "size_hist": dict(self._size_hist),
                "heatmap": [[r, b, v] for (r, b), v in self._heatmap.items()],
                "epoch": self._t0_epoch,
                "bin_s": self.heatmap_bin_s,
            }

    def merge(self, snap: dict):
        """Fold a `snapshot()` from another process into this monitor
        (additive on every counter). Heatmap bins are REBASED via the
        snapshot's clock epoch: bin b of the source covers wall time
        `src_epoch + b*bin_s`, which lands at a different bin index on
        this monitor's axis — two monitors started at different times
        must not superimpose their timelines at bin 0."""
        if not snap:
            return
        with self._lock:
            for r, counters in snap.get("per_rank", {}).items():
                dst = self._per_rank[r]
                for k, v in counters.items():
                    dst[k] += v
            for p, counters in snap.get("per_file", {}).items():
                dst = self._per_file[p]
                for k, v in counters.items():
                    dst[k] += v
            for k, v in snap.get("size_hist", {}).items():
                self._size_hist[k] += v
            src_epoch = snap.get("epoch")
            src_bin = snap.get("bin_s", self.heatmap_bin_s)
            for r, b, v in snap.get("heatmap", []):
                if src_epoch is not None:
                    t = src_epoch + b * src_bin       # wall time of the bin
                    b = int((t - self._t0_epoch) / self.heatmap_bin_s)
                self._heatmap[(r, max(b, 0))] += v

    # ------------------------------------------------------------------ report
    def report(self, n_procs: Optional[int] = None) -> dict:
        """n_procs: logical process count to normalize by (aggregated writes
        are attributed to aggregator ids, so 'observed ranks' undercounts the
        job size — pass the real rank count for per-process numbers)."""
        with self._lock:
            ranks = sorted(self._per_rank)
            agg: dict[str, float] = defaultdict(float)
            for r in ranks:
                for k, v in self._per_rank[r].items():
                    agg[k] += v
            n = max(n_procs if n_procs else len(ranks), 1)
            per_proc = {k: agg.get(k, 0.0) / n
                        for k in (_COUNTER_KEYS + _TIME_KEYS +
                                  _TRANSPORT_KEYS + _SERVICE_KEYS +
                                  _COMPRESS_KEYS)}
            return {
                "n_ranks": len(ranks),
                "total": dict(agg),
                "avg_per_process": per_proc,
                "files": {p: dict(c) for p, c in self._per_file.items()},
                "access_size_histogram": dict(self._size_hist),
            }

    def cost_per_process(self, n_procs: Optional[int] = None) -> dict:
        """Fig-5-style: average seconds per process for reads/writes/meta."""
        rep = self.report(n_procs)["avg_per_process"]
        return {"read_s": rep["F_READ_TIME"], "write_s": rep["F_WRITE_TIME"],
                "meta_s": rep["F_META_TIME"]}

    def heatmap(self) -> dict:
        with self._lock:
            return {f"rank{r}@{b * self.heatmap_bin_s:.1f}s": v
                    for (r, b), v in sorted(self._heatmap.items())}

    def total_files_written(self) -> int:
        rep = self.report()
        return sum(1 for p, c in rep["files"].items()
                   if c.get("POSIX_BYTES_WRITTEN", 0) > 0)

    def parser_dump(self, n_procs: Optional[int] = None) -> str:
        """darshan-parser-style text report (one block per file record)."""
        rep = self.report(n_procs)
        lines = ["# darshan-style report (repro/core/darshan.py)",
                 f"# nprocs: {n_procs or rep['n_ranks']}", "#"]
        lines.append("# <counter> <value> — job totals")
        for k in (_COUNTER_KEYS + _TIME_KEYS + _TRANSPORT_KEYS
                  + _SERVICE_KEYS + _COMPRESS_KEYS):
            lines.append(f"total_{k}\t{rep['total'].get(k, 0.0):.6f}")
        lines.append("#")
        lines.append("# per-file records")
        for path, c in sorted(rep["files"].items()):
            lines.append(f"file\t{path}")
            for k in sorted(c):
                lines.append(f"\t{k}\t{c[k]:.6f}")
        lines.append("#")
        lines.append("# access size histogram")
        for k, v in sorted(rep["access_size_histogram"].items()):
            lines.append(f"hist\t{k}\t{v:.0f}")
        # DXT trace summary — per-operation tracing state (repro.core.dxt);
        # always emitted so consumers can parse the block unconditionally
        ts = TRACER.stats()
        lines.append("#")
        lines.append("# DXT trace summary (per-operation tracing)")
        lines.append(f"dxt_enabled\t{1 if ts['enabled'] else 0}")
        lines.append(f"dxt_events\t{ts['events']}")
        lines.append(f"dxt_dropped\t{ts['dropped']}")
        if ts["events"]:
            by_op: dict[str, int] = {}
            for _s, _r, _p, op, _o, _l, _t0, _t1 in TRACER.events():
                by_op[op] = by_op.get(op, 0) + 1
            for op in sorted(by_op):
                lines.append(f"dxt_op\t{op}\t{by_op[op]}")
        return "\n".join(lines)


MONITOR = DarshanMonitor()


class InstrumentedFile:
    """File handle that reports every op to the monitor — and, when DXT
    tracing is on, records one `(rank, path, op, offset, length, t0, t1)`
    event per op (offsets from the handle's own position tracking; the
    trace costs one branch per op while disabled)."""

    def __init__(self, path: str, mode: str, rank: int = 0,
                 monitor: DarshanMonitor = MONITOR):
        self.path = str(path)
        self.rank = rank
        self.mon = monitor
        t0 = time.perf_counter()
        # the one legitimate raw open(): this IS the instrumentation
        # primitive every other file op routes through
        self._f = open(self.path, mode)   # jbplint: disable=JBP002
        t1 = time.perf_counter()
        self._pos = self._f.tell()          # append modes start at EOF
        self.mon.record(rank, self.path, CTR.POSIX_OPENS, 1.0, CTR.F_META_TIME,
                        t1 - t0)
        if TRACER.enabled:
            TRACER.record(rank, self.path, "open", self._pos, 0, t0, t1)

    def write(self, data) -> int:
        t0 = time.perf_counter()
        n = self._f.write(data)
        t1 = time.perf_counter()
        nb = n if isinstance(n, int) else len(data)
        off = self._pos
        self._pos = off + nb
        self.mon.record(self.rank, self.path, CTR.POSIX_WRITES, 1.0,
                        CTR.F_WRITE_TIME, t1 - t0, nbytes=nb)
        if TRACER.enabled:
            TRACER.record(self.rank, self.path, "write", off, nb, t0, t1)
        if METRICS.enabled:
            METRICS.observe("write", t1 - t0, nbytes=nb, key=self.path)
        return nb

    def read(self, n: int = -1):
        t0 = time.perf_counter()
        data = self._f.read(n)
        t1 = time.perf_counter()
        off = self._pos
        self._pos = off + len(data)
        self.mon.record(self.rank, self.path, CTR.POSIX_READS, 1.0,
                        CTR.F_READ_TIME, t1 - t0, nbytes=len(data))
        if TRACER.enabled:
            TRACER.record(self.rank, self.path, "read", off, len(data),
                          t0, t1)
        if METRICS.enabled:
            METRICS.observe("read", t1 - t0, nbytes=len(data), key=self.path)
        return data

    def seek(self, off: int, whence: int = 0):
        t0 = time.perf_counter()
        r = self._f.seek(off, whence)
        t1 = time.perf_counter()
        self._pos = self._f.tell() if whence else off
        self.mon.record(self.rank, self.path, CTR.POSIX_SEEKS, 1.0,
                        CTR.F_META_TIME, t1 - t0)
        if TRACER.enabled:
            TRACER.record(self.rank, self.path, "seek", self._pos, 0, t0, t1)
        return r

    def tell(self) -> int:
        return self._f.tell()

    def flush(self):
        """Userspace-buffer flush (write(2) without the fsync barrier) —
        metadata time that used to be invisible to the monitor."""
        t0 = time.perf_counter()
        self._f.flush()
        t1 = time.perf_counter()
        self.mon.record(self.rank, self.path, CTR.POSIX_FLUSHES, 1.0,
                        CTR.F_META_TIME, t1 - t0)
        if TRACER.enabled:
            TRACER.record(self.rank, self.path, "flush", self._pos, 0, t0, t1)

    def fsync(self):
        t0 = time.perf_counter()
        self._f.flush()
        os.fsync(self._f.fileno())
        t1 = time.perf_counter()
        self.mon.record(self.rank, self.path, CTR.POSIX_FSYNCS, 1.0,
                        CTR.F_META_TIME, t1 - t0)
        if TRACER.enabled:
            TRACER.record(self.rank, self.path, "fsync", self._pos, 0, t0, t1)
        if METRICS.enabled:
            METRICS.observe("fsync", t1 - t0, key=self.path)

    def close(self):
        t0 = time.perf_counter()
        self._f.close()
        t1 = time.perf_counter()
        self.mon.record(self.rank, self.path, CTR.POSIX_CLOSES, 1.0,
                        CTR.F_META_TIME, t1 - t0)
        if TRACER.enabled:
            TRACER.record(self.rank, self.path, "close", self._pos, 0, t0, t1)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()


def open_file(path, mode, rank: int = 0,
              monitor: DarshanMonitor = MONITOR) -> InstrumentedFile:
    return InstrumentedFile(path, mode, rank=rank, monitor=monitor)


def merge_worker_payload(payload, monitor: DarshanMonitor = MONITOR,
                         tracer=TRACER, metrics=METRICS):
    """Merge one worker's "finished"/"closed"/ack payload into this
    process's monitor (and tracer/metrics registry). Instrumented workers
    ship `{"darshan": <monitor snapshot>, "dxt": <tracer snapshot>,
    "metrics": <registry snapshot>}` (each key optional); workers with
    tracing off (and pre-DXT peers) ship the bare monitor snapshot."""
    if not isinstance(payload, dict):
        return
    if "darshan" in payload or "dxt" in payload or "metrics" in payload:
        snap = payload.get("darshan")
        if snap:
            monitor.merge(snap)
        trace = payload.get("dxt")
        if trace:
            tracer.ingest(trace)
        hist = payload.get("metrics")
        if hist:
            metrics.merge(hist)
    else:
        monitor.merge(payload)
