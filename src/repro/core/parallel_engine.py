"""Multi-process parallel write plane for the JBP engine (paper §IV-C).

The paper's headline claim is *parallel* I/O: N ranks streaming
simultaneously into M aggregated BP4 subfiles. `BpWriter` reproduces the
format but drives every "rank" from one Python process — aggregate write
throughput is bounded by one process and one GIL. `ParallelBpWriter`
makes the write plane real:

    coordinator (rank 0)                 writer process w (of W)
    --------------------                 -----------------------
    put() routes chunks by               owns data.<w>   (SubfileSet owned={w})
    aggregator_of(rank, N, W)            owns md.<w>.shard (private metadata)
    end_step():
      phase 1  PREPARE  --- headers ---> view chunk in shm ring
               (chunk bytes go through      -> compress -> append data.<w>
               a per-worker ShmRing:        -> sealed shard record -> ack
               ONE memcpy, no pickle)    (ack doubles as the slot free-list)
               validate every sealed
               shard record (crc) read
               back from md.<w>.shard
      phase 2  COMMIT
               merge shard chunk tables
               -> md.0 record
               -> crc-sealed md.idx record

Durability is a TWO-PHASE COMMIT: a worker's sealed shard record is its
"prepared" vote; the crc-sealed md.idx record written by the coordinator
is the commit. A crash (or worker failure) anywhere before the commit
leaves shard records and payload bytes with no md.idx record — the step
is dropped by `BpReader` exactly like a torn step today, and orphaned
shard/payload bytes are dead weight, never wrong data. `md.0`/`md.idx`
are byte-compatible with the single-process writer, so the reader needs
ZERO format changes (shards are a writer-side artifact; `md.0` remains
the reader-visible merged metadata).

Chunk TRANSPORT (`transport=`): the default `"shm"` moves chunk bytes
through a per-worker `repro.core.shm_transport.ShmRing` — the
coordinator memcpys each chunk into a shared-memory slot and sends only
a small `ShmHeader` down the control queue; the worker compresses
straight from the mapped pages. Slots are freed when the step's ack
arrives (prepared OR error — the ack is the free-list), so slot contents
are stable for exactly the life of the step, and a worker dying with a
slot in flight drops the step like a torn shard, nothing more. Payloads
that cannot fit the ring (oversized, or a full ring) fall back to the
`"pickle"` path per chunk — the transport degrades, it never blocks.
`transport="pickle"` keeps the PR-3 behavior: whole ndarrays pickled
down the queue (the baseline `bench_parallel_io` sweeps against).

ASYNC COMPOSITION (`async_commit=True`): a bounded snapshot queue (the
`_PipelinedCommitter` shared with `AsyncBpWriter`) sits in FRONT of the
coordinator — `end_step()` deep-copies the step and returns immediately;
a dedicated committer thread runs the full two-phase commit in the
background. The producer sees neither compression nor commit latency;
`drain()` is the durability barrier; `fsync_policy="step"` forces a
blocking seal exactly like the async engine. This is what
`Series(parallel_io=W, async_commit=True)` wires up.

Worker processes are spawned (never forked — the parent may hold JAX/XLA
runtime threads) via `launch.distributed.spawn_io_workers`; control
messages travel down per-worker task queues, so compression + subfile
appends + shard seals run with W-way real parallelism across processes.

Shard record format (md.<w>.shard, append-only log):

    <QQI: step, blob_len, crc32(blob)> <blob: {"step", "chunks": {name: [...]}}>

`iter_shard_records` replays a shard and stops at the first torn record —
the recovery primitive for crashed writers. Note a shard may contain
sealed records for steps that were never committed (prepare succeeded,
commit did not); md.idx is always the commit truth.

Persistent plane: a `WriterPlane` spawns W workers ONCE and keeps them
idle between series; `ParallelBpWriter(..., plane=plane)` retargets them
("open") and releases them ("finish") per series, so periodic checkpoint
writes stop paying W process spawns per save (`CheckpointManager` holds
one plane for the whole run). The plane also owns the shm rings: they
stay mapped across saves and are unlinked in `shutdown()` — plus a
`weakref.finalize` so an abnormal exit leaks nothing in /dev/shm. On
"finished"/"closed" every worker ships its own Darshan
`MONITOR.snapshot()` back on the ack (including the new
`TRANSPORT_SHM_BYTES` / `TRANSPORT_PICKLE_FALLBACK_BYTES` counters) and
the coordinator merges it — `parser_dump` in the parent covers the whole
write plane.

DXT tracing (`repro.core.dxt`): when the coordinator's TRACER is enabled
the flag rides the spawn args / "open" payload, workers trace their own
compress/seal spans + per-op file events, and ship trace buffers home on
the "prepared" ack (per step) and "finished"/"closed" (remainder) next
to the counter snapshot — each snapshot carries the worker's clock epoch
so `TRACER.ingest` rebases everything onto the coordinator's wall clock.
"""
from __future__ import annotations

import json
import multiprocessing
import os
import pathlib
import queue as _queue
import struct
import threading
import time
import traceback
import weakref
import zlib
from typing import Any, Optional

import numpy as np

from repro.core import compression as C
from repro.core.aggregation import SubfileSet, aggregator_of
from repro.core.bp_engine import (ChunkMeta, EngineConfig, StepSnapshot,
                                  build_md_record, encode_chunk,
                                  record_compress_counters,
                                  seal_md_record, take_step_snapshot,
                                  validate_put_rank)
from repro.core.darshan import CTR, MONITOR, merge_worker_payload, open_file
from repro.core.dxt import TRACER
from repro.core.metrics import METRICS, StepJournal, journal_path
from repro.core.shm_transport import (DEFAULT_RING_BYTES, ShmHeader, ShmRing,
                                      unlink_rings, validate_transport)
from repro.core.striping import OstPool
from repro.launch.distributed import spawn_io_workers

SHARD_HDR = struct.Struct("<QQI")      # step, blob_len, crc32(blob)


def shard_path(path, w: int) -> pathlib.Path:
    return pathlib.Path(str(path)) / f"md.{w}.shard"


def iter_shard_records(path, w: int):
    """Replay writer `w`'s metadata shard: yield (step, record) for every
    crc-valid sealed record, stopping at the first torn/corrupt one (the
    shard is an append-only log, so a torn tail is the crash case)."""
    p = shard_path(path, w)
    if not p.exists():
        return
    with open_file(p, "rb") as f:
        raw = f.read()
    off = 0
    while off + SHARD_HDR.size <= len(raw):
        step, ln, crc = SHARD_HDR.unpack_from(raw, off)
        blob = raw[off + SHARD_HDR.size:off + SHARD_HDR.size + ln]
        if len(blob) != ln or (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
            return
        yield step, json.loads(blob)
        off += SHARD_HDR.size + ln


# --------------------------------------------------------------------- worker
def _open_worker_files(path: pathlib.Path, w: int, n_writers: int,
                       cfg: EngineConfig):
    """Open worker `w`'s subfile + metadata shard for one series."""
    ost_pool = (OstPool(path, cfg.n_osts)
                if cfg.stripe is not None else None)
    subfiles = SubfileSet(path, n_writers, stripe=cfg.stripe,
                          ost_pool=ost_pool, owned=(w,))
    shard = open_file(shard_path(path, w), "wb", rank=w)
    return subfiles, shard


def _worker_main(w: int, path_str, n_writers: int, cfg, task_q, result_q,
                 ring_name: Optional[str] = None, trace: bool = False,
                 metrics: bool = False):
    """One writer process: owns data.<w> + md.<w>.shard while a series is
    open. With `path_str=None` the worker starts IDLE (a `WriterPlane`
    member) and is retargeted per series via "open"/"finish" — the process
    (spawn cost, imports, page cache) persists across series.

    `ring_name` attaches the worker to its shm transport ring (created by
    the coordinator/plane); chunk items then arrive as `ShmHeader`s and
    are read as zero-copy views over the mapped pages. Raw ndarrays in the
    same items list are the pickle fallback and always accepted.

    Protocol (every message is (tag, w, step, payload)):
      in:  ("open", None, (path, n_writers, cfg))  retarget at a new series
           ("step", step, items)  items = [(name, rank, offset, chunk), ...]
                                  chunk = ndarray | ShmHeader; an optional
                                  5th element is a meta dict: {"codec": spec}
                                  overrides cfg.codec for that chunk, and
                                  meta["pre"] marks chunk as the raw bytes
                                  of a device-preconditioned (pre-shuffled)
                                  array to rebuild as a PreshuffledChunk
           ("finish", None, None)  fsync + close files; worker stays alive
           ("close", None, None)   close files (if open) and exit
      out: ("ready", w, None, None)           files open / idle, accepting
           ("prepared", w, step, info)        payload + shard sealed on disk
                                              (info["dxt"]: trace snapshot
                                              when tracing)
           ("error", w, step, traceback_str)  step failed; worker stays alive
           ("finished", w, None, payload)     files closed; monitor snapshot,
                                              or {"darshan","dxt"} when
                                              tracing (merge_worker_payload
                                              takes either)
           ("closed", w, None, payload)       exiting; same payload shape

    The "prepared"/"error" ack is also the transport FREE-LIST: the
    coordinator releases the step's ring slots when it arrives (the worker
    is guaranteed done reading them), so the ring never needs cross-process
    synchronization. The darshan payload on "finished"/"closed" is the
    worker's own `MONITOR.snapshot()` (reset after shipping, so a
    persistent worker ships per-series deltas); the coordinator merges it
    so `parser_dump` covers the whole write plane.
    """
    from repro.core.darshan import CTR, MONITOR

    # orphan watchdog: a coordinator SIGKILLed (or OOM-killed) cannot tell
    # the workers anything — without this they would block on task_q.get()
    # forever, pinning their fds AND keeping the shared resource tracker
    # alive so the transport rings never get unlinked. Exiting on parent
    # death lets the tracker reap /dev/shm. (No-op when _worker_main runs
    # as a thread in tests: parent_process() is None in the main process.)
    parent = multiprocessing.parent_process()
    # DXT: a spawned worker inherits tracing from the coordinator's flag
    # (env-based enablement also works — spawn re-imports dxt.py). Trace
    # buffers are shipped home ONLY from a real child process: in thread
    # mode the parent's TRACER *is* this tracer, and a reset-snapshot
    # would steal the coordinator's own events.
    if trace and parent is not None:
        TRACER.enable()
    # metrics plane: same inheritance story as DXT — the coordinator's flag
    # rides the spawn args / "open" payload; enabling in thread mode would
    # alias the parent's registry, so only a real child flips it
    if metrics and parent is not None:
        METRICS.enable()

    def _ship_payload(reset: bool):
        snap = MONITOR.snapshot()
        if reset:
            MONITOR.reset()
        if parent is not None and (TRACER.enabled or METRICS.enabled):
            out = {"darshan": snap}
            if TRACER.enabled:
                out["dxt"] = TRACER.snapshot(reset=True)
            if METRICS.enabled:
                out["metrics"] = METRICS.snapshot(reset=True)
            return out
        return snap

    if parent is not None:
        def _exit_with_parent():
            parent.join()               # returns only when the parent died
            os._exit(2)
        threading.Thread(target=_exit_with_parent, daemon=True,
                         name="jbp-orphan-watchdog").start()

    subfiles = shard = None
    spath = str(path_str) if path_str is not None else ""
    ring = None
    if ring_name is not None:
        try:
            ring = ShmRing(name=ring_name, create=False)
        except BaseException:                   # noqa: BLE001
            result_q.put(("error", w, None, traceback.format_exc()))
            return

    def _teardown():
        nonlocal subfiles, shard
        if subfiles is not None:
            subfiles.fsync_close()
            shard.fsync()
            shard.close()
            subfiles = shard = None

    if path_str is not None:
        try:
            subfiles, shard = _open_worker_files(
                pathlib.Path(path_str), w, n_writers, cfg)
        except BaseException:                   # noqa: BLE001
            result_q.put(("error", w, None, traceback.format_exc()))
            return
    result_q.put(("ready", w, None, None))
    while True:
        msg = task_q.get()
        tag = msg[0]
        if tag == "open":
            try:
                _teardown()                     # stale series, if any
                o_path, o_n, o_cfg = msg[2][:3]
                if len(msg[2]) > 3 and msg[2][3] and parent is not None:
                    TRACER.enable()             # coordinator traces this series
                if len(msg[2]) > 4 and msg[2][4] and parent is not None:
                    METRICS.enable()            # coordinator meters this series
                n_writers, cfg = o_n, o_cfg
                spath = str(o_path)
                subfiles, shard = _open_worker_files(
                    pathlib.Path(o_path), w, n_writers, cfg)
            except BaseException:               # noqa: BLE001
                result_q.put(("error", w, None, traceback.format_exc()))
                continue                        # plane stays usable
            result_q.put(("ready", w, None, None))
            continue
        if tag == "finish":
            try:
                _teardown()
            except BaseException:               # noqa: BLE001
                result_q.put(("error", w, None, traceback.format_exc()))
                continue
            result_q.put(("finished", w, None, _ship_payload(reset=True)))
            continue
        if tag == "close":
            try:
                _teardown()
            except BaseException:               # noqa: BLE001
                pass                            # exiting anyway
            result_q.put(("closed", w, None, _ship_payload(reset=False)))
            if ring is not None:
                ring.close()
            return
        _, step, items = msg
        if subfiles is None:
            result_q.put(("error", w, step,
                          "worker received a step with no open series"))
            continue
        try:
            t0 = time.perf_counter()
            tcomp = 0.0
            shm_bytes = fallback_bytes = 0
            payloads, metas = [], []
            with TRACER.span("compress", path=f"data.{w}", rank=w) as csp:
                for item in items:
                    name, rank, offset, chunk = item[:4]
                    meta = item[4] if len(item) > 4 else None
                    if isinstance(chunk, ShmHeader):
                        arr = ring.view(chunk)  # zero-copy: shared pages
                        shm_bytes += chunk.nbytes
                    else:
                        arr = chunk             # pickle path / spill
                        fallback_bytes += arr.nbytes
                    codec = (meta or {}).get("codec") or cfg.codec
                    pre = (meta or {}).get("pre")
                    if pre is not None:
                        # coordinator shuffled this chunk on-device and shipped
                        # the raw shuffled bytes; rebuild the wrapper so
                        # encode_chunk skips the host shuffle stage
                        arr = C.PreshuffledChunk(
                            np.ascontiguousarray(arr).view(np.uint8).reshape(-1),
                            pre["dtype"], tuple(pre["shape"]), pre["block"],
                            pre["vmin"], pre["vmax"])
                    raw_nbytes = arr.nbytes
                    tc = time.perf_counter()
                    payload, shape, stats, _ = encode_chunk(
                        arr, codec, cfg.compression_block)
                    tcomp += time.perf_counter() - tc
                    record_compress_counters(w, f"data.{w}", codec,
                                             raw_nbytes, len(payload), None)
                    payloads.append(payload)
                    metas.append((name, rank, offset, shape, len(payload),
                                  stats))
                    del arr                     # release any shm view NOW
                csp.length = sum(len(p) for p in payloads)
            if METRICS.enabled:
                METRICS.observe("compress", tcomp, key=f"data.{w}",
                                nbytes=sum(len(p) for p in payloads))
            if ring is not None:
                tkey = f"{spath}/transport"
                if shm_bytes:
                    MONITOR.record(w, tkey, CTR.TRANSPORT_SHM_BYTES,
                                   inc=shm_bytes)
                if fallback_bytes:
                    MONITOR.record(w, tkey, CTR.TRANSPORT_PICKLE_FALLBACK_BYTES,
                                   inc=fallback_bytes)
            base = subfiles.append(w, b"".join(payloads))
            off = base
            chunks: dict[str, list] = {}
            for name, rank, offset, shape, nb, (vmin, vmax) in metas:
                chunks.setdefault(name, []).append(
                    ChunkMeta(rank, tuple(offset), tuple(shape), w, off, nb,
                              vmin, vmax).to_json())
                off += nb
            blob = json.dumps({"step": step, "chunks": chunks}).encode()
            crc = zlib.crc32(blob) & 0xFFFFFFFF
            # the record offset is re-derived from the file position every
            # step: a previous FAILED step may have left (torn) bytes in
            # the shard, and a stale counter would desync every later
            # commit ("worker stays alive" requires this)
            rec_off = shard.tell()
            tseal = time.perf_counter()
            with TRACER.span("seal", path=f"md.{w}.shard", rank=w,
                             length=len(blob)):
                shard.write(SHARD_HDR.pack(step, len(blob), crc))
                shard.write(blob)
                if cfg.fsync_policy == "step":
                    subfiles.fsync_one(w)
                    shard.fsync()
                else:
                    subfiles.flush_one(w)
                    shard.flush()  # coordinator reads the record back NOW
            if METRICS.enabled:
                METRICS.observe("seal", time.perf_counter() - tseal,
                                nbytes=len(blob), key=f"md.{w}.shard")
            info = {"shard_off": rec_off,
                    "shard_len": SHARD_HDR.size + len(blob), "crc": crc,
                    "compress_s": tcomp, "bytes_stored": off - base,
                    "shm_bytes": shm_bytes, "fallback_bytes": fallback_bytes,
                    "worker_s": time.perf_counter() - t0}
            if parent is not None and TRACER.enabled:
                # ship this step's trace events home on the ack itself —
                # the coordinator's timeline stays live, not close-time
                info["dxt"] = TRACER.snapshot(reset=True)
            if parent is not None and METRICS.enabled:
                # per-step histogram shard home on the same ack: the
                # coordinator's journal frame carries this worker's cells
                info["metrics"] = METRICS.snapshot(reset=True)
            result_q.put(("prepared", w, step, info))
        except BaseException:                   # noqa: BLE001
            result_q.put(("error", w, step, traceback.format_exc()))


# ---------------------------------------------------------------- coordinator
def collect_acks(workers, result_q, kind: str, expect, *,
                 timeout: float, step: Optional[int] = None) -> dict:
    """Wait for one `kind` ack per worker in `expect`; raise on worker
    errors or deaths. Acks for other steps (stale messages from an
    aborted step) are ignored. Shared by the per-series coordinator and
    the persistent WriterPlane."""
    pending = set(expect)
    got: dict[int, Any] = {}
    errors: list[tuple[int, str]] = []
    deadline = time.monotonic() + timeout
    while pending:
        try:
            tag, wid, mstep, payload = result_q.get(timeout=1.0)
        except _queue.Empty:
            dead = [i for i in pending if not workers[i][0].is_alive()]
            if dead:
                raise RuntimeError(
                    f"writer process(es) {dead} died before acking "
                    f"{kind!r} — step aborted (not committed)")
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"timed out after {timeout}s waiting for "
                    f"{kind!r} from writer(s) {sorted(pending)}")
            continue
        if tag == "error":
            if step is not None and mstep is not None and mstep != step:
                continue           # stale error from an already-aborted step
            errors.append((wid, payload))
            pending.discard(wid)
        elif tag == kind and (step is None or mstep == step):
            got[wid] = payload
            pending.discard(wid)
        # anything else: stale ack from an aborted step — drop it
    if errors:
        detail = "\n".join(f"--- writer {i} ---\n{tb}" for i, tb in errors)
        raise RuntimeError(
            f"parallel write failed on writer(s) "
            f"{[i for i, _ in errors]}:\n{detail}")
    return got


def _make_rings(n: int, ring_bytes: int) -> list[ShmRing]:
    """One transport ring per worker, cleaned up as a unit on failure."""
    rings: list[ShmRing] = []
    try:
        for _ in range(n):
            rings.append(ShmRing(ring_bytes))
    except BaseException:
        unlink_rings(rings)
        raise
    return rings


class WriterPlane:
    """W persistent writer processes, reusable across series.

    `ParallelBpWriter(..., plane=plane)` retargets the plane's workers at
    its series ("open") and releases them on close ("finish") WITHOUT
    tearing the processes down — the spawn/import cost is paid once per
    plane, not once per series. This is what makes periodic parallel
    checkpoints cheap: `CheckpointManager` keeps one plane alive for the
    whole run instead of spawning W processes every `every` steps.

    The plane also owns the shm transport rings (`transport="shm"`): one
    per worker, mapped for the plane's whole life, so repeated checkpoint
    saves reuse the same shared pages. `shutdown()` unlinks them, and a
    `weakref.finalize` guarantees the unlink even when the plane is
    leaked or the process dies with an unhandled exception.
    """

    def __init__(self, n_writers: int, *, ack_timeout: float = 300.0,
                 transport: str = "shm",
                 ring_bytes: int = DEFAULT_RING_BYTES):
        validate_transport(transport)
        self.m = max(1, int(n_writers))
        self.ack_timeout = ack_timeout
        self.transport = transport
        self._shut = False
        self.rings: list[ShmRing] = (
            _make_rings(self.m, ring_bytes) if transport == "shm" else [])
        self._ring_finalizer = weakref.finalize(
            self, unlink_rings, list(self.rings))
        ring_names = [r.name for r in self.rings] or [None] * self.m
        self.workers, self.result_q = spawn_io_workers(
            self.m, _worker_main,
            lambda i, tq, rq: (i, None, self.m, None, tq, rq, ring_names[i],
                               TRACER.enabled, METRICS.enabled))
        try:       # idle-ready handshake: every process is up and listening
            collect_acks(self.workers, self.result_q, "ready", range(self.m),
                         timeout=self.ack_timeout)
        except BaseException:
            self.shutdown(_collect=False)
            raise

    def pids(self) -> list[int]:
        return [p.pid for p, _ in self.workers]

    def alive(self) -> bool:
        return not self._shut and all(p.is_alive() for p, _ in self.workers)

    def shutdown(self, _collect: bool = True):
        """Exit every worker; merge their Darshan counters into this
        process's MONITOR; unlink the transport rings (idempotent)."""
        if self._shut:
            return
        self._shut = True
        for p, tq in self.workers:
            if p.is_alive():
                tq.put(("close", None, None))
        if _collect:
            try:
                got = collect_acks(
                    self.workers, self.result_q, "closed",
                    [i for i, (p, _) in enumerate(self.workers)
                     if p.is_alive()], timeout=self.ack_timeout)
                for payload in got.values():
                    merge_worker_payload(payload)
            except BaseException:               # noqa: BLE001
                pass                            # best effort on teardown
        for p, tq in self.workers:
            tq.close()
            p.join(timeout=10.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)             # reap: no zombie PID entry
        self._ring_finalizer()                  # close + unlink every ring

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.shutdown()


class ParallelBpWriter:
    """BpWriter-protocol writer backed by W real writer processes.

    Drop-in for `BpWriter` on the producer side (begin_step/put/
    set_attribute/end_step/close). The number of aggregators equals the
    number of writer processes: each process owns its subfile outright,
    which is what makes the plane coordination-free between commits.

    `transport="shm"` (default) moves chunk bytes through per-worker
    shared-memory rings; `"pickle"` is the queue-serialization baseline.
    `async_commit=True` pipelines the whole two-phase commit behind a
    bounded snapshot queue: `end_step()` returns after a deep-copy
    snapshot, `drain()` is the durability barrier (otherwise `drain()` is
    a no-op — the sync `end_step` is its own commit barrier).
    """

    def __init__(self, path, n_ranks: int, cfg: EngineConfig = EngineConfig(),
                 *, n_writers: Optional[int] = None, ack_timeout: float = 300.0,
                 plane: Optional[WriterPlane] = None, transport: str = "shm",
                 ring_bytes: int = DEFAULT_RING_BYTES,
                 async_commit: bool = False, queue_depth: int = 2):
        validate_transport(transport)
        self.path = pathlib.Path(str(path))
        self.path.mkdir(parents=True, exist_ok=True)
        self.cfg = cfg
        self.n_ranks = n_ranks
        w = n_writers if n_writers is not None else cfg.aggregators
        self.m = min(max(1, int(w)), max(n_ranks, 1))
        if plane is not None:
            self.m = min(self.m, plane.m)
            # the plane owns worker processes AND rings: inherit its mode
            transport = plane.transport
        self.ack_timeout = ack_timeout
        self._plane = plane
        self.async_commit = bool(async_commit)
        if cfg.stripe is not None:
            OstPool(self.path, cfg.n_osts)      # create ost dirs up front
            for i in range(self.m):
                with open_file(self.path / f"data.{i}.stripe.json", "w",
                               rank=0) as sf:
                    sf.write(json.dumps(
                        {"stripe_count": cfg.stripe.stripe_count,
                         "stripe_size": cfg.stripe.stripe_size}))
        self._md = open_file(self.path / "md.0", "wb", rank=0)
        self._idx = open_file(self.path / "md.idx", "wb", rank=0)
        self._md_off = 0
        self._step: Optional[int] = None
        self._pending: dict[str, dict] = {}
        self._attrs: dict[str, Any] = {}
        self._profile: list[dict] = []
        # metrics journal sidecar: one frame per committed step carrying
        # the coordinator's delta + every worker's shipped shard
        self._journal = (StepJournal(journal_path(self.path))
                         if METRICS.enabled and cfg.profiling else None)
        self._closed = False
        self._crash_after_prepare = False       # test hook: torn-commit sim
        self._rings: list[ShmRing] = []
        self._ring_finalizer = None
        try:
            if plane is not None:
                # retarget the persistent plane's first m workers at this
                # series; spawn cost is NOT paid here, rings are the plane's
                self._workers, self._result_q = plane.workers, plane.result_q
                self._rings = plane.rings[:self.m]
                for wid in range(self.m):
                    self._workers[wid][1].put(
                        ("open", None, (str(self.path), self.m, cfg,
                                        TRACER.enabled, METRICS.enabled)))
            else:
                if transport == "shm":
                    self._rings = _make_rings(self.m, ring_bytes)
                    self._ring_finalizer = weakref.finalize(
                        self, unlink_rings, list(self._rings))
                ring_names = [r.name for r in self._rings] or [None] * self.m
                self._workers, self._result_q = spawn_io_workers(
                    self.m, _worker_main,
                    lambda i, tq, rq: (i, str(self.path), self.m, cfg, tq, rq,
                                       ring_names[i], TRACER.enabled,
                                       METRICS.enabled))
            self._collect("ready", range(self.m))   # spawn/open failures here
        except BaseException:
            # a failed bring-up must not leak the md handles, the rings, OR
            # the workers that DID come up (they would block on task_q.get
            # holding their subfile/shard fds until parent exit); a
            # borrowed plane is left alive — its workers stay idle-usable
            self._md.close()
            self._idx.close()
            if plane is None:
                for p, _ in getattr(self, "_workers", []):
                    if p.is_alive():
                        p.terminate()
                    p.join(timeout=2.0)
                if self._ring_finalizer is not None:
                    self._ring_finalizer()
            raise
        self.transport = "shm" if self._rings else "pickle"
        # the pipelined committer sits in FRONT of the coordinator: it owns
        # the two-phase commit ordering exactly like AsyncBpWriter's seal
        # thread owns md.0/md.idx ordering
        self._committer = None
        if self.async_commit:
            from repro.core.async_engine import _PipelinedCommitter
            self._committer = _PipelinedCommitter(
                self._commit_step, queue_depth=queue_depth,
                name="jbp-parallel-commit")

    # ------------------------------------------------------------------ step
    def begin_step(self, step: int):
        if self._step is not None:
            raise RuntimeError(
                f"begin_step({step}) while step {self._step} is still open "
                f"(previous step not closed — call end_step() first)")
        self._step = step
        self._pending = {}

    def set_attribute(self, name: str, value):
        self._attrs[name] = value

    def put(self, name: str, array, *, global_shape: tuple,
            offset: tuple, rank: int, codec: Optional[str] = None):
        """Register one rank's chunk of variable `name` for this step.

        Same contract as BpWriter.put: `array` may be a numpy ndarray, a
        jax.Array (preconditioned on-device at commit when the engine has
        `device_compress=True`), or a `PreshuffledChunk`; `codec` overrides
        the engine codec for THIS variable."""
        if self._step is None:
            raise RuntimeError("put() outside begin/end_step")
        validate_put_rank(rank, self.n_ranks)
        if isinstance(array, C.PreshuffledChunk) or C.is_device_array(array):
            a = array                      # no host materialization here
        else:
            a = np.ascontiguousarray(array)
        gshape = tuple(int(x) for x in global_shape)
        var = self._pending.setdefault(name, {
            "dtype": np.dtype(a.dtype).str, "shape": gshape, "chunks": []})
        if var["shape"] != gshape:
            raise ValueError(
                f"put({name!r}) global_shape {gshape} conflicts with "
                f"{var['shape']} from an earlier put of this step")
        if codec is not None:
            C.parse_codec(codec)           # fail fast on bad specs
            prev = var.get("codec")
            if prev is not None and prev != codec:
                raise ValueError(
                    f"put({name!r}) codec {codec!r} conflicts with {prev!r} "
                    f"from an earlier put of this step")
            var["codec"] = codec
        var["chunks"].append((rank, tuple(int(x) for x in offset), a))

    def _take_snapshot(self, *, copy: bool) -> StepSnapshot:
        """Capture the open step and reset producer-side state (the shared
        bp_engine snapshot contract: `copy=True` deep-copies chunk arrays
        so an async producer may reuse its buffers immediately)."""
        snap = take_step_snapshot(self._step, self._pending, self._attrs,
                                  copy=copy)
        self._step = None
        self._pending = {}
        return snap

    # ----------------------------------------------------------- ack plumbing
    def _collect(self, kind: str, expect, step: Optional[int] = None) -> dict:
        return collect_acks(self._workers, self._result_q, kind, expect,
                            timeout=self.ack_timeout, step=step)

    def _read_shard_record(self, wid: int, info: dict, step: int) -> dict:
        """Phase-1 validation: read the sealed shard record back from disk
        and crc-check it — the coordinator commits only what is durably
        prepared. A torn/corrupt shard aborts the step like a torn step."""
        with open_file(shard_path(self.path, wid), "rb", rank=0) as f:
            f.seek(info["shard_off"])
            raw = f.read(info["shard_len"])
        if len(raw) < SHARD_HDR.size:
            raise RuntimeError(f"torn shard record from writer {wid} "
                               f"(step {step} not committed)")
        rstep, ln, crc = SHARD_HDR.unpack_from(raw, 0)
        blob = raw[SHARD_HDR.size:SHARD_HDR.size + ln]
        if (rstep != step or len(blob) != ln
                or (zlib.crc32(blob) & 0xFFFFFFFF) != crc):
            raise RuntimeError(f"torn shard record from writer {wid} "
                               f"(step {step} not committed)")
        return json.loads(blob)

    # ------------------------------------------------------------------ commit
    def end_step(self, blocking: bool = False) -> dict:
        """Sync mode: run the two-phase commit inline (the commit barrier).
        `async_commit` mode: snapshot + enqueue; `blocking=True` (forced by
        fsync_policy="step") waits for the background seal instead."""
        if self._committer is None:
            return self._commit_step(self._take_snapshot(copy=False))
        if self.cfg.fsync_policy == "step":
            blocking = True            # durable seal must precede the return
        snap = self._take_snapshot(copy=not blocking)
        return self._committer.submit(snap, blocking=blocking)

    def _commit_step(self, snap: StepSnapshot) -> dict:
        step = snap.step
        t0 = time.perf_counter()

        by_w: dict[int, list] = {}
        n_bytes_raw = 0
        for name, var in snap.pending.items():
            codec = var.get("codec") or self.cfg.codec
            for rank, offset, arr in var["chunks"]:
                if C.is_device_array(arr):
                    if (self.cfg.device_compress
                            and C.codec_wants_device(codec)):
                        # on-chip byte shuffle BEFORE the shm handoff: the
                        # worker sees pre-shuffled bytes and pays only the
                        # LZ stage (its encode skips the host shuffle)
                        arr = C.device_precondition(
                            arr, block=self.cfg.compression_block)
                        MONITOR.record(0, str(self.path),
                                       CTR.COMPRESS_DEVICE_BYTES,
                                       inc=float(arr.device_bytes))
                    else:
                        arr = np.asarray(arr)
                n_bytes_raw += arr.nbytes
                wid = aggregator_of(rank, self.n_ranks, self.m)
                by_w.setdefault(wid, []).append((name, rank, offset, arr,
                                                 codec))

        # ---- phase 1: PREPARE — fan chunks out, await sealed-shard votes.
        # shm transport: ONE memcpy into the worker's ring per chunk, only
        # the header crosses the queue; a chunk the ring cannot hold right
        # now falls back to pickling that one array (never blocks).
        shm_slots: dict[int, list[int]] = {}
        shm_bytes = fallback_bytes = 0
        try:
            with TRACER.span("transport", path=str(self.path),
                             length=n_bytes_raw):
                for wid, items in by_w.items():
                    ring = self._rings[wid] if self._rings else None
                    wire_items = []
                    tw0 = time.perf_counter()
                    wid_bytes = 0
                    for name, rank, offset, arr, codec in items:
                        meta = None
                        if isinstance(arr, C.PreshuffledChunk):
                            # ship the shuffled bytes; the wrapper's metadata
                            # rides the wire item so the worker can rebuild it
                            meta = {"codec": codec,
                                    "pre": {"dtype": arr.dtype.str,
                                            "shape": arr.shape,
                                            "block": arr.block,
                                            "vmin": arr.vmin,
                                            "vmax": arr.vmax}}
                            arr = arr.data
                        elif codec != self.cfg.codec:
                            meta = {"codec": codec}
                        hdr = (ring.write_array(arr)
                               if ring is not None else None)
                        wid_bytes += arr.nbytes
                        if hdr is not None:
                            shm_slots.setdefault(wid, []).append(hdr.offset)
                            shm_bytes += arr.nbytes
                            sent = hdr
                        else:
                            if ring is not None:
                                fallback_bytes += arr.nbytes
                            sent = arr
                        wire_items.append((name, rank, offset, sent, meta)
                                          if meta is not None
                                          else (name, rank, offset, sent))
                    self._workers[wid][1].put(("step", step, wire_items))
                    if METRICS.enabled:
                        # per-worker transport latency: the straggler axis
                        # the autotuner reads (a slow ring = a slow worker)
                        METRICS.observe("transport",
                                        time.perf_counter() - tw0,
                                        nbytes=wid_bytes, key=f"w{wid}")
            with TRACER.span("prepare", path=str(self.path)):
                acks = self._collect("prepared", by_w, step=step)
        finally:
            # the ack (prepared OR error OR abort) is the free-list: the
            # step is resolved, the worker is done (or dead) — reclaim its
            # slots in allocation order. An aborted step's slots may still
            # be read by a straggling worker, but that step is never
            # committed, so the garbage it might produce is torn-shard
            # dead weight by construction.
            for wid, offs in shm_slots.items():
                for off in offs:
                    self._rings[wid].free(off)
        worker_mets: dict[int, dict] = {}
        for wid, a in acks.items():             # workers ship per-step traces
            trace = a.pop("dxt", None)
            if trace:
                TRACER.ingest(trace)
            met = a.pop("metrics", None)
            if met:
                # fold into the live registry (the jbpd/metrics-op view)
                # AND keep the per-worker shard for this step's journal
                # frame — the two views stay additive-identical
                METRICS.merge(met)
                worker_mets[wid] = met
        merged: dict[str, list] = {name: [] for name in snap.pending}
        for wid in sorted(acks):
            rec = self._read_shard_record(wid, acks[wid], step)
            for name, chunk_list in rec["chunks"].items():
                merged[name].extend(chunk_list)
        t_prepare = time.perf_counter() - t0
        if METRICS.enabled:
            METRICS.observe("prepare", t_prepare, nbytes=n_bytes_raw,
                            key=str(self.path))

        if self._crash_after_prepare:
            raise RuntimeError("simulated coordinator crash between "
                               "prepare and commit")

        # ---- phase 2: COMMIT — merge shard chunk tables into md.0/md.idx
        # (record layout and seal ordering live in bp_engine so every
        # engine commits identically — byte parity is not re-implemented)
        with TRACER.span("commit", path=str(self.path)) as sp:
            md_rec = build_md_record(step, snap.attrs, snap.pending, merged)
            blob = json.dumps(md_rec).encode()
            sp.length = len(blob)
            self._md_off = seal_md_record(
                self._md, self._idx, self._md_off, step, blob,
                fsync_step=self.cfg.fsync_policy == "step")

        dt = time.perf_counter() - t0
        if METRICS.enabled:
            METRICS.observe("commit", dt - t_prepare, nbytes=len(blob),
                            key=str(self.path))
        prof = {"step": step, "write_s": dt, "prepare_s": t_prepare,
                "commit_s": dt - t_prepare,
                "compress_s": sum(a["compress_s"] for a in acks.values()),
                "bytes_raw": n_bytes_raw,
                "bytes_stored": sum(a["bytes_stored"] for a in acks.values()),
                "transport": self.transport,
                "transport_shm_bytes": shm_bytes,
                "transport_pickle_bytes": (fallback_bytes if self._rings
                                           else n_bytes_raw),
                "aggregators": self.m, "writers": self.m,
                "worker_s": {str(wid): acks[wid]["worker_s"]
                             for wid in sorted(acks)}}
        prof.update(snap.extra)
        self._profile.append(prof)
        if self._journal is not None:
            # single-threaded by the commit contract (caller thread, or the
            # committer thread in async mode) — ordered like md.idx appends
            self._journal.frame(step, prof, MONITOR.report()["total"],
                                METRICS.snapshot(reset=True)["hists"],
                                workers=worker_mets)
        return prof

    def drain(self):
        """Durability barrier. Sync mode: no-op (end_step() already commits
        synchronously). async_commit: block until every queued step's
        md.idx record is sealed per the fsync policy."""
        if self._committer is not None:
            self._committer.drain()

    # ------------------------------------------------------------------ close
    def _profile_doc(self) -> dict:
        doc = {"engine": "JBP(BP4-parallel)", "aggregators": self.m,
               "writers": self.m, "codec": self.cfg.codec,
               "transport": self.transport, "steps": self._profile}
        if self._committer is not None:
            doc["async"] = self._committer.profile_block(self._profile)
        return doc

    def overlap_stats(self) -> dict:
        """Live view of the commit-overlap accounting (async_commit)."""
        doc = self._profile_doc()
        return dict(doc.get("async", {}), steps=len(self._profile))

    def _drain_stale_acks(self):
        """Throw away unconsumed result-queue messages (acks of aborted
        steps) so worker feeder threads are never wedged on a full pipe at
        exit — part of the close-cannot-hang contract. Owned-queue path
        only: a plane's queue outlives this writer."""
        try:
            while True:
                self._result_q.get_nowait()
        except _queue.Empty:
            pass

    def close(self):
        if self._closed:
            return
        self._closed = True
        errors: list[BaseException] = []
        if self._committer is not None:
            try:
                self._committer.shutdown()      # drain; never raises early
            except BaseException as e:          # noqa: BLE001
                errors.append(e)
        fin_mets: dict[int, dict] = {}

        def _absorb(got: dict):
            # keep each worker's residual metrics shard for the journal's
            # final frame BEFORE the payload merge folds it into the live
            # registry — the two views stay additive-identical
            for wid, payload in got.items():
                if isinstance(payload, dict):
                    met = payload.get("metrics")
                    if met:
                        fin_mets[wid] = met
                merge_worker_payload(payload)

        if self._plane is not None:
            # release, don't kill: workers fsync+close this series' files
            # and go back to idle — the plane is reusable immediately
            for wid in range(self.m):
                self._workers[wid][1].put(("finish", None, None))
            try:
                _absorb(self._collect(
                    "finished", [i for i in range(self.m)
                                 if self._workers[i][0].is_alive()]))
            except BaseException as e:          # noqa: BLE001
                errors.append(e)
        else:
            for _, tq in self._workers:
                tq.put(("close", None, None))
            try:
                _absorb(self._collect(
                    "closed", [i for i, (p, _) in enumerate(self._workers)
                               if p.is_alive()]))
            except BaseException as e:          # noqa: BLE001
                errors.append(e)
            # a worker that died mid-step (or is wedged) must not turn
            # close() into a hang: drain stale acks so exiting workers can
            # flush their feeder threads, close the task queues, and
            # terminate anything join() cannot reap
            self._drain_stale_acks()
            for p, tq in self._workers:
                tq.close()
                p.join(timeout=10.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=5.0)
            if self._ring_finalizer is not None:
                self._ring_finalizer()          # close + unlink every ring
        if self.cfg.fsync_policy != "step":
            self._md.fsync()
            self._idx.fsync()
        self._md.close()
        self._idx.close()
        if self.cfg.profiling:
            with open_file(self.path / "profiling.json", "w", rank=0) as f:
                f.write(json.dumps(self._profile_doc(), indent=1))
        if TRACER.enabled:
            # after the worker merges above: the sidecar is the MERGED
            # coordinator+worker timeline on one wall clock
            TRACER.dump(self.path / "dxt.json")
        if self._journal is not None:
            # final frame: close-time residuals (md fsyncs, profiling.json,
            # each worker's post-last-step shard) — sum over journal frames
            # reproduces the live registry exactly
            self._journal.frame(-1, {"final": True},
                                MONITOR.report()["total"],
                                METRICS.snapshot(reset=True)["hists"],
                                workers=fin_mets)
            self._journal.close()
            self._journal = None
        if self._committer is not None:
            self._committer.check_error()       # background commit failures
        if errors:
            raise errors[0]

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
            return
        try:
            self.close()
        except BaseException:                   # noqa: BLE001
            pass       # the in-flight exception is the root cause; keep it
