"""DXT-style per-operation I/O tracing (paper §III-D).

The paper's analysis leans on Darshan eXtended Tracing: not just *how
much* I/O each rank did (the `DarshanMonitor` counters) but *when each
operation ran* — which rank wrote which bytes to which subfile at what
time. That per-operation timeline is what exposes stragglers, commit
stalls and serialization that aggregate counters average away. We own the
whole I/O stack, so the trace is explicit rather than LD_PRELOADed:

  * every `InstrumentedFile` op (open/read/write/seek/flush/fsync/close)
    records one event `(rank, path, op, offset, length, t_start, t_end)`
    — offsets come from the handle's own position tracking, exactly what
    DXT's X_POSIX module logs,
  * the planes emit higher-level SPANS for the step lifecycle — snapshot,
    compress, shm transport, shard seal, two-phase commit, cache
    fetch/serve — so the timeline shows the *why* between the POSIX ops,
  * writer worker PROCESSES ship their trace buffers home on the existing
    "prepared"/"finished"/"closed" ack paths next to their Darshan
    counter snapshots; every snapshot carries a per-process CLOCK EPOCH
    (a paired `time.time()`/`time.perf_counter()` sample) so `ingest`
    rebases everything onto one global wall-clock axis — merged timelines
    are comparable across processes (and across hosts, to NTP accuracy).

Cost discipline: tracing OFF is one attribute load + branch per op (the
hot paths check `TRACER.enabled` before calling anything). Tracing ON is
bounded memory — per-thread ring buffers of `capacity` events each;
when a ring fills the OLDEST event is dropped and counted, never blocking
an I/O path (`bench_darshan_costs.run_tracing_overhead` holds the write
path to <= 5% overhead).

Exports:
  * `to_dxt_text(events)` — darshan-parser DXT-style text (`X_POSIX`
    lines per file record, spans as `X_SPAN`),
  * `to_chrome(events)` — Chrome trace-event JSON, loadable in Perfetto
    (chrome://tracing): pid = source process (coordinator / writer worker
    / daemon connection), tid = rank within it,
  * `TRACER.dump(path)` / `load_trace(path)` — the `dxt.json` sidecar the
    writers leave next to `profiling.json`, which `repro.tools.jbpdxt`
    analyzes (timeline summary, per-subfile/OST straggler table,
    bandwidth-over-time).

Enable programmatically (`TRACER.enable()`) or via the environment
(`JBP_DXT=1`, inherited by spawned writer workers); `JBP_DXT_CAPACITY`
overrides the per-thread ring size.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional

DEFAULT_CAPACITY = int(os.environ.get("JBP_DXT_CAPACITY", 1 << 15))

# span vocabulary (the step-lifecycle ops, distinct from the POSIX ops
# recorded by InstrumentedFile): keep these stable — jbpdxt and the
# Chrome export group by them
SPAN_OPS = ("snapshot", "compress", "transport", "prepare", "seal",
            "commit", "pipeline", "cache_fetch", "serve", "read_task",
            "device_shuffle")
POSIX_OPS = ("open", "read", "write", "seek", "flush", "fsync", "close")


class _ThreadBuf:
    """One thread's bounded event ring. Appends are single-threaded (the
    owning thread); snapshots copy under the GIL."""

    __slots__ = ("events", "dropped", "cap")

    def __init__(self, cap: int):
        self.events: deque = deque()
        self.dropped = 0
        self.cap = cap


class _Span:
    """Context manager recording one lifecycle span on exit. `length` may
    be set inside the block (e.g. bytes moved by a transport span)."""

    __slots__ = ("_tr", "op", "path", "rank", "length", "_t0")

    def __init__(self, tr: "DxtTracer", op: str, path: str, rank: int,
                 length: int):
        self._tr = tr
        self.op = op
        self.path = path
        self.rank = rank
        self.length = length

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self._tr.record(self.rank, self.path, self.op, 0, self.length,
                        self._t0, time.perf_counter())
        return False


class _NullSpan:
    """The tracing-off span: no clock reads, no record. One shared
    instance; `length` writes are absorbed by __slots__ on each use."""

    __slots__ = ("length",)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


_NULL_SPAN = _NullSpan()


class DxtTracer:
    """Process-global per-operation trace recorder.

    Events live in bounded per-thread ring buffers (no locks on the
    record path — each thread appends to its own deque; registration of a
    new thread's buffer is the only locked step). `snapshot()` exports a
    picklable dict with this process's clock epoch; `ingest()` folds
    another process's snapshot in, rebased onto the wall-clock axis;
    `events()` returns the single merged timeline.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self.enabled = bool(int(os.environ.get("JBP_DXT", "0") or 0))
        self.src = f"pid{os.getpid()}"
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._bufs: list[_ThreadBuf] = []
        # events ingested from other processes, already on the wall axis:
        # (src, rank, path, op, offset, length, t0, t1)
        self._foreign: list[tuple] = []
        self._foreign_dropped = 0
        self._stamp_epoch()

    def _stamp_epoch(self):
        # paired wall/monotonic sample: everything recorded in this
        # process is rebased wall = perf + (epoch_wall - epoch_perf)
        self.epoch = (time.time(), time.perf_counter())

    # ---------------------------------------------------------------- control
    def enable(self, capacity: Optional[int] = None):
        if capacity is not None:
            self.capacity = int(capacity)
        self.enabled = True

    def disable(self):
        self.enabled = False

    def reset(self, capacity: Optional[int] = None):
        """Drop every recorded and ingested event (buffers of other
        threads included) and restamp the clock epoch."""
        with self._lock:
            if capacity is not None:
                self.capacity = int(capacity)
            for b in self._bufs:
                b.events.clear()
                b.dropped = 0
                b.cap = self.capacity
            self._foreign = []
            self._foreign_dropped = 0
            self.src = f"pid{os.getpid()}"
            self._stamp_epoch()

    # ----------------------------------------------------------------- record
    def _register(self) -> _ThreadBuf:
        buf = _ThreadBuf(self.capacity)
        with self._lock:
            self._bufs.append(buf)
        self._tls.buf = buf
        return buf

    def record(self, rank: int, path: str, op: str, offset: int, length: int,
               t0: float, t1: float):
        """Append one event to the calling thread's ring (oldest-dropped
        when full — I/O never blocks on its own trace)."""
        if not self.enabled:
            return
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = self._register()
        ev = buf.events
        if len(ev) >= buf.cap:
            ev.popleft()
            buf.dropped += 1
        ev.append((rank, path, op, offset, length, t0, t1))

    def span(self, op: str, path: str = "", rank: int = 0, length: int = 0):
        """Lifecycle span context manager; a shared no-op when disabled
        (callers on hot paths may also branch on `TRACER.enabled`)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, op, path, rank, length)

    @staticmethod
    def now() -> float:
        """The trace clock (perf_counter) — for callers timing raw events
        by hand instead of through `span`."""
        return time.perf_counter()

    # ------------------------------------------------------- snapshot / merge
    def snapshot(self, reset: bool = False) -> dict:
        """Picklable dump of this PROCESS's own events (not ingested
        foreign ones) — what a writer worker ships home on its ack.
        `reset=True` clears the shipped buffers (per-step deltas)."""
        with self._lock:
            bufs = list(self._bufs)
        events: list = []
        dropped = 0
        for b in bufs:
            events.extend(b.events)     # atomic copy under the GIL
            dropped += b.dropped
            if reset:
                b.events.clear()
                b.dropped = 0
        events.sort(key=lambda e: e[5])
        return {"src": self.src, "epoch": list(self.epoch),
                "dropped": dropped, "events": [list(e) for e in events]}

    def ingest(self, snap: Optional[dict]):
        """Fold another process's `snapshot()` into the merged timeline,
        rebasing its perf_counter timestamps onto the wall-clock axis via
        its shipped epoch."""
        if not snap or not (snap.get("events") or snap.get("dropped")):
            return
        ew, ep = snap.get("epoch", (0.0, 0.0))
        shift = ew - ep
        src = snap.get("src", "?")
        rebased = [(src, r, p, o, off, ln, t0 + shift, t1 + shift)
                   for r, p, o, off, ln, t0, t1 in snap.get("events", ())]
        with self._lock:
            self._foreign.extend(rebased)
            self._foreign_dropped += int(snap.get("dropped", 0))

    def events(self) -> list[tuple]:
        """The single merged timeline: own events (rebased with this
        process's epoch) + every ingested snapshot, sorted by t_start.
        Tuples: (src, rank, path, op, offset, length, t0, t1) — t0/t1 are
        wall-clock seconds on one shared axis."""
        shift = self.epoch[0] - self.epoch[1]
        own = self.snapshot()
        merged = [(self.src, r, p, o, off, ln, t0 + shift, t1 + shift)
                  for r, p, o, off, ln, t0, t1 in own["events"]]
        with self._lock:
            merged.extend(self._foreign)
        merged.sort(key=lambda e: e[6])
        return merged

    def dropped(self) -> int:
        with self._lock:
            own = sum(b.dropped for b in self._bufs)
            return own + self._foreign_dropped

    def stats(self) -> dict:
        """The `jbpd --stats` / parser_dump summary block."""
        with self._lock:
            n_own = sum(len(b.events) for b in self._bufs)
            n_foreign = len(self._foreign)
        return {"enabled": self.enabled, "events": n_own + n_foreign,
                "dropped": self.dropped(), "capacity": self.capacity}

    # ------------------------------------------------------------ persistence
    def dump(self, path) -> dict:
        """Write the merged timeline as the `dxt.json` sidecar (next to
        profiling.json). Returns the document written."""
        doc = {"format": "jbp-dxt-1", "generated": time.time(),
               "dropped": self.dropped(),
               "events": [list(e) for e in self.events()]}
        # raw open() on purpose: the sidecar is the tracer's OWN output —
        # routing it through InstrumentedFile would trace the trace dump
        with open(str(path), "w") as f:   # jbplint: disable=JBP002
            json.dump(doc, f)
        return doc


def load_trace(path) -> dict:
    """Read a `dxt.json` sidecar back: {"events": [tuples], "dropped": n}.
    Accepts a series directory (looks for dxt.json inside) or the file."""
    p = str(path)
    if os.path.isdir(p):
        p = os.path.join(p, "dxt.json")
    # raw open() on purpose: reading the tracer's own sidecar through
    # InstrumentedFile would pollute the counters the trace is explaining
    with open(p) as f:   # jbplint: disable=JBP002
        doc = json.load(f)
    if doc.get("format") != "jbp-dxt-1":
        raise ValueError(f"{p}: not a jbp DXT trace (format="
                         f"{doc.get('format')!r})")
    doc["events"] = [tuple(e) for e in doc.get("events", [])]
    return doc


# -------------------------------------------------------------------- exports
def to_chrome(events, dropped: int = 0) -> dict:
    """Chrome trace-event JSON (Perfetto / chrome://tracing loadable).

    pid <-> source process (coordinator, each writer worker, the daemon),
    tid <-> rank/worker/connection within it. POSIX ops and lifecycle
    spans are complete ("X") events; process names arrive as "M" metadata
    records. Timestamps are microseconds relative to the earliest event.
    """
    srcs: dict[str, int] = {}
    out: list[dict] = []
    t_base = min((e[6] for e in events), default=0.0)
    for src, rank, path, op, off, ln, t0, t1 in events:
        pid = srcs.setdefault(src, len(srcs) + 1)
        ev = {"name": op, "cat": "span" if op in SPAN_OPS else "posix",
              "ph": "X", "pid": pid, "tid": int(rank),
              "ts": (t0 - t_base) * 1e6,
              "dur": max((t1 - t0) * 1e6, 0.001),
              "args": {"path": path, "offset": int(off),
                       "length": int(ln)}}
        out.append(ev)
    meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": src}} for src, pid in srcs.items()]
    return {"traceEvents": meta + out, "displayTimeUnit": "ms",
            "otherData": {"format": "jbp-dxt-1", "dropped": int(dropped)}}


def to_dxt_text(events, dropped: int = 0) -> str:
    """darshan-parser DXT-style text: one block per file record with
    X_POSIX lines (rank, op, segment, offset, length, start, end), then
    an X_SPAN module for the lifecycle spans. Times are seconds relative
    to the earliest event, like darshan's job-relative timestamps."""
    t_base = min((e[6] for e in events), default=0.0)
    lines = ["# DXT-style trace (repro/core/dxt.py)",
             f"# events: {len(events)}  dropped: {dropped}"]
    by_file: dict[str, list] = {}
    spans: list = []
    for e in events:
        (spans if e[3] in SPAN_OPS else
         by_file.setdefault(e[2], [])).append(e)
    for path in sorted(by_file):
        lines.append("#")
        lines.append(f"# DXT, file_name: {path}")
        lines.append("# Module\tRank\tOp\tSegment\tOffset\tLength\t"
                     "Start(s)\tEnd(s)")
        seg: dict[int, int] = {}
        for src, rank, _p, op, off, ln, t0, t1 in by_file[path]:
            s = seg.get(rank, 0)
            seg[rank] = s + 1
            lines.append(f" X_POSIX\t{rank}\t{op}\t{s}\t{off}\t{ln}\t"
                         f"{t0 - t_base:.6f}\t{t1 - t_base:.6f}")
    if spans:
        lines.append("#")
        lines.append("# DXT, module: X_SPAN (step lifecycle)")
        lines.append("# Module\tRank\tOp\tSrc\tLength\tStart(s)\tEnd(s)")
        for src, rank, path, op, off, ln, t0, t1 in spans:
            lines.append(f" X_SPAN\t{rank}\t{op}\t{src}\t{ln}\t"
                         f"{t0 - t_base:.6f}\t{t1 - t_base:.6f}")
    return "\n".join(lines)


TRACER = DxtTracer()
