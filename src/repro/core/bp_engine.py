"""JBP — the BP4-style log-structured parallel write engine (paper Fig 1).

Directory layout mirrors ADIOS2 BP4:

    <name>.bp4/
      data.0 .. data.M-1    aggregated subfiles (optionally Lustre-striped
                            across emulated OSTs: ost<k>/data.<m>.obj)
      md.0                  per-step variable metadata (chunk tables)
      md.idx                fixed-size index records -> rapid metadata scan
      profiling.json        per-step engine timings (ADIOS2-compatible idea)

Write protocol per step (all ranks logical):
  1. every rank `put()`s its chunks (numpy views — zero copy),
  2. `end_step()` compresses chunks (codec from EngineConfig), assigns
     rank -> aggregator, and the work-stealing WriterPool appends payloads
     to the M subfiles,
  3. the chunk table (rank, box, subfile, offset, nbytes) goes to md.0,
     then a crc-sealed 64-byte record goes to md.idx — a step is durable
     iff its idx record validates, which is the crash-consistency story.

Reads never touch subfiles until the box intersection says so: md.idx ->
md.0 -> exact byte ranges. Arbitrary box selections let a restarted job
with a different mesh read exactly the bytes each new shard needs
(elastic re-sharding).

Async pipeline: `end_step()` is factored into `_take_snapshot()` (capture
the step's chunks + attrs) and `_write_step(snapshot)` (compress, assign
aggregators, append subfiles, seal metadata). `BpWriter` runs both inline;
`repro.core.async_engine.AsyncBpWriter` enqueues snapshots onto a bounded
in-flight queue and runs `_write_step` on a background writer thread, so
computation overlaps I/O. Durability semantics are IDENTICAL in both modes:
a step is durable iff its crc-sealed md.idx record validates, sync and
async writers produce byte-identical data.* and md.0 files for the same
puts, and `fsync_policy="step"` always means the seal (fsync of md.0 and
md.idx) has happened before `end_step` returns to the producer.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import struct
import threading
import time
import zlib
from typing import Any, Optional

import numpy as np

from repro.core import compression as C
from repro.core.aggregation import (AggregatorConfig, SubfileSet, WriterPool,
                                    aggregator_of)
from repro.core.darshan import CTR, MONITOR, open_file
from repro.core.dxt import TRACER
from repro.core.metrics import METRICS, StepJournal, journal_path
from repro.core.reader_pool import ReaderPool
from repro.core.striping import OstPool, StripeConfig, StripedFile

IDX_RECORD = struct.Struct("<QQQIIQQQ")   # step, md_off, md_len, crc, flags, t_ns, reserved x2
IDX_SIZE = IDX_RECORD.size


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    aggregators: int = 1
    # none | blosc | bzip2 | zlib | lossy:<abs> | lossy:rel:<rel>
    codec: str = "none"
    compression_block: int = C.DEFAULT_BLOCK
    # run the blosc byte-shuffle preconditioner ON-DEVICE for jax.Array
    # puts (kernels/bitshuffle Pallas kernel + async D2H overlapping the
    # host Z_RLE stage); host/numpy puts are unaffected
    device_compress: bool = False
    stripe: Optional[StripeConfig] = None
    n_osts: int = 4
    workers: int = 4
    profiling: bool = True
    # "close": BP4-style — metadata buffered, fsync once at series close
    #          (max throughput; a crash loses only the current series).
    # "step":  fsync md.0+md.idx every step (checkpoint durability).
    fsync_policy: str = "close"


@dataclasses.dataclass
class ChunkMeta:
    rank: int
    offset: tuple
    extent: tuple
    agg: int
    file_offset: int
    nbytes: int
    # per-block value statistics, ADIOS2-style: recorded in md.0 at write
    # time so min/max queries never decompress a payload. None for empty
    # or non-numeric blocks (and for series written before stats existed).
    vmin: Optional[float] = None
    vmax: Optional[float] = None

    def to_json(self):
        d = {"rank": self.rank, "offset": list(self.offset),
             "extent": list(self.extent), "agg": self.agg,
             "foff": self.file_offset, "nbytes": self.nbytes}
        if self.vmin is not None:
            d["min"] = self.vmin
            d["max"] = self.vmax
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ChunkMeta":
        return cls(d["rank"], tuple(d["offset"]), tuple(d["extent"]),
                   d["agg"], d["foff"], d["nbytes"],
                   d.get("min"), d.get("max"))


def chunk_stats(arr: np.ndarray) -> tuple[Optional[float], Optional[float]]:
    """(min, max) of a block, or (None, None) when undefined. NaNs are
    ignored; stats are recorded only when both bounds are FINITE, so md.0
    stays strict JSON (a bare NaN/Infinity token would break every
    standards-compliant consumer of `jbpls --json`)."""
    if arr.size == 0 or arr.dtype.kind not in "iufb":
        return None, None
    lo, hi = float(arr.min()), float(arr.max())
    if arr.dtype.kind == "f" and not (np.isfinite(lo) and np.isfinite(hi)):
        finite = arr[np.isfinite(arr)]        # rare path: NaN/inf present
        if finite.size == 0:
            return None, None
        return float(finite.min()), float(finite.max())
    return lo, hi


def finite_stats(vmin: float, vmax: float, kind: str,
                 size: int) -> tuple[Optional[float], Optional[float]]:
    """The `chunk_stats` contract applied to bounds computed ELSEWHERE
    (device-side reductions, PreshuffledChunk metadata): record only
    finite bounds of ordered dtypes, else (None, None)."""
    if size == 0 or kind not in "iufb":
        return None, None
    if not (math.isfinite(vmin) and math.isfinite(vmax)):
        return None, None
    return float(vmin), float(vmax)


def encode_chunk(arr, codec: str, block: int, *, device_compress: bool = False):
    """Compress ONE chunk whatever its form — numpy ndarray (host path),
    jax.Array (on-device shuffle + D2H overlapping the host LZ stage when
    `device_compress`, else materialized to host first), or a
    `PreshuffledChunk` from an upstream preconditioner (host finishes the
    encode, shuffle skipped). Returns
    (payload, extent_shape, (vmin, vmax), DeviceStats | None) — the ONE
    chunk encode shared by the thread-pool engine's agg jobs and the
    multi-process engine's workers, so payload bytes cannot drift."""
    if isinstance(arr, C.PreshuffledChunk):
        return (C.array_payload_preshuffled(arr, codec), arr.shape,
                finite_stats(arr.vmin, arr.vmax, arr.dtype.kind, arr.size),
                None)
    if C.is_device_array(arr):
        if device_compress:
            payload, ds = C.device_array_payload(arr, codec, block=block)
            kind = np.dtype(arr.dtype).kind
            return (payload, tuple(arr.shape),
                    finite_stats(ds.vmin, ds.vmax, kind, int(arr.size)), ds)
        arr = np.asarray(arr)
    payload = C.array_payload(arr, codec, block=block)
    return payload, arr.shape, chunk_stats(arr), None


def record_compress_counters(rank: int, path: str, codec: str,
                             raw_nbytes: int, payload_len: int, dstats):
    """Fold one encoded chunk's device/lossy accounting into the Darshan
    monitor: on-chip shuffled bytes + overlapped host-LZ seconds (device
    path) and raw-minus-stored bytes for lossy-coded payloads."""
    if dstats is not None and dstats.device_bytes:
        MONITOR.record(rank, path, CTR.COMPRESS_DEVICE_BYTES,
                       inc=float(dstats.device_bytes),
                       tkey=CTR.COMPRESS_OVERLAP_TIME, dt=dstats.overlap_s)
    if C.parse_codec(codec)[0] == "lossy" and payload_len < raw_nbytes:
        MONITOR.record(rank, path, CTR.LOSSY_BYTES_SAVED,
                       inc=float(raw_nbytes - payload_len))


def validate_put_rank(rank: int, n_ranks: int):
    """The put() boundary check — an out-of-range rank must be a clear
    ValueError here, not an opaque IndexError deep in SubfileSet."""
    if not 0 <= rank < n_ranks:
        raise ValueError(
            f"put(rank={rank}) out of range for a writer opened with "
            f"n_ranks={n_ranks} (valid ranks are 0..{n_ranks - 1})")


def build_md_record(step: int, attrs: dict, pending: dict,
                    chunks_json: dict[str, list]) -> dict:
    """The global per-step metadata record written to md.0 — THE one
    definition of the on-disk chunk-table layout and ordering. Shared by
    the sync, async and multi-process writers: byte parity across engines
    (and therefore reader compatibility) depends on every writer building
    its record here."""
    return {
        "step": step,
        "attrs": attrs,
        "vars": {
            name: {"dtype": var["dtype"], "shape": list(var["shape"]),
                   "chunks": sorted(chunks_json[name],
                                    key=lambda c: (c["rank"],
                                                   tuple(c["offset"])))}
            for name, var in pending.items()},
    }


def seal_md_record(md, idx, md_off: int, step: int, blob: bytes,
                   *, fsync_step: bool) -> int:
    """Append one md.0 blob and its crc-sealed md.idx record — the commit
    point of every engine. With `fsync_step` the seal is durable before
    returning (md.0 fsynced BEFORE the idx record exists, so a validated
    idx record always points at durable metadata); otherwise bytes reach
    the OS and the fsync is deferred to close. Returns the new md offset."""
    ts = time.perf_counter()
    with TRACER.span("seal", path=getattr(idx, "path", ""),
                     length=len(blob)):
        md.write(blob)
        crc = zlib.crc32(blob) & 0xFFFFFFFF
        rec = IDX_RECORD.pack(step, md_off, len(blob), crc, 1,
                              time.time_ns(), 0, 0)
        if fsync_step:
            md.fsync()
            idx.write(rec)
            idx.fsync()
        else:
            idx.write(rec)
            md.flush()   # bytes reach the OS; fsync deferred to close
            idx.flush()
    if METRICS.enabled:
        METRICS.observe("seal", time.perf_counter() - ts, nbytes=len(blob),
                        key=getattr(idx, "path", ""))
    return md_off + len(blob)


@dataclasses.dataclass
class StepSnapshot:
    """One step's puts, captured at end_step time — the unit of work handed
    to `_write_step`. The sync writer builds one and writes it inline; the
    async writer deep-copies chunk arrays (`copy=True`) so the producer may
    reuse its buffers immediately, and queues it for the background seal."""
    step: int
    pending: dict[str, dict]
    attrs: dict[str, Any]
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)


def take_step_snapshot(step: Optional[int], pending: dict, attrs: dict, *,
                       copy: bool) -> StepSnapshot:
    """Build one StepSnapshot from a writer's open-step state — the ONE
    place the snapshot contract lives (every engine's `_take_snapshot`
    delegates here, so the {dtype, shape, chunks} structure and the
    `copy=True` deep-copy semantics cannot drift between engines)."""
    if step is None:
        raise RuntimeError("end_step() outside begin_step()")

    def _copy_chunk(arr):
        # only host ndarrays need the deep copy — jax.Arrays are immutable
        # and PreshuffledChunks are minted fresh by the preconditioner, so
        # the producer cannot mutate either after end_step returns
        return np.array(arr) if isinstance(arr, np.ndarray) else arr

    with TRACER.span("snapshot", path=f"step.{step}") as sp:
        if copy:
            pending = {name: {**{k: v for k, v in var.items()
                                 if k != "chunks"},
                              "chunks": [(r, off, _copy_chunk(arr))
                                         for r, off, arr in var["chunks"]]}
                       for name, var in pending.items()}
        sp.length = sum(arr.nbytes for var in pending.values()
                        for _, _, arr in var["chunks"])
    return StepSnapshot(step, pending, dict(attrs))


class BpWriter:
    def __init__(self, path, n_ranks: int, cfg: EngineConfig = EngineConfig()):
        self.path = pathlib.Path(str(path))
        self.path.mkdir(parents=True, exist_ok=True)
        self.cfg = cfg
        self.n_ranks = n_ranks
        self.m = min(cfg.aggregators, max(n_ranks, 1))
        self.pool = WriterPool(cfg.workers)
        ost_pool = None
        if cfg.stripe is not None:
            ost_pool = OstPool(self.path, cfg.n_osts)
            for i in range(self.m):
                with open_file(self.path / f"data.{i}.stripe.json", "w",
                               rank=0) as sf:
                    sf.write(json.dumps(
                        {"stripe_count": cfg.stripe.stripe_count,
                         "stripe_size": cfg.stripe.stripe_size}))
        self.subfiles = SubfileSet(self.path, self.m, stripe=cfg.stripe,
                                   ost_pool=ost_pool)
        self._md = open_file(self.path / "md.0", "wb", rank=0)
        self._idx = open_file(self.path / "md.idx", "wb", rank=0)
        self._md_off = 0
        self._step: Optional[int] = None
        self._pending: dict[str, dict] = {}
        self._attrs: dict[str, Any] = {}
        self._profile: list[dict] = []
        # metrics journal sidecar (metrics.jsonl next to profiling.json):
        # one frame per sealed step while the metrics plane is enabled
        self._journal = (StepJournal(journal_path(self.path))
                         if METRICS.enabled and cfg.profiling else None)

    # ------------------------------------------------------------------ step
    def begin_step(self, step: int):
        if self._step is not None:
            raise RuntimeError(
                f"begin_step({step}) while step {self._step} is still open "
                f"(previous step not closed — call end_step() first)")
        self._step = step
        self._pending = {}

    def set_attribute(self, name: str, value):
        self._attrs[name] = value

    def replace_attributes(self, attrs: dict):
        """Replace the attribute set wholesale. Attributes normally
        ACCUMULATE across steps (each step's md.0 record stores the current
        set); a replaying tool (jbprepack) needs per-step exactness instead
        — what the source step recorded, nothing more."""
        self._attrs = dict(attrs)

    def put(self, name: str, array, *, global_shape: tuple,
            offset: tuple, rank: int, codec: Optional[str] = None):
        """Register one rank's chunk of variable `name` for this step.

        `array` may be a numpy ndarray, a jax.Array (left on-device until
        end_step — the device-compress path shuffles it on-chip), or a
        `PreshuffledChunk` from an upstream preconditioner. `codec`
        overrides the engine codec for THIS variable (e.g. "lossy:1e-3"
        for particle data while fields stay lossless)."""
        if self._step is None:
            raise RuntimeError("put() outside begin/end_step")
        validate_put_rank(rank, self.n_ranks)
        if isinstance(array, C.PreshuffledChunk) or C.is_device_array(array):
            a = array                      # no host materialization here
        else:
            a = np.ascontiguousarray(array)
        gshape = tuple(int(x) for x in global_shape)
        var = self._pending.setdefault(name, {
            "dtype": np.dtype(a.dtype).str, "shape": gshape, "chunks": []})
        if var["shape"] != gshape:
            raise ValueError(
                f"put({name!r}) global_shape {gshape} conflicts with "
                f"{var['shape']} from an earlier put of this step")
        if codec is not None:
            C.parse_codec(codec)           # fail fast on bad specs
            prev = var.get("codec")
            if prev is not None and prev != codec:
                raise ValueError(
                    f"put({name!r}) codec {codec!r} conflicts with {prev!r} "
                    f"from an earlier put of this step")
            var["codec"] = codec
        var["chunks"].append((rank, tuple(int(x) for x in offset), a))

    def _take_snapshot(self, *, copy: bool) -> StepSnapshot:
        """Capture the open step and reset producer-side state. With
        `copy=True` chunk arrays are deep-copied (the async contract: the
        caller may mutate its buffers the moment end_step returns)."""
        snap = take_step_snapshot(self._step, self._pending, self._attrs,
                                  copy=copy)
        self._step = None
        self._pending = {}
        return snap

    def end_step(self) -> dict:
        return self._write_step(self._take_snapshot(copy=False))

    def _write_step(self, snap: StepSnapshot) -> dict:
        """Compress + aggregate + append + seal one snapshot. Must be called
        from ONE thread at a time (the caller thread here; the dedicated
        writer thread in AsyncBpWriter) — md.0/md.idx appends are ordered."""
        step = snap.step
        t0 = time.perf_counter()
        results: dict[str, list[ChunkMeta]] = {n: [] for n in snap.pending}
        lock = threading.Lock()
        errors: list = []
        tcomp_total = [0.0]

        # Coalesce: one job per aggregator compresses its ranks' chunks and
        # issues a SINGLE append (one write syscall per aggregator per step
        # instead of one per chunk — §Perf hillclimb C iteration r6).
        by_agg: dict[int, list] = {}
        n_bytes_raw = 0
        for name, var in snap.pending.items():
            codec = var.get("codec") or self.cfg.codec
            for rank, offset, arr in var["chunks"]:
                n_bytes_raw += arr.nbytes
                agg = aggregator_of(rank, self.n_ranks, self.m)
                by_agg.setdefault(agg, []).append(
                    (name, rank, offset, arr, codec))

        def agg_job(agg, items):
            try:
                tc = time.perf_counter()
                dpath = str(self.path / f"data.{agg}")
                payloads, metas = [], []
                with TRACER.span("compress", path=f"data.{agg}",
                                 rank=agg) as sp:
                    for name, rank, offset, arr, codec in items:
                        payload, shape, stats, dstats = encode_chunk(
                            arr, codec, self.cfg.compression_block,
                            device_compress=self.cfg.device_compress)
                        record_compress_counters(
                            agg, dpath, codec, arr.nbytes, len(payload),
                            dstats)
                        payloads.append(payload)
                        metas.append((name, rank, offset, shape,
                                      len(payload), stats))
                    sp.length = sum(len(p) for p in payloads)
                tcomp = time.perf_counter() - tc
                if METRICS.enabled:
                    METRICS.observe(
                        "compress", tcomp, key=f"data.{agg}",
                        nbytes=sum(len(p) for p in payloads))
                base = self.subfiles.append(agg, b"".join(payloads))
            except Exception as e:   # noqa: BLE001
                errors.append(e)
                return
            with lock:
                off = base
                for name, rank, offset, shape, nb, (vmin, vmax) in metas:
                    results[name].append(ChunkMeta(rank, offset, shape, agg,
                                                   off, nb, vmin, vmax))
                    off += nb
                tcomp_total[0] += tcomp

        for agg, items in by_agg.items():
            self.pool.submit(agg_job, agg, items)
        self.pool.drain()
        if errors:
            raise errors[0]

        # ---- metadata record (md.0), then sealed index record (md.idx) ------
        md_rec = build_md_record(
            step, snap.attrs, snap.pending,
            {name: [c.to_json() for c in results[name]]
             for name in snap.pending})
        blob = json.dumps(md_rec).encode()
        self._md_off = seal_md_record(
            self._md, self._idx, self._md_off, step, blob,
            fsync_step=self.cfg.fsync_policy == "step")

        dt = time.perf_counter() - t0
        prof = {"step": step, "write_s": dt, "compress_s": tcomp_total[0],
                "bytes_raw": n_bytes_raw,
                "bytes_stored": sum(c.nbytes for cl in results.values()
                                    for c in cl),
                "aggregators": self.m}
        prof.update(snap.extra)
        self._profile.append(prof)
        self._journal_frame(step, prof)
        return prof

    def _journal_frame(self, step: int, prof: dict,
                       workers: Optional[dict] = None):
        """Append one metrics.jsonl frame for a sealed step: absolute
        Darshan totals (the journal stores deltas), this process's
        per-step histogram delta, and any per-worker shipped shards.
        Single-threaded by the same contract as `_write_step`."""
        if self._journal is None:
            return
        self._journal.frame(step, prof, MONITOR.report()["total"],
                            METRICS.snapshot(reset=True)["hists"],
                            workers=workers)

    def _profile_doc(self) -> dict:
        return {"engine": "JBP(BP4)", "aggregators": self.m,
                "codec": self.cfg.codec, "steps": self._profile}

    def close(self):
        self.pool.shutdown()
        self.subfiles.fsync_close()
        if self.cfg.fsync_policy != "step":
            self._md.fsync()
            self._idx.fsync()
        self._md.close()
        self._idx.close()
        if self.cfg.profiling:
            with open_file(self.path / "profiling.json", "w", rank=0) as f:
                f.write(json.dumps(self._profile_doc(), indent=1))
        if TRACER.enabled:
            TRACER.dump(self.path / "dxt.json")
        if self._journal is not None:
            # final frame: close-time residuals (fsyncs, profiling.json) —
            # the journal's cumulative stays identical to the live registry
            self._journal_frame(-1, {"final": True})
            self._journal.close()
            self._journal = None


def _box_intersection(coff, cext, sel_off, sel_ext):
    """[lo, hi) overlap of two boxes, or None when they don't intersect."""
    lo = tuple(max(a, b) for a, b in zip(coff, sel_off))
    hi = tuple(min(a + e, b + f) for a, e, b, f in
               zip(coff, cext, sel_off, sel_ext))
    if any(l >= h for l, h in zip(lo, hi)):
        return None
    return lo, hi


class BpReader:
    """Reader with a metadata-only query plane (the paper's "rapid metadata
    extraction" claim, §V):

      * md.idx is scanned once (fixed-size crc-sealed records); md.0 blobs
        are crc-validated up front but JSON-parsed LAZILY per step — opening
        a 10k-step series to read one iteration parses one record,
      * every query below (`var_names`, `iter_chunks`, `chunks_in_box`,
        `var_minmax`, `var_nbytes`, `layout`, `variables`) is answered from
        md.idx/md.0 alone — no `data.*` subfile is ever opened until
        `read_var()` actually needs payload bytes,
      * `read_var` prunes chunks with the same `_box_intersection`
        predicate `chunks_in_box` uses, so an empty-intersection selection
        performs zero payload I/O,
      * `read_var(parallel=N)` fans a multi-chunk read plan out over a
        `ReaderPool` (N worker threads, per-aggregator handle affinity) —
        payload reads hit the M subfiles concurrently and decompression
        overlaps across cores (zlib/bz2 release the GIL). Results are
        byte-identical to the serial path; `parallel` passed to the
        constructor sets the default for every read.
    """

    def __init__(self, path, *, parallel: int = 0, chunk_cache=None):
        self.path = pathlib.Path(str(path))
        self.default_parallel = int(parallel)
        # Service-plane hook: an object with
        #     get_or_fetch(key, fetch, nbytes) -> np.ndarray
        # consulted by `read_chunk` for every decompressed chunk (key =
        # (series, step, var, agg, file_offset) — chunk-granular, exactly
        # what jbpd's LRU cache and request coalescing key on). None (the
        # default) reads and decompresses inline, as ever.
        self.chunk_cache = chunk_cache
        self._blobs: dict[int, bytes] = {}        # step -> validated md.0 blob
        self._meta: dict[int, dict] = {}          # step -> parsed record cache
        self.idx_records: dict[int, dict] = {}    # step -> md.idx fields
        self._data_handles: dict[int, Any] = {}   # agg -> cached payload handle
        self._io_lock = threading.Lock()          # seek+read must be atomic
        self._pool: Optional[ReaderPool] = None   # lazy parallel-read plane
        self._tls = threading.local()             # per-worker handle cache
        self._side_handles: list = []             # every per-thread handle
        self._load_index()

    def _load_index(self):
        """md.idx scan -> md.0 regions; crc-invalid/truncated steps dropped."""
        idx_p = self.path / "md.idx"
        md_p = self.path / "md.0"
        if not idx_p.exists() or not md_p.exists():
            return
        with open_file(idx_p, "rb") as f:
            raw = f.read()
        with open_file(md_p, "rb") as f:
            md = f.read()
        for i in range(0, len(raw) - IDX_SIZE + 1, IDX_SIZE):
            step, off, ln, crc, flags, t_ns, _, _ = IDX_RECORD.unpack_from(raw, i)
            blob = md[off:off + ln]
            if len(blob) != ln or (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
                continue                       # torn/corrupt step -> ignore
            self._blobs[step] = blob
            self.idx_records[step] = {"md_off": off, "md_len": ln,
                                      "flags": flags, "t_ns": t_ns}

    def _record(self, step: int) -> dict:
        rec = self._meta.get(step)
        if rec is None:
            rec = self._meta[step] = json.loads(self._blobs[step])
        return rec

    @property
    def steps(self) -> dict[int, dict]:
        """Eager step->record view (compat with the pre-lazy reader):
        touching it parses every remaining md.0 record."""
        for s in self._blobs:
            self._record(s)
        return self._meta

    def valid_steps(self) -> list[int]:
        return sorted(self._blobs)

    def attributes(self, step: int) -> dict:
        return self._record(step).get("attrs", {})

    def var_names(self, step: int) -> list[str]:
        return sorted(self._record(step)["vars"])

    def var_info(self, step: int, name: str) -> dict:
        return self._record(step)["vars"][name]

    # ------------------------------------------------- metadata query layer
    def iter_chunks(self, step: int, name: str):
        """Lazily yield one ChunkMeta per stored block of `name`."""
        for ch in self.var_info(step, name)["chunks"]:
            yield ChunkMeta.from_json(ch)

    def chunks_in_box(self, step: int, name: str, offset: tuple,
                      extent: tuple) -> list[ChunkMeta]:
        """The read plan: chunk metas intersecting the selection box."""
        sel_off, sel_ext = tuple(offset), tuple(extent)
        return [c for c in self.iter_chunks(step, name)
                if _box_intersection(c.offset, c.extent, sel_off, sel_ext)]

    def _accum_var(self, step: int, name: str,
                   layout: Optional[dict] = None) -> dict:
        """Single chunk-table walk for one (step, name): byte totals, chunk
        count, min/max fold, and (when `layout` is passed) aggregator
        occupancy — THE one place the accumulation semantics live."""
        info = self.var_info(step, name)
        itemsize = np.dtype(info["dtype"]).itemsize
        raw = stored = chunks = 0
        lo: Optional[float] = None
        hi: Optional[float] = None
        stats_ok = True
        for c in self.iter_chunks(step, name):
            n = 1
            for e in c.extent:
                n *= int(e)
            raw += n * itemsize
            stored += c.nbytes
            chunks += 1
            if layout is not None:
                d = layout.setdefault(c.agg, {"chunks": 0, "bytes": 0,
                                              "end": 0})
                d["chunks"] += 1
                d["bytes"] += c.nbytes
                d["end"] = max(d["end"], c.file_offset + c.nbytes)
            if c.vmin is None:
                stats_ok = False
            else:
                lo = c.vmin if lo is None else min(lo, c.vmin)
                hi = c.vmax if hi is None else max(hi, c.vmax)
        return {"info": info, "raw": raw, "stored": stored, "chunks": chunks,
                "minmax": (lo, hi) if stats_ok and lo is not None else None}

    def var_minmax(self, step: int, name: str) -> Optional[tuple]:
        """Global (min, max) from the chunk statistics alone; None when any
        block lacks finite stats (pre-stats series, empty/non-numeric/
        all-NaN blocks)."""
        return self._accum_var(step, name)["minmax"]

    def var_nbytes(self, step: int, name: str) -> tuple[int, int]:
        """(raw, stored) bytes — raw derived from extents x itemsize,
        stored summed from the chunk table. ratio = raw / stored."""
        a = self._accum_var(step, name)
        return a["raw"], a["stored"]

    def scan(self, steps=None, name_filter=None) -> dict:
        """ONE pass over the chunk tables producing every aggregate the
        listing tools need (re-walking md.0 per query would multiply the
        cost of the thing that exists to be fast):

          variables: name -> {dtype, shape, steps, chunks_per_step,
                              shape_varies, raw, stored}
                     (shape/chunks_per_step are the LATEST step's;
                      shape_varies flags series that change shape)
          per_step:  [{step, t_ns, n_vars, raw, stored}]
          layout:    agg -> {chunks, bytes, end}   (subfile occupancy)
          minmax:    name -> (lo, hi) over ALL scanned steps, or None when
                     any block lacks finite stats

        `name_filter` (a predicate on variable names) restricts EVERY
        aggregate consistently — per-step totals, layout and minmax all
        cover exactly the filtered variables.
        """
        variables: dict[str, dict] = {}
        minmax: dict[str, Optional[tuple]] = {}
        layout: dict[int, dict] = {}
        per_step = []
        for step in (self.valid_steps() if steps is None else steps):
            step_raw = step_stored = 0
            names = self.var_names(step)
            if name_filter is not None:
                names = [n for n in names if name_filter(n)]
            for name in names:
                a = self._accum_var(step, name, layout)
                step_raw += a["raw"]
                step_stored += a["stored"]
                shape = tuple(a["info"]["shape"])
                v = variables.setdefault(name, {
                    "dtype": a["info"]["dtype"], "shape": shape,
                    "steps": [], "chunks_per_step": a["chunks"],
                    "shape_varies": False, "raw": 0, "stored": 0})
                if v["steps"] and v["shape"] != shape:
                    v["shape_varies"] = True
                v["shape"] = shape
                v["chunks_per_step"] = a["chunks"]
                v["steps"].append(step)
                v["raw"] += a["raw"]
                v["stored"] += a["stored"]
                if a["minmax"] is None:
                    minmax[name] = None
                elif name not in minmax:
                    minmax[name] = a["minmax"]
                elif minmax[name] is not None:
                    lo, hi = a["minmax"]
                    plo, phi = minmax[name]
                    minmax[name] = (min(plo, lo), max(phi, hi))
            per_step.append({"step": step,
                             "t_ns": self.idx_records[step]["t_ns"],
                             "n_vars": len(names), "raw": step_raw,
                             "stored": step_stored})
        return {"variables": variables, "per_step": per_step,
                "layout": layout, "minmax": minmax}

    def layout(self, steps=None) -> dict[int, dict]:
        """Per-aggregator subfile occupancy {agg: {chunks, bytes, end}},
        reconstructed from chunk tables — data.* files are never touched."""
        return self.scan(steps)["layout"]

    def variables(self, steps=None) -> dict[str, dict]:
        """Union of variables across `steps` (default: all valid steps):
        name -> {dtype, shape, steps, chunks_per_step, raw, stored}."""
        return self.scan(steps)["variables"]

    def _data_file(self, agg: int):
        """Cached per-aggregator payload handle (InstrumentedFile for plain
        subfiles, read-mode StripedFile for striped layouts) — a multi-chunk
        read_var no longer reopens data.<agg> once per chunk."""
        f = self._data_handles.get(agg)
        if f is not None:
            return f
        f = self._open_data(agg)
        self._data_handles[agg] = f
        return f

    def _open_data(self, agg: int):
        """Open a fresh payload handle for aggregator `agg` (plain subfile
        or striped layout)."""
        plain = self.path / f"data.{agg}"
        if plain.exists():
            f = open_file(plain, "rb")
        else:
            # striped layout: reconstruct via a read-mode StripedFile
            n_osts = len(sorted(self.path.glob("ost*")))
            objs = sorted(self.path.glob(f"ost*/data.{agg}.obj"))
            if not objs:
                raise FileNotFoundError(f"no data for aggregator {agg} "
                                        f"under {self.path}")
            # stripe params are discoverable from the writer config file; for
            # robustness store them alongside: meta sidecar
            side = self.path / f"data.{agg}.stripe.json"
            if side.exists():
                with open_file(side, "r") as sf:
                    cfgd = json.loads(sf.read())
            else:
                cfgd = {"stripe_count": len(objs),
                        "stripe_size": C.DEFAULT_BLOCK}
            pool = OstPool(self.path, n_osts)
            f = StripedFile(pool, f"data.{agg}",
                            StripeConfig(cfgd["stripe_count"],
                                         cfgd["stripe_size"]),
                            rank=0, mode="r")
        return f

    def _read_payload(self, agg: int, foff: int, nbytes: int) -> bytes:
        f = self._data_file(agg)
        if isinstance(f, StripedFile):
            return f.read(foff, nbytes)      # StripedFile locks internally
        with self._io_lock:
            f.seek(foff)
            return f.read(nbytes)

    def _read_payload_local(self, agg: int, foff: int, nbytes: int) -> bytes:
        """Payload read through a PER-THREAD handle — the ReaderPool path.
        No lock is taken around seek+read: every (worker thread, aggregator)
        pair owns its handle outright, which is the handle-affinity contract
        (affinity routing makes the common case one handle per subfile)."""
        cache = getattr(self._tls, "handles", None)
        if cache is None:
            cache = self._tls.handles = {}
        f = cache.get(agg)
        if f is None:
            f = cache[agg] = self._open_data(agg)
            with self._io_lock:
                self._side_handles.append(f)
        if isinstance(f, StripedFile):
            return f.read(foff, nbytes)
        f.seek(foff)
        return f.read(nbytes)

    def _get_pool(self, n: int) -> ReaderPool:
        """Lazily create (or grow, in place) the parallel-read plane.
        Creation is locked and growth never recreates the pool, so
        concurrent read_var callers share one plane safely."""
        with self._io_lock:
            if self._pool is None:
                self._pool = ReaderPool(n)
            elif self._pool.n_workers < n:
                self._pool.ensure(n)
            return self._pool

    def close(self):
        """Release the reader pool and every cached payload handle
        (metadata stays queryable; a later read reopens lazily)."""
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown()
        with self._io_lock:
            side, self._side_handles = self._side_handles, []
        self._tls = threading.local()
        handles, self._data_handles = self._data_handles, {}
        for f in list(handles.values()) + side:
            f.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    def _fetch_chunk(self, ch: ChunkMeta, dtype, local: bool) -> np.ndarray:
        """Uncached read+decompress of one stored chunk (`local=True` uses
        the per-thread handle — the ReaderPool path)."""
        read = self._read_payload_local if local else self._read_payload
        payload = read(ch.agg, ch.file_offset, ch.nbytes)
        return C.payload_to_array(payload, dtype, ch.extent)

    def read_chunk(self, step: int, name: str, ch: ChunkMeta, *,
                   dtype=None, local: bool = False) -> np.ndarray:
        """Decompressed array of ONE stored chunk — the chunk-granular read
        entrypoint. When a `chunk_cache` is installed (the jbpd service
        plane) the chunk is looked up / fetched through it, keyed by
        (series, step, var, agg, file_offset): concurrent identical
        requests share one payload read + decompress, repeats are memory
        hits. Cached arrays are read-only; callers needing to mutate copy."""
        if dtype is None:
            dtype = np.dtype(self.var_info(step, name)["dtype"])
        if self.chunk_cache is None:
            return self._fetch_chunk(ch, dtype, local)
        key = (str(self.path), step, name, ch.agg, ch.file_offset)
        n = int(np.prod(ch.extent, dtype=np.int64)) * dtype.itemsize
        return self.chunk_cache.get_or_fetch(
            key, lambda: self._fetch_chunk(ch, dtype, local), n)

    def _scatter_chunk(self, out: np.ndarray, dtype, sel_off: tuple,
                       step: int, name: str, ch: ChunkMeta, box, local: bool):
        """Read one chunk (through `read_chunk`, so the service cache sees
        every read path), scatter its intersection into `out`. The unit of
        work of both read paths; `local=True` uses the per-thread handle
        (ReaderPool workers), else the shared locked handle."""
        lo, hi = box
        arr = self.read_chunk(step, name, ch, dtype=dtype, local=local)
        src = tuple(slice(l - o, h - o)
                    for l, o, h in zip(lo, ch.offset, hi))
        dst = tuple(slice(l - o, h - o)
                    for l, o, h in zip(lo, sel_off, hi))
        out[dst] = arr[src]

    def read_var(self, step: int, name: str,
                 offset: Optional[tuple] = None,
                 extent: Optional[tuple] = None, *,
                 parallel: Optional[int] = None) -> np.ndarray:
        """Assemble a box selection (default: the full global array).

        `parallel=N` (default: the constructor's `parallel`) fans the
        chunk plan out over N ReaderPool workers keyed by aggregator id —
        bytes returned are identical to the serial path; chunks of a step
        cover disjoint boxes, so the scatters never race."""
        n = self.default_parallel if parallel is None else int(parallel)
        info = self.var_info(step, name)
        dtype = np.dtype(info["dtype"])
        gshape = tuple(info["shape"])
        sel_off = tuple(offset) if offset is not None else (0,) * len(gshape)
        sel_ext = tuple(extent) if extent is not None else gshape
        out = np.zeros(sel_ext, dtype=dtype)
        plan = []
        for ch in self.iter_chunks(step, name):
            box = _box_intersection(ch.offset, ch.extent, sel_off, sel_ext)
            if box is not None:
                plan.append((ch, box))
        if n > 1 and len(plan) > 1:
            pool = self._get_pool(min(n, len(plan)))
            # per-call batch: concurrent read_var callers on one reader
            # (e.g. restore_sharded fetchers) each wait on — and receive
            # the errors of — exactly their own chunk tasks
            batch = pool.batch()
            for ch, box in plan:
                pool.submit(ch.agg, self._scatter_chunk, out, dtype, sel_off,
                            step, name, ch, box, True, batch=batch)
            pool.drain_batch(batch)
        else:
            for ch, box in plan:
                self._scatter_chunk(out, dtype, sel_off, step, name, ch, box,
                                    False)
        return out
