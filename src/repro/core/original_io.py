"""BIT1 'Original I/O' baseline (paper §IV, Figs 2-5, Table II).

The pre-openPMD BIT1 writes, per diagnostic dump, one small text .dat file
per rank (fprintf-style: many tiny formatted writes, open/close per dump)
and per checkpoint one binary .dmp file per rank. File count grows O(ranks),
file size shrinks O(1/ranks), and metadata ops dominate — the pathology the
paper measures with Darshan and then eliminates. We reproduce it faithfully
so the benchmarks have the paper's own baseline to beat.
"""
from __future__ import annotations

import pathlib

import numpy as np

from repro.core.darshan import open_file


def write_dat(dirpath, rank: int, step: int, arrays: dict[str, np.ndarray],
              values_per_line: int = 8) -> pathlib.Path:
    """One diagnostic snapshot, one rank: formatted text, many small writes."""
    d = pathlib.Path(str(dirpath))
    d.mkdir(parents=True, exist_ok=True)
    p = d / f"diag_{step:07d}_r{rank:05d}.dat"
    with open_file(p, "w", rank=rank) as f:
        for name, arr in arrays.items():
            flat = np.asarray(arr).ravel()
            f.write(f"# {name} n={flat.size}\n")
            for i in range(0, flat.size, values_per_line):
                line = " ".join(f"{float(v):.6e}" for v in
                                flat[i:i + values_per_line])
                f.write(line + "\n")          # fprintf-per-line pathology
    return p


def write_dmp(dirpath, rank: int, step: int,
              arrays: dict[str, np.ndarray]) -> pathlib.Path:
    """One checkpoint, one rank: raw binary, one file per rank."""
    d = pathlib.Path(str(dirpath))
    d.mkdir(parents=True, exist_ok=True)
    p = d / f"state_{step:07d}_r{rank:05d}.dmp"
    with open_file(p, "wb", rank=rank) as f:
        for name, arr in arrays.items():
            a = np.ascontiguousarray(arr)
            hdr = f"{name}|{a.dtype.str}|{','.join(map(str, a.shape))}\n"
            f.write(hdr.encode())
            f.write(a.tobytes())
        f.fsync()
    return p


def read_dmp(path, rank: int = 0) -> dict[str, np.ndarray]:
    out = {}
    with open_file(path, "rb", rank=rank) as f:
        data = f.read()
    pos = 0
    while pos < len(data):
        nl = data.index(b"\n", pos)
        name, dt, shp = data[pos:nl].decode().split("|")
        shape = tuple(int(x) for x in shp.split(",")) if shp else ()
        n = int(np.prod(shape)) if shape else 1
        dtype = np.dtype(dt)
        start = nl + 1
        end = start + n * dtype.itemsize
        out[name] = np.frombuffer(data[start:end], dtype=dtype).reshape(shape)
        pos = end
    return out
