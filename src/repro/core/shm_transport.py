"""Zero-copy shared-memory chunk transport for the parallel write plane.

`ParallelBpWriter` (PR 3) ships every chunk to its writer process by
pickling the ndarray down a `multiprocessing` queue: that is one
serialize pass plus a pipe write in the coordinator and a pipe read plus
a deserialize pass in the worker — three-plus copies of every payload
byte, all through 64 KiB pipe windows. On multi-MiB chunks the pickle
copy, not the disk, is what caps aggregate throughput (ROADMAP; Huebl et
al. on in-transit data reduction).

`ShmRing` replaces that with ONE memcpy into a per-worker POSIX
shared-memory ring buffer:

    coordinator                            worker w
    -----------                            --------
    write_array(arr)                       view(hdr) -> ndarray over the
      -> bump-alloc a pow2 slot                ring's mmap (ZERO copies;
      -> single np.copyto into the ring        compression reads straight
      -> ShmHeader(offset, dtype, shape)       from shared pages)
         down the control queue            ...ack "prepared"
    free(offset)  <------- the ack is the free-list: slots are
                           reclaimed only after the step resolved

Allocation is a classic single-producer ring: slots are powers of two
(>= `min_slot`), allocated at `head`, freed strictly FIFO at the tail
(the deque of live segments). When a slot would run off the end of the
ring a pad segment covers the wasted tail and allocation wraps to 0 —
pads are reclaimed transparently when the FIFO free sweeps past them.
A payload that cannot fit (oversized, or the ring is full of in-flight
steps) gets `None` back and the caller falls back to the pickle path —
the transport degrades, it never blocks or fails.

Crash semantics are the write plane's own: slot contents are stable from
`write_array` until `free`, and the coordinator frees only when the
step's ack arrived (prepared OR error) or the step aborted. A worker
SIGKILLed while a slot is in flight therefore corrupts nothing — the
step was never committed, exactly a torn shard — and the ring itself is
unlinked by the owner's `close()`/finalizer, so no /dev/shm leak even on
abnormal exit.
"""
from __future__ import annotations

import secrets
import threading
from collections import deque
from multiprocessing import shared_memory
from typing import NamedTuple, Optional

import numpy as np

from repro.core.dxt import TRACER
from repro.core.metrics import METRICS

MIN_SLOT = 4096                      # one page: below this, pickle wins anyway
DEFAULT_RING_BYTES = 64 * 1024 ** 2  # per-worker ring; ~2 steps of 8x4MiB ranks

# serializes the attach-side resource-tracker register suppression below:
# two threads attaching concurrently (jbpd clients attach per-connection
# response rings) would otherwise race the save/restore and could leave the
# no-op register installed process-wide
_ATTACH_LOCK = threading.Lock()


class ShmHeader(NamedTuple):
    """What travels down the control queue INSTEAD of the ndarray."""
    offset: int          # byte offset of the slot in the ring
    nbytes: int          # payload bytes (slot is the pow2 roundup)
    dtype: str           # numpy dtype.str
    shape: tuple         # chunk shape


def validate_transport(transport: str) -> str:
    """The one accepted-spelling check for every constructor that takes a
    `transport=` (Series, WriterPlane, ParallelBpWriter) — a transport the
    plane does not speak must fail identically everywhere."""
    if transport not in ("shm", "pickle"):
        raise ValueError(f"unknown transport {transport!r} "
                         "(expected 'shm' or 'pickle')")
    return transport


def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length() if n > 1 else 1


class ShmRing:
    """Power-of-two-slot ring buffer in one POSIX shared-memory segment.

    One ring per writer worker; the COORDINATOR is the only allocator
    (`alloc`/`write_array`/`free`), the WORKER only maps read views
    (`view`). Frees must arrive in allocation order — they do, because
    the plane keeps at most one step in flight per worker and a step's
    slots are allocated and resolved together.
    """

    def __init__(self, capacity: int = DEFAULT_RING_BYTES, *,
                 name: Optional[str] = None, create: bool = True,
                 min_slot: int = MIN_SLOT):
        if create:
            capacity = _pow2_ceil(max(int(capacity), min_slot))
            name = name or f"jbp-ring-{secrets.token_hex(8)}"
            self._shm = shared_memory.SharedMemory(
                name=name, create=True, size=capacity)
            # prefault: touch one byte per page so tmpfs allocates the whole
            # ring NOW (ring creation precedes the ready handshake, off the
            # step path) — otherwise the first step of every fresh ring pays
            # a page fault per 4 KiB of payload and the transport benchmarks
            # its own cold start instead of its steady state
            np.frombuffer(self._shm.buf, np.uint8)[::4096] = 0
        else:
            # CPython < 3.13 registers ATTACHED segments with the resource
            # tracker too. Spawned workers share the coordinator's tracker,
            # so an attach-register is a harmless set re-add — but a worker
            # must NOT unregister (that would strip the owner's entry and
            # defeat abnormal-exit cleanup) and must not let a private
            # tracker unlink the ring at worker exit. Suppressing the
            # register during attach is the one behavior that is correct in
            # both topologies; the owner's registration stays authoritative.
            from multiprocessing import resource_tracker
            with _ATTACH_LOCK:
                real_register = resource_tracker.register
                resource_tracker.register = lambda *a, **k: None
                try:
                    self._shm = shared_memory.SharedMemory(name=name)
                finally:
                    resource_tracker.register = real_register
            # populate this process's page table for the whole mapping (a
            # read suffices: the owner already allocated the pages) — the
            # attach side of the same cold-start avoidance as above
            int(np.frombuffer(self._shm.buf, np.uint8)[::4096].sum())
        self.capacity = self._shm.size
        self.min_slot = min_slot
        self._owner = create
        self._head = 0
        # live segments in allocation order: (offset, slot_len, is_pad)
        self._segments: deque[tuple[int, int, bool]] = deque()
        self._unlinked = False

    @classmethod
    def attach(cls, name: str, *, min_slot: int = MIN_SLOT) -> "ShmRing":
        """Map an EXISTING ring by name from a process that is NOT a child
        of the owner — the jbpd client topology: the daemon owns per-client
        response rings, and an unrelated local process attaches to read its
        responses. The same register-suppression as the worker attach path
        applies (an unrelated process has its own resource tracker, which
        must not unlink the daemon's ring when the client exits); the
        owner's registration stays the abnormal-exit cleanup. Raises
        FileNotFoundError when no such segment exists (daemon gone or the
        ring already unlinked) — callers fall back to socket framing."""
        return cls(name=name, create=False, min_slot=min_slot)

    @property
    def name(self) -> str:
        return self._shm.name

    # ------------------------------------------------------------ coordinator
    def slot_len(self, nbytes: int) -> int:
        return _pow2_ceil(max(int(nbytes), self.min_slot))

    def free_bytes(self) -> int:
        return self.capacity - sum(s for _, s, _ in self._segments)

    def alloc(self, nbytes: int) -> Optional[int]:
        """Reserve a slot for `nbytes`; returns its offset, or None when it
        cannot fit (caller falls back to pickling the array)."""
        slot = self.slot_len(nbytes)
        if slot > self.capacity:
            return None
        if not self._segments:
            self._head = 0                      # empty ring: defragment free
        tail = self._segments[0][0] if self._segments else None
        if tail is None or tail < self._head:
            # live region (if any) is [tail, head): free space is the tail
            # end [head, capacity) then the wrapped start [0, tail)
            if self._head + slot <= self.capacity:
                off, self._head = self._head, self._head + slot
                self._segments.append((off, slot, False))
                return off
            if tail is not None and slot < tail:
                # wrap: pad out the unusable tail so FIFO frees stay aligned
                self._segments.append(
                    (self._head, self.capacity - self._head, True))
                self._segments.append((0, slot, False))
                self._head = slot
                return 0
            return None
        # live region wraps [tail, capacity) + [0, head) — or the ring is
        # exactly full (tail == head): free space is [head, tail), kept
        # strictly short of tail so full never aliases empty
        if self._head + slot < tail:
            off, self._head = self._head, self._head + slot
            self._segments.append((off, slot, False))
            return off
        return None

    def write_array(self, arr: np.ndarray) -> Optional[ShmHeader]:
        """One memcpy of `arr` into a fresh slot; the returned header is all
        that crosses the process boundary. None = fall back to pickle."""
        off = self.alloc(arr.nbytes)
        if off is None:
            return None
        t0 = (TRACER.now() if TRACER.enabled or METRICS.enabled else 0.0)
        dst = np.ndarray(arr.shape, dtype=arr.dtype,
                         buffer=self._shm.buf, offset=off)
        np.copyto(dst, arr)
        del dst                                 # release the exported buffer
        if TRACER.enabled:
            TRACER.record(0, self.name, "shm_write", off, arr.nbytes,
                          t0, TRACER.now())
        if METRICS.enabled:
            METRICS.observe("shm_write", TRACER.now() - t0,
                            nbytes=arr.nbytes, key=self.name)
        return ShmHeader(off, arr.nbytes, arr.dtype.str, tuple(arr.shape))

    def free(self, offset: int):
        """Release the OLDEST live slot (must match `offset`) plus any pad
        segments in front of it — the FIFO discipline of the ack free-list."""
        while self._segments and self._segments[0][2]:
            self._segments.popleft()
        if not self._segments or self._segments[0][0] != offset:
            raise ValueError(
                f"out-of-order free: offset {offset} is not the ring tail "
                f"({self._segments[0][0] if self._segments else 'empty'})")
        self._segments.popleft()
        while self._segments and self._segments[0][2]:
            self._segments.popleft()
        if not self._segments:
            self._head = 0

    # ----------------------------------------------------------------- worker
    def view(self, hdr: ShmHeader) -> np.ndarray:
        """Read-only ndarray over the slot — compression reads shared pages
        directly, no copy. The view MUST be dropped before close()."""
        a = np.ndarray(hdr.shape, dtype=np.dtype(hdr.dtype),
                       buffer=self._shm.buf, offset=hdr.offset)
        a.flags.writeable = False
        return a

    # --------------------------------------------------------------- lifetime
    def close(self):
        try:
            self._shm.close()
        except BufferError:
            # a live view pins the mmap; the fd still goes away with the
            # process, and the owner's unlink below is what matters
            pass

    def unlink(self):
        if self._unlinked:
            return
        self._unlinked = True
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
        if self._owner:
            self.unlink()


def unlink_rings(rings):
    """Finalizer target: unlink every ring (idempotent, exception-free) —
    registered via `weakref.finalize` by ring owners so an abnormal exit
    (unhandled exception, GC of a leaked plane) still reclaims /dev/shm."""
    for r in rings:
        try:
            r.close()
            r.unlink()
        except Exception:                       # noqa: BLE001 — teardown
            pass
