"""SST-style streaming engine — the paper's stated future work (§VI):
"the ADIOS2 SST engine enables the direct connection of data producers and
consumers ... for in-situ processing, analysis, and visualization".

`SstStream` is the JBP-native analogue: a bounded in-memory step queue with
the same put()/step protocol as BpWriter, so a Series can stream iterations
to an in-process consumer (live diagnostics, training-metric dashboards)
WITHOUT touching the filesystem. Back-pressure blocks the producer when the
consumer lags (queue_depth), exactly like SST's reliable mode.

Tee-to-disk: pass `tee=AsyncBpWriter(...)` and every streamed step is ALSO
forwarded chunk-for-chunk into the write pipeline from the same snapshot —
streaming consumers and BP4 persistence share one capture of the data, and
because the tee's end_step is non-blocking the producer still only pays the
in-memory assembly cost. `close()` drains and closes the tee, so a closed
stream implies the teed series is durable.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterator, Optional

import numpy as np

# consumers blocked in steps() re-check the closed flag at this cadence, so
# close() never has to force a sentinel through a full queue
_POLL_S = 0.05


class SstStream:
    def __init__(self, queue_depth: int = 4, *, tee=None):
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._step: Optional[int] = None
        self._pending: dict[str, dict] = {}
        self._closed = threading.Event()
        self._tee = tee                  # BpWriter-protocol sink (async ok)

    # ------------------------------------------------------------- producer
    def begin_step(self, step: int):
        if self._step is not None:
            raise RuntimeError(f"begin_step({step}) while step "
                               f"{self._step} is still open — call "
                               f"end_step() first")
        self._step = step
        self._pending = {}

    def put(self, name: str, array: np.ndarray, *, global_shape=None,
            offset=None, rank: int = 0):
        if self._step is None:
            raise RuntimeError(
                "put() outside a step — call begin_step() first")
        a = np.asarray(array)
        var = self._pending.setdefault(name, {
            "dtype": a.dtype, "global_shape": tuple(global_shape or a.shape),
            "chunks": []})
        var["chunks"].append((tuple(offset or (0,) * a.ndim), rank, a))

    def end_step(self):
        """Assemble the step's variables and hand them to the consumer
        (blocks when the consumer is queue_depth behind). The same snapshot
        feeds the tee writer, if any."""
        step = self._step
        out: dict[str, np.ndarray] = {}
        for name, var in self._pending.items():
            g = np.zeros(var["global_shape"], var["dtype"])
            for off, _rank, arr in var["chunks"]:
                sl = tuple(slice(o, o + s) for o, s in zip(off, arr.shape))
                g[sl] = arr
            out[name] = g
        tee_exc = None
        if self._tee is not None:
            try:
                self._tee.begin_step(step)
                for name, var in self._pending.items():
                    for off, rank, arr in var["chunks"]:
                        self._tee.put(name, arr,
                                      global_shape=var["global_shape"],
                                      offset=off, rank=rank)
                self._tee.end_step()
            except BaseException as e:     # noqa: BLE001
                tee_exc = e                # persistence failed — stream on
        self._q.put((step, out))
        self._step = None
        self._pending = {}
        if tee_exc is not None:
            # the consumer got its step and the stream stays usable; the
            # producer still learns that persistence is broken
            raise tee_exc

    def close(self):
        """End the stream. ALWAYS completes, even with a full queue and no
        consumer draining: the sentinel is best-effort (a blocking put here
        deadlocked producers whose consumer had died) — consumers blocked in
        steps() observe the closed flag by polling instead."""
        self._closed.set()
        try:
            self._q.put_nowait(None)       # wake an already-waiting consumer
        except queue.Full:
            pass                           # steps() polls _closed; no deadlock
        if self._tee is not None:
            # AsyncBpWriter.close() drains, always completes its cleanup
            # (thread + file handles) and only then raises any write error
            self._tee.close()

    # ------------------------------------------------------------- consumer
    def steps(self, timeout: Optional[float] = None) -> Iterator[tuple]:
        """Yield (step, vars) until the stream closes. `timeout` bounds the
        idle wait between steps: when nothing arrives for `timeout` seconds
        the iterator ENDS (it does not leak queue.Empty), so a consumer can
        bail out of a stalled producer cleanly."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._closed.is_set() and self._q.empty():
                return
            wait = _POLL_S
            if deadline is not None:
                wait = min(wait, deadline - time.monotonic())
                if wait <= 0:
                    return                 # idle past timeout -> clean end
            try:
                item = self._q.get(timeout=max(wait, 1e-3))
            except queue.Empty:
                continue
            if item is None:
                return
            yield item
            if timeout is not None:        # idle timeout is per-step
                deadline = time.monotonic() + timeout


def attach_consumer(stream: SstStream, fn: Callable[[int, dict], Any],
                    *, daemon: bool = True) -> threading.Thread:
    """Run `fn(step, vars)` on every streamed step in a background thread.

    A raising `fn` must not wedge the pipeline: the producer blocks in
    `end_step` whenever the bounded queue is full, so a silently-dead
    consumer thread would deadlock it. On the first exception the error is
    recorded on the returned thread (`t.error`), later steps are DRAINED
    and discarded until the stream closes, and the caller discovers the
    failure after join() by checking `t.error`.
    """
    def loop():
        try:
            for step, data in stream.steps():
                fn(step, data)
        except BaseException as e:         # noqa: BLE001 — surfaced via t.error
            t.error = e
            for _ in stream.steps():       # keep the producer unblocked
                pass

    t = threading.Thread(target=loop, daemon=daemon)
    t.error = None
    t.start()
    return t
