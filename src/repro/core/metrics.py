"""The metrics plane: fixed-log2-bucket latency/size histograms.

The Darshan counters (`repro.core.darshan`) answer *how much* I/O ran and
the DXT traces (`repro.core.dxt`) answer *when each op* ran — but neither
gives aggregated DISTRIBUTIONS over time, which is what actually exposes
stragglers and regressions (raw counters average the tail away; raw
traces are unbounded and post-hoc). `MetricsRegistry` is the third layer:

  * every observed op lands in a pair of FIXED log2-bucket histograms —
    latency (microsecond-resolution, `NB_LAT` buckets) and size (bytes,
    `NB_SIZE` buckets). Bucket `i` covers `(2^(i-1), 2^i]` units with
    bucket 0 = `<= 1` unit and the top bucket open-ended, so two
    processes' histograms merge by plain element-wise addition and
    percentiles are DETERMINISTIC functions of the counts (p50/p95/p99
    are the upper edge of the bucket holding that rank — identical
    whether computed live, from a shipped snapshot, or from a journal
    read back days later). `max`/`sum`/`count` are tracked exactly.
  * recording is LOCK-FREE per thread (the DxtTracer discipline): each
    thread owns a shard registered once under the lock; `observe()` is a
    tls lookup + dict bump. Disabled = one attribute load + branch per
    op — the hot paths check `METRICS.enabled` before touching anything
    (`bench_darshan_costs` holds the write path to the same <=5% budget
    as DXT with metrics recording ON).
  * `snapshot()`/`merge()` follow the same epoch-rebase discipline as
    `DarshanMonitor`: every cell stamps its first/last observation on the
    process-private perf_counter clock, and `snapshot()` rebases them to
    wall time via a paired (time.time, perf_counter) epoch — merged
    first/last times are comparable across processes. `snapshot(
    reset=True)` ships a per-step DELTA and retires it into a local
    cumulative, so the live `merged()` view never loses history to the
    journal (sum over journal frames == live totals, exactly — the
    jbpstat/jbpd parity contract).

On top of the registry:

  * `StepJournal` — the persistent `metrics.jsonl` sidecar (one JSON
    frame per committed step/save, next to `profiling.json`): counter
    deltas + per-step histogram cells + per-worker shards shipped home
    on the existing "prepared"/"finished" ack paths. `load_journal`
    reads it back; `repro.tools.jbpstat` analyzes it.
  * `straggler_report` / `RollingBaseline` — the anomaly detector: per
    key (subfile / OST path / worker) p99-vs-median-of-peers ratio, plus
    a rolling EWMA baseline per key so a *newly* slow key is flagged
    even when every peer degrades with it. Surfaced in `jbpd --watch`
    frames, `--io-report`, and the journal.
  * `to_prometheus` — Prometheus text-exposition (v0.0.4) rendering of
    the histograms + Darshan counters (`jbp_*` families), served by the
    jbpd `metrics` op and its `--metrics-port` HTTP shim so standard
    scrapers work.

Enable programmatically (`METRICS.enable()`) or via the environment
(`JBP_METRICS=1`, inherited by spawned writer workers).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Iterable, Optional

#: latency buckets: microseconds, log2 — bucket i covers (2^(i-1), 2^i] us,
#: bucket 0 is <=1us, bucket NB_LAT-1 is everything past ~2^30 us (~18 min)
NB_LAT = 32
#: size buckets: bytes, log2 — same scheme, top bucket past 2^38 B (256 GiB)
NB_SIZE = 40
LAT_UNIT_S = 1e-6                       # one latency bucket unit, in seconds

#: the observation vocabulary (mirrors the DXT span/POSIX ops that feed it);
#: free-form ops are accepted — this tuple is documentation + test surface
KNOWN_OPS = ("read", "write", "fsync", "compress", "seal", "transport",
             "prepare", "commit", "shm_write", "cache_fetch", "serve",
             "read_task", "device_shuffle")


def bucket_index(x: int, nb: int) -> int:
    """Log2 bucket of a non-negative integer quantity: 0 for x<=1, else
    bit_length(x-1) clamped to the top bucket — so bucket i's upper edge
    is exactly 2^i and edges are shared by every producer."""
    if x <= 1:
        return 0
    return min(nb - 1, (x - 1).bit_length())


def bucket_le(i: int) -> int:
    """Inclusive upper edge (in units) of bucket i: 2^i."""
    return 1 << i


def quantile_from_buckets(counts: Iterable[int], q: float) -> Optional[int]:
    """The upper edge (in units) of the bucket containing rank ceil(q*n) —
    the ONE deterministic percentile read every consumer (live registry,
    journal, jbpstat, Prometheus) shares. None when the histogram is
    empty."""
    counts = list(counts)
    total = sum(counts)
    if total <= 0:
        return None
    rank = max(1, int(q * total + 0.999999))     # ceil without float drama
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum >= rank:
            return bucket_le(i)
    return bucket_le(len(counts) - 1)


# ----------------------------------------------------------------- cell math
def new_cell() -> dict:
    """One (op, key) histogram cell in its wire/JSON form."""
    return {"count": 0, "sum_s": 0.0, "max_s": 0.0, "sum_b": 0, "max_b": 0,
            "lat": [0] * NB_LAT, "size": [0] * NB_SIZE,
            "t0": None, "t1": None}


def merge_cell(dst: dict, src: dict):
    """Element-wise fold of one cell into another (both wire-form)."""
    dst["count"] += src.get("count", 0)
    dst["sum_s"] += src.get("sum_s", 0.0)
    dst["max_s"] = max(dst["max_s"], src.get("max_s", 0.0))
    dst["sum_b"] += src.get("sum_b", 0)
    dst["max_b"] = max(dst["max_b"], src.get("max_b", 0))
    for i, c in enumerate(src.get("lat", ())):
        dst["lat"][i] += c
    for i, c in enumerate(src.get("size", ())):
        dst["size"][i] += c
    for bound, pick in (("t0", min), ("t1", max)):
        s = src.get(bound)
        if s is not None:
            d = dst.get(bound)
            dst[bound] = s if d is None else pick(d, s)


def merge_cells(dst: dict, src: dict) -> dict:
    """Fold a whole `{"op|key": cell}` mapping into `dst` (mutated and
    returned) — the additive property every consumer leans on: summing
    per-step journal frames reproduces the live cumulative exactly."""
    for k, cell in src.items():
        d = dst.get(k)
        if d is None:
            dst[k] = d = new_cell()
        merge_cell(d, cell)
    return dst


def summarize_cell(cell: dict) -> dict:
    """p50/p95/p99 (deterministic, from buckets) + exact max/mean for one
    cell — seconds for latency, bytes for size."""
    n = cell.get("count", 0)
    out = {"count": n, "max_s": cell.get("max_s", 0.0),
           "sum_s": cell.get("sum_s", 0.0), "sum_b": cell.get("sum_b", 0)}
    for q, name in ((0.50, "p50_s"), (0.95, "p95_s"), (0.99, "p99_s")):
        u = quantile_from_buckets(cell.get("lat", ()), q)
        out[name] = None if u is None else u * LAT_UNIT_S
    out["mean_s"] = (out["sum_s"] / n) if n else None
    return out


def cell_key(op: str, key: str = "") -> str:
    return f"{op}|{key}"


def split_key(k: str) -> tuple[str, str]:
    op, _, key = k.partition("|")
    return op, key


# ------------------------------------------------------------------ registry
class _Shard:
    """One thread's cells. Appends are single-threaded (the owning
    thread); snapshots copy under the GIL — the _ThreadBuf discipline."""

    __slots__ = ("cells",)

    def __init__(self):
        self.cells: dict[str, dict] = {}


class _NullTimer:
    """The metrics-off timer: no clock reads, no record. One shared
    instance, like dxt's _NULL_SPAN."""

    __slots__ = ("nbytes",)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


_NULL_TIMER = _NullTimer()


class _Timer:
    """Context manager observing one op on exit; `nbytes` may be set
    inside the block."""

    __slots__ = ("_reg", "op", "key", "nbytes", "_t0")

    def __init__(self, reg: "MetricsRegistry", op: str, key: str,
                 nbytes: int):
        self._reg = reg
        self.op = op
        self.key = key
        self.nbytes = nbytes

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self._reg.observe(self.op, time.perf_counter() - self._t0,
                          nbytes=self.nbytes, key=self.key)
        return False


class MetricsRegistry:
    """Process-wide latency/size histogram registry (see module doc).

    `observe()` is the one recording entry point; `timer()` wraps it for
    spans without their own clocks. `snapshot(reset=True)` ships a
    per-step delta (retired locally so `merged()` stays cumulative);
    `merge()` folds another process's snapshot in; `merged()` is the
    single combined `{"op|key": cell}` view every reporter reads."""

    def __init__(self):
        self.enabled = bool(int(os.environ.get("JBP_METRICS", "0") or 0))
        self.src = f"pid{os.getpid()}"
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._shards: list[_Shard] = []
        self._retired: dict[str, dict] = {}     # reset-snapshot deltas
        self._foreign: dict[str, dict] = {}     # merged from other processes
        self._stamp_epoch()

    def _stamp_epoch(self):
        # paired wall/monotonic sample (the DarshanMonitor/DxtTracer
        # discipline): cell t0/t1 are recorded on perf_counter and rebased
        # wall = perf + (epoch_wall - epoch_perf) at snapshot time
        self.epoch = (time.time(), time.perf_counter())

    # ---------------------------------------------------------------- control
    def enable(self):
        self.enabled = True

    def disable(self):
        self.enabled = False

    def reset(self):
        """Drop every recorded, retired and merged cell (other threads'
        shards included) and restamp the clock epoch."""
        with self._lock:
            for sh in self._shards:
                sh.cells.clear()
            self._retired = {}
            self._foreign = {}
            self.src = f"pid{os.getpid()}"
            self._stamp_epoch()

    # ----------------------------------------------------------------- record
    def _register(self) -> _Shard:
        sh = _Shard()
        with self._lock:
            self._shards.append(sh)
        self._tls.shard = sh
        return sh

    def observe(self, op: str, seconds: float, nbytes: int = 0,
                key: str = ""):
        """Record one observation into the calling thread's shard. Hot
        paths branch on `METRICS.enabled` before calling (observe() also
        guards, so cold paths may call unconditionally)."""
        if not self.enabled:
            return
        sh = getattr(self._tls, "shard", None)
        if sh is None:
            sh = self._register()
        ck = f"{op}|{key}"
        cell = sh.cells.get(ck)
        if cell is None:
            cell = sh.cells[ck] = new_cell()
        t = time.perf_counter()
        if seconds < 0:
            seconds = 0.0
        cell["count"] += 1
        cell["sum_s"] += seconds
        if seconds > cell["max_s"]:
            cell["max_s"] = seconds
        cell["lat"][bucket_index(int(seconds * 1e6), NB_LAT)] += 1
        if nbytes:
            cell["sum_b"] += nbytes
            if nbytes > cell["max_b"]:
                cell["max_b"] = nbytes
            cell["size"][bucket_index(int(nbytes), NB_SIZE)] += 1
        if cell["t0"] is None:
            cell["t0"] = t
        cell["t1"] = t

    def timer(self, op: str, key: str = "", nbytes: int = 0):
        """Timing context manager; a shared no-op when disabled (hot
        paths may also branch on `METRICS.enabled` themselves)."""
        if not self.enabled:
            return _NULL_TIMER
        return _Timer(self, op, key, nbytes)

    # ------------------------------------------------------- snapshot / merge
    def _rebase(self, cell: dict) -> dict:
        """Wire-form copy of a live cell with t0/t1 rebased onto the wall
        clock via this process's epoch."""
        shift = self.epoch[0] - self.epoch[1]
        out = {k: (list(v) if isinstance(v, list) else v)
               for k, v in cell.items()}
        for bound in ("t0", "t1"):
            if out.get(bound) is not None:
                out[bound] = out[bound] + shift
        return out

    def snapshot(self, reset: bool = False) -> dict:
        """Picklable dump of this process's OWN cells (live shards; not
        retired deltas, not foreign merges) — what a worker ships home on
        its ack. `reset=True` clears the shipped cells AND retires the
        delta into the local cumulative, so journaling per-step deltas
        never makes `merged()` forget."""
        with self._lock:
            shards = list(self._shards)
        own: dict[str, dict] = {}
        for sh in shards:
            for ck, cell in list(sh.cells.items()):  # copy under the GIL
                rb = self._rebase(cell)
                d = own.get(ck)
                if d is None:
                    own[ck] = rb
                else:
                    merge_cell(d, rb)
            if reset:
                sh.cells.clear()
        if reset and own:
            with self._lock:
                merge_cells(self._retired, own)
        return {"format": "jbp-metrics-1", "src": self.src,
                "epoch": list(self.epoch), "hists": own}

    def merge(self, snap: Optional[dict]):
        """Fold another process's `snapshot()` in. Cells arrive already
        wall-rebased (the shipper's epoch), so the fold is pure addition —
        the same "rebase at the source, add at the sink" contract as
        `DarshanMonitor.merge`."""
        if not snap:
            return
        hists = snap.get("hists") if "hists" in snap else snap
        if not isinstance(hists, dict) or not hists:
            return
        with self._lock:
            merge_cells(self._foreign, hists)

    def merged(self) -> dict:
        """The combined cumulative `{"op|key": cell}` view: live shards +
        retired deltas + every merged foreign snapshot."""
        out: dict[str, dict] = {}
        merge_cells(out, self.snapshot()["hists"])
        with self._lock:
            merge_cells(out, self._retired)
            merge_cells(out, self._foreign)
        return out

    def stats(self) -> dict:
        """Summary block for `jbpd --stats` / parser-style reports."""
        cells = self.merged()
        return {"enabled": self.enabled, "cells": len(cells),
                "observations": sum(c["count"] for c in cells.values())}


METRICS = MetricsRegistry()


# ---------------------------------------------------------------- stragglers
def straggler_report(cells: dict, *, ratio: float = 2.0,
                     min_count: int = 4) -> list[dict]:
    """Per-op peer comparison: within each op that has >= 2 keys, a key
    whose p99 is >= `ratio` x the median p99 of its peers is a straggler
    (per-OST and per-worker latencies surface as keys — subfile paths,
    `data.<w>`, `md.<w>.shard`). Sorted worst-first."""
    by_op: dict[str, list[tuple[str, dict]]] = {}
    for ck, cell in cells.items():
        op, key = split_key(ck)
        if cell.get("count", 0) >= min_count:
            by_op.setdefault(op, []).append((key, cell))
    out: list[dict] = []
    for op, members in by_op.items():
        if len(members) < 2:
            continue
        p99s = {key: quantile_from_buckets(cell["lat"], 0.99)
                for key, cell in members}
        vals = sorted(v for v in p99s.values() if v is not None)
        if not vals:
            continue
        median = vals[len(vals) // 2]
        for key, cell in members:
            p99 = p99s[key]
            if p99 is None or median <= 0:
                continue
            r = p99 / median
            if r >= ratio:
                out.append({"op": op, "key": key,
                            "p99_s": p99 * LAT_UNIT_S,
                            "median_p99_s": median * LAT_UNIT_S,
                            "ratio": r, "count": cell["count"]})
    out.sort(key=lambda e: -e["ratio"])
    return out


class RollingBaseline:
    """EWMA p99 per (op, key) across successive `update()` calls — the
    rolling baseline that catches a key turning slow against ITS OWN
    history even when every peer degrades together (peer-median alone is
    blind to that). `update(cells)` returns the combined report: the
    peer-ratio stragglers plus any key whose current p99 exceeds
    `baseline_ratio` x its EWMA."""

    def __init__(self, alpha: float = 0.3, ratio: float = 2.0,
                 baseline_ratio: float = 3.0, min_count: int = 4):
        self.alpha = float(alpha)
        self.ratio = float(ratio)
        self.baseline_ratio = float(baseline_ratio)
        self.min_count = int(min_count)
        self._ewma: dict[str, float] = {}

    def update(self, cells: dict) -> list[dict]:
        report = straggler_report(cells, ratio=self.ratio,
                                  min_count=self.min_count)
        flagged = {(e["op"], e["key"]) for e in report}
        for ck, cell in cells.items():
            if cell.get("count", 0) < self.min_count:
                continue
            p99u = quantile_from_buckets(cell["lat"], 0.99)
            if p99u is None:
                continue
            p99 = p99u * LAT_UNIT_S
            prev = self._ewma.get(ck)
            if prev is not None and prev > 0:
                vs = p99 / prev
                op, key = split_key(ck)
                if vs >= self.baseline_ratio and (op, key) not in flagged:
                    report.append({"op": op, "key": key, "p99_s": p99,
                                   "baseline_p99_s": prev,
                                   "ratio": vs, "vs_baseline": True,
                                   "count": cell["count"]})
            self._ewma[ck] = (p99 if prev is None
                              else prev + self.alpha * (p99 - prev))
        report.sort(key=lambda e: -e["ratio"])
        return report


# ------------------------------------------------------------------- journal
class StepJournal:
    """The `metrics.jsonl` sidecar: one JSON frame per committed step,
    appended and flushed AT the step (a crash keeps every frame already
    committed — it is a journal, not a close-time report). Frames carry
    the step's profiling numbers, Darshan counter DELTAS, this process's
    per-step histogram cells, per-worker shards shipped on the "prepared"
    acks, and the straggler report at that step."""

    def __init__(self, path):
        self.path = str(path)
        self._f = None
        self._prev_counters: dict[str, float] = {}
        self.baseline = RollingBaseline()
        self._cum: dict[str, dict] = {}

    def frame(self, step: int, prof: dict, counters: dict,
              hists: dict, workers: Optional[dict] = None) -> dict:
        """Build + append one frame. `counters` are ABSOLUTE totals (the
        journal stores the delta vs the previous frame); `hists` is this
        process's per-step delta (`snapshot(reset=True)["hists"]`);
        `workers` maps worker id -> its shipped per-step snapshot."""
        delta = {k: v - self._prev_counters.get(k, 0.0)
                 for k, v in counters.items()
                 if v - self._prev_counters.get(k, 0.0)}
        self._prev_counters = dict(counters)
        merge_cells(self._cum, hists)
        wcells: dict[str, dict] = {}
        for wid, wsnap in (workers or {}).items():
            wh = wsnap.get("hists", wsnap) if isinstance(wsnap, dict) else {}
            wcells[str(wid)] = wh
            merge_cells(self._cum, wh)
        doc = {"format": "jbp-metrics-journal-1", "step": step,
               "t": time.time(), "prof": prof, "counters": delta,
               "hists": hists, "workers": wcells,
               "stragglers": self.baseline.update(self._cum)}
        self._append(doc)
        return doc

    def _append(self, doc: dict):
        if self._f is None:
            # raw open on purpose: the journal is the metrics plane's OWN
            # output — routing it through InstrumentedFile would fold the
            # observer's writes into the very counter deltas it reports
            self._f = open(self.path, "w")   # jbplint: disable=JBP002
        self._f.write(json.dumps(doc) + "\n")
        self._f.flush()

    def close(self):
        f, self._f = self._f, None
        if f is not None:
            f.close()


def journal_path(series_path) -> str:
    return os.path.join(str(series_path), "metrics.jsonl")


def load_journal(path) -> list[dict]:
    """Read a metrics.jsonl back (series directory or the file itself):
    the list of frames, validated."""
    p = str(path)
    if os.path.isdir(p):
        p = os.path.join(p, "metrics.jsonl")
    # raw open on purpose: reading the journal through InstrumentedFile
    # would pollute the counters the journal is explaining
    with open(p) as f:   # jbplint: disable=JBP002
        frames = [json.loads(line) for line in f if line.strip()]
    for fr in frames:
        if fr.get("format") != "jbp-metrics-journal-1":
            raise ValueError(f"{p}: not a jbp metrics journal (format="
                             f"{fr.get('format')!r})")
    return frames


def sum_journal_hists(frames: Iterable[dict],
                      workers: bool = True) -> dict:
    """Fold every frame's per-step cells (own + per-worker) into one
    cumulative mapping — by the additive bucket property this reproduces
    the producer's live `merged()` exactly (the jbpstat parity test)."""
    out: dict[str, dict] = {}
    for fr in frames:
        merge_cells(out, fr.get("hists", {}))
        if workers:
            for wh in fr.get("workers", {}).values():
                merge_cells(out, wh)
    return out


# ---------------------------------------------------------------- prometheus
def _prom_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _prom_float(v: float) -> str:
    return repr(float(v))


def to_prometheus(cells: dict, counters: Optional[dict] = None,
                  gauges: Optional[dict] = None) -> str:
    """Prometheus text exposition (format version 0.0.4) of the metrics
    plane: `jbp_counter_total{name=...}` for the Darshan counters,
    `jbp_<gauge>` gauges, and `jbp_latency_seconds` /
    `jbp_size_bytes` histogram families labelled {op, key} with the
    shared log2 bucket edges (cumulative, `+Inf`-terminated, `_sum` and
    `_count` per series — the grammar standard scrapers expect)."""
    lines: list[str] = []
    if counters:
        lines.append("# HELP jbp_counter_total Darshan counter totals "
                     "(repro.core.darshan)")
        lines.append("# TYPE jbp_counter_total counter")
        for name in sorted(counters):
            lines.append(f'jbp_counter_total{{name="{_prom_label(name)}"}} '
                         f'{_prom_float(counters[name])}')
    for gname in sorted(gauges or {}):
        full = f"jbp_{gname}"
        lines.append(f"# HELP {full} jbpd gauge")
        lines.append(f"# TYPE {full} gauge")
        lines.append(f"{full} {_prom_float(gauges[gname])}")
    if cells:
        lines.append("# HELP jbp_latency_seconds per-op latency "
                     "(fixed log2 buckets, repro.core.metrics)")
        lines.append("# TYPE jbp_latency_seconds histogram")
        for ck in sorted(cells):
            op, key = split_key(ck)
            cell = cells[ck]
            lab = f'op="{_prom_label(op)}",key="{_prom_label(key)}"'
            cum = 0
            for i, c in enumerate(cell["lat"][:-1]):
                cum += c
                le = _prom_float(bucket_le(i) * LAT_UNIT_S)
                lines.append(f'jbp_latency_seconds_bucket{{{lab},'
                             f'le="{le}"}} {cum}')
            lines.append(f'jbp_latency_seconds_bucket{{{lab},le="+Inf"}} '
                         f'{cell["count"]}')
            lines.append(f'jbp_latency_seconds_sum{{{lab}}} '
                         f'{_prom_float(cell["sum_s"])}')
            lines.append(f'jbp_latency_seconds_count{{{lab}}} '
                         f'{cell["count"]}')
        sized = {ck: c for ck, c in cells.items() if sum(c["size"])}
        if sized:
            lines.append("# HELP jbp_size_bytes per-op transfer size "
                         "(fixed log2 buckets, repro.core.metrics)")
            lines.append("# TYPE jbp_size_bytes histogram")
            for ck in sorted(sized):
                op, key = split_key(ck)
                cell = sized[ck]
                lab = f'op="{_prom_label(op)}",key="{_prom_label(key)}"'
                nsz = sum(cell["size"])
                cum = 0
                for i, c in enumerate(cell["size"][:-1]):
                    cum += c
                    lines.append(f'jbp_size_bytes_bucket{{{lab},'
                                 f'le="{_prom_float(bucket_le(i))}"}} {cum}')
                lines.append(f'jbp_size_bytes_bucket{{{lab},le="+Inf"}} '
                             f'{nsz}')
                lines.append(f'jbp_size_bytes_sum{{{lab}}} '
                             f'{_prom_float(cell["sum_b"])}')
                lines.append(f'jbp_size_bytes_count{{{lab}}} {nsz}')
    return "\n".join(lines) + "\n"
