"""Two-level write aggregation (paper §IV-C).

N writer ranks are assigned to M aggregators (`OPENPMD_ADIOS2_BP5_NumAgg`
analogue). Each aggregator owns one `data.<m>` subfile; its ranks' chunk
payloads are concatenated into that subfile. A work-stealing thread pool
drains the aggregator queues — slow aggregators (straggler OSTs, big
payloads) are absorbed by idle workers, which is the straggler-mitigation
story for 1000+-node deployments (DESIGN.md §6).

Multi-process write plane (repro.core.parallel_engine): each writer
PROCESS constructs a `SubfileSet` that owns only its aggregator ids
(`owned=`), so W processes share one BP directory without ever opening
each other's subfiles — per-process subfile ownership is what makes the
parallel plane free of cross-process write coordination.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterable, Optional

from repro.core.darshan import open_file
from repro.core.striping import OstPool, StripeConfig, StripedFile


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    num_aggregators: int = 1
    num_workers: int = 4                      # writer threads (work-stealing)
    stripe: Optional[StripeConfig] = None     # stripe each subfile if set


def aggregator_of(rank: int, n_ranks: int, m: int) -> int:
    """Contiguous block assignment: rank -> aggregator (ADIOS2 default)."""
    if n_ranks < 1:
        raise ValueError(f"n_ranks must be >= 1, got {n_ranks}")
    if not 0 <= rank < n_ranks:
        raise ValueError(
            f"rank {rank} out of range for n_ranks={n_ranks} "
            f"(valid ranks are 0..{n_ranks - 1})")
    m = min(m, n_ranks)
    return rank * m // n_ranks


class SubfileSet:
    """The M open data.<m> subfiles of one step/series (striped or plain).

    `owned` restricts which aggregator ids this instance opens and may
    append to (default: all M). A multi-process writer gives each process
    `owned={w}` so subfile handles are never shared across processes;
    appending to an un-owned aggregator is a clear error instead of a
    silent cross-process corruption.
    """

    def __init__(self, dirpath, m: int, *, stripe: Optional[StripeConfig] = None,
                 ost_pool: Optional[OstPool] = None,
                 owned: Optional[Iterable[int]] = None):
        self.dirpath = dirpath
        self.m = m
        self.owned = frozenset(range(m) if owned is None else owned)
        bad = [i for i in self.owned if not 0 <= i < m]
        if bad:
            raise ValueError(f"owned aggregator ids {bad} out of range 0..{m - 1}")
        self._offsets = {i: 0 for i in self.owned}
        self._locks = {i: threading.Lock() for i in self.owned}
        self._files = {}
        for i in sorted(self.owned):
            if stripe is not None and ost_pool is not None:
                self._files[i] = StripedFile(ost_pool, f"data.{i}", stripe,
                                             rank=i)
            else:
                self._files[i] = open_file(dirpath / f"data.{i}", "wb",
                                           rank=i)

    def _check_owned(self, agg_id: int):
        if agg_id not in self.owned:
            raise ValueError(
                f"aggregator {agg_id} is not owned by this SubfileSet "
                f"(owned: {sorted(self.owned)}) — each writer process may "
                f"only append to its own subfiles")

    def append(self, agg_id: int, payload: bytes) -> int:
        """Thread-safe append; returns the subfile offset written at.
        Appends are sequential per subfile — no seek() is ever needed (the
        log-structured layout is exactly why BP4 avoids metadata ops)."""
        self._check_owned(agg_id)
        with self._locks[agg_id]:
            off = self._offsets[agg_id]
            f = self._files[agg_id]
            if isinstance(f, StripedFile):
                f.write(payload, offset=off)
            else:
                f.write(payload)
            self._offsets[agg_id] = off + len(payload)
            return off

    def flush_one(self, agg_id: int):
        """Push one subfile's bytes to the OS (no durability barrier)."""
        self._check_owned(agg_id)
        with self._locks[agg_id]:
            self._files[agg_id].flush()

    def fsync_one(self, agg_id: int):
        """Durability barrier for one subfile (parallel prepare phase)."""
        self._check_owned(agg_id)
        with self._locks[agg_id]:
            # fsync under the per-subfile lock is the point: the barrier
            # must order against concurrent appends to the same subfile
            self._files[agg_id].fsync()   # jbplint: disable=JBP004

    def fsync_close(self):
        for f in self._files.values():
            f.fsync()
            f.close()


class WriterPool:
    """Work-stealing writer pool: tasks are (agg_id, payload, on_done).

    A failing task must not kill its worker thread: the pool would silently
    shrink and a later `drain()` would hang forever on the un-consumed
    queue. Instead the FIRST task error is recorded and re-raised from
    `drain()`; workers stay alive and keep draining.
    """

    def __init__(self, n_workers: int):
        self.n_workers = max(1, n_workers)
        self._q: queue.Queue = queue.Queue()
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._err_lock = threading.Lock()
        self._error: Optional[BaseException] = None
        for i in range(self.n_workers):
            t = threading.Thread(target=self._worker, name=f"jbp-writer-{i}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _worker(self):
        while not self._stop.is_set():
            try:
                task = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                fn, args = task
                fn(*args)
            except BaseException as e:         # noqa: BLE001 — surfaced in drain
                with self._err_lock:
                    if self._error is None:    # first failure is the root cause
                        self._error = e
            finally:
                self._q.task_done()

    def submit(self, fn: Callable, *args):
        self._q.put((fn, args))

    def drain(self):
        """Barrier: every submitted task has run. Raises the first task
        error recorded since the last drain (the pool stays usable)."""
        self._q.join()
        with self._err_lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def shutdown(self):
        try:
            self.drain()
        finally:
            self._stop.set()
            for t in self._threads:
                t.join(timeout=2.0)
