"""Two-level write aggregation (paper §IV-C).

N writer ranks are assigned to M aggregators (`OPENPMD_ADIOS2_BP5_NumAgg`
analogue). Each aggregator owns one `data.<m>` subfile; its ranks' chunk
payloads are concatenated into that subfile. A work-stealing thread pool
drains the aggregator queues — slow aggregators (straggler OSTs, big
payloads) are absorbed by idle workers, which is the straggler-mitigation
story for 1000+-node deployments (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Optional

from repro.core.darshan import open_file
from repro.core.striping import OstPool, StripeConfig, StripedFile


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    num_aggregators: int = 1
    num_workers: int = 4                      # writer threads (work-stealing)
    stripe: Optional[StripeConfig] = None     # stripe each subfile if set


def aggregator_of(rank: int, n_ranks: int, m: int) -> int:
    """Contiguous block assignment: rank -> aggregator (ADIOS2 default)."""
    m = min(m, n_ranks)
    return rank * m // n_ranks


class SubfileSet:
    """The M open data.<m> subfiles of one step/series (striped or plain)."""

    def __init__(self, dirpath, m: int, *, stripe: Optional[StripeConfig] = None,
                 ost_pool: Optional[OstPool] = None):
        self.dirpath = dirpath
        self.m = m
        self._offsets = [0] * m
        self._locks = [threading.Lock() for _ in range(m)]
        self._files = []
        for i in range(m):
            if stripe is not None and ost_pool is not None:
                self._files.append(StripedFile(ost_pool, f"data.{i}", stripe,
                                               rank=i))
            else:
                self._files.append(open_file(dirpath / f"data.{i}", "wb",
                                             rank=i))

    def append(self, agg_id: int, payload: bytes) -> int:
        """Thread-safe append; returns the subfile offset written at.
        Appends are sequential per subfile — no seek() is ever needed (the
        log-structured layout is exactly why BP4 avoids metadata ops)."""
        with self._locks[agg_id]:
            off = self._offsets[agg_id]
            f = self._files[agg_id]
            if isinstance(f, StripedFile):
                f.write(payload, offset=off)
            else:
                f.write(payload)
            self._offsets[agg_id] = off + len(payload)
            return off

    def fsync_close(self):
        for f in self._files:
            f.fsync()
            f.close()


class WriterPool:
    """Work-stealing writer pool: tasks are (agg_id, payload, on_done)."""

    def __init__(self, n_workers: int):
        self.n_workers = max(1, n_workers)
        self._q: queue.Queue = queue.Queue()
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        for i in range(self.n_workers):
            t = threading.Thread(target=self._worker, name=f"jbp-writer-{i}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _worker(self):
        while not self._stop.is_set():
            try:
                task = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                fn, args = task
                fn(*args)
            finally:
                self._q.task_done()

    def submit(self, fn: Callable, *args):
        self._q.put((fn, args))

    def drain(self):
        self._q.join()

    def shutdown(self):
        self.drain()
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
