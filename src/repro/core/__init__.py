"""Paper's primary contribution: openPMD-standard high-throughput parallel
I/O with a BP4-style engine (aggregation + compression + striping) and
Darshan-style monitoring — adapted TPU/JAX-native (see DESIGN.md §2)."""
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import MONITOR, DarshanMonitor, open_file
from repro.core.openpmd import Iteration, Mesh, ParticleSpecies, Record, Series
from repro.core.parallel_engine import ParallelBpWriter
from repro.core.striping import OstPool, StripeConfig, StripedFile

__all__ = [
    "BpReader", "BpWriter", "EngineConfig", "MONITOR", "DarshanMonitor",
    "open_file", "Iteration", "Mesh", "ParticleSpecies", "Record", "Series",
    "OstPool", "StripeConfig", "StripedFile", "ParallelBpWriter",
]
