"""openPMD data model (Series -> Iteration -> Mesh/ParticleSpecies ->
Record -> RecordComponent) over the JBP engine.

Follows the openPMD standard's structure and naming (basePath="/data/%T/",
meshesPath="meshes/", particlesPath="particles/") and the openPMD-api usage
protocol the paper describes in §III-A/B:

  * a Series is the root object spanning all iterations,
  * data accumulates in record components via store_chunk() and hits the
    engine only at series.flush() (single action for I/O efficiency),
  * once an iteration is closed it is never reopened,
  * store_chunk needs (local array, offset, global extent) per rank —
    exactly the information an MPI rank (or a jax.Array shard) owns.

Group-based iteration encoding with steps: one BP directory, one engine
step per iteration (the paper's chosen memory strategy).

Async I/O: `Series(..., async_io=True)` swaps the sync BpWriter for an
`AsyncBpWriter` — `flush()` then only SNAPSHOTS the dirty record components
(deep copy) and enqueues the step on a bounded in-flight queue, returning
before compression or any filesystem write happens. The background pipeline
seals steps in flush order with the same crc'd md.idx protocol, so
durability semantics are unchanged: a flushed iteration is durable once its
index record is on disk, `Series.drain()` is the barrier that guarantees it
for every queued step, and `close()` implies `drain()`. The openPMD "chunks
stay unmodified until flush" contract thereby RELAXES to "until end of
flush()": the caller may reuse buffers as soon as flush returns.

Multi-process I/O: `Series(..., parallel_io=W)` swaps in the
`repro.core.parallel_engine.ParallelBpWriter` — W REAL writer processes,
each owning one aggregated subfile, committed per step by a rank-0
two-phase commit. Chunk bytes reach the workers through per-worker
shared-memory rings by default (`transport="shm"`; `"pickle"` is the
queue-serialization baseline). The on-disk series is read-compatible
with every other engine.

Composition: `Series(..., parallel_io=W, async_commit=True)` puts a
bounded snapshot queue in FRONT of the parallel coordinator — `flush()`
returns after a deep-copy snapshot and the whole two-phase commit
(compression, subfile appends, shard votes, md.idx seal) runs behind the
producer; `drain()` is the durability barrier, exactly as with
`async_io`. The two flags are validated UP FRONT: `async_io` names the
single-process pipelined engine, `async_commit` names the parallel
plane's pipelined commit, and asking for both planes at once
(`async_io=True, parallel_io=W`) is a `ValueError` pointing at the
`async_commit` spelling rather than a silently-ignored knob.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Any, Optional

import numpy as np

from repro.core.bp_engine import BpReader, BpWriter, EngineConfig

OPENPMD_VERSION = "1.1.0"
BASE_PATH = "/data/%T/"
MESHES_PATH = "meshes/"
PARTICLES_PATH = "particles/"


class RecordComponent:
    def __init__(self, path: str, series: "Series"):
        self._path = path
        self._series = series
        self._dtype: Optional[np.dtype] = None
        self._global_extent: Optional[tuple] = None
        self._chunks: list[tuple[np.ndarray, tuple, int]] = []
        self.attributes: dict[str, Any] = {"unitSI": 1.0}
        self.codec: Optional[str] = None   # per-variable engine-codec override

    def reset_dataset(self, dtype, global_extent: tuple):
        self._dtype = np.dtype(dtype)
        self._global_extent = tuple(int(x) for x in global_extent)
        return self

    def set_codec(self, spec: Optional[str]):
        """Override the engine codec for THIS component, e.g. "lossy:1e-4"
        for particle data while fields stay lossless. Validated now."""
        if spec is not None:
            from repro.core import compression as _C
            _C.parse_codec(spec)
        self.codec = spec
        return self

    def store_chunk(self, array, offset: tuple, *, rank: int = 0):
        """Queue one rank's chunk. The referenced data must stay unmodified
        until flush() (openPMD contract). A jax.Array is kept on-device:
        with `Series(device_compress=True)` the engine byte-shuffles it on
        the accelerator at flush and the host only runs the LZ stage."""
        from repro.core import compression as _C
        a = array if _C.is_device_array(array) else np.asarray(array)
        if self._dtype is None:
            self.reset_dataset(a.dtype, a.shape)
        self._chunks.append((a, tuple(int(x) for x in offset), rank))
        self._series._dirty.add(self)
        return self

    def set_attribute(self, k: str, v):
        self.attributes[k] = v

    # -------- read side ------------------------------------------------------
    def load_chunk(self, offset: Optional[tuple] = None,
                   extent: Optional[tuple] = None) -> np.ndarray:
        step = int(self._path.split("/")[2])
        return self._series._reader().read_var(step, self._path, offset, extent)

    @property
    def shape(self):
        if self._global_extent is not None:
            return self._global_extent
        step = int(self._path.split("/")[2])
        return tuple(self._series._reader().var_info(step, self._path)["shape"])


class Record(dict):
    """A physical quantity; dict of RecordComponents (scalar: key ''). """

    SCALAR = ""

    def __init__(self, path: str, series: "Series"):
        super().__init__()
        self._path = path
        self._series = series
        self.attributes: dict[str, Any] = {"unitDimension": [0.0] * 7}

    def __getitem__(self, key) -> RecordComponent:
        if key not in self:
            comp_path = self._path if key == "" else f"{self._path}/{key}"
            super().__setitem__(key, RecordComponent(comp_path, self._series))
        return super().__getitem__(key)

    def set_attribute(self, k, v):
        self.attributes[k] = v


class Mesh(Record):
    def __init__(self, path, series):
        super().__init__(path, series)
        self.attributes.update({
            "geometry": "cartesian", "dataOrder": "C", "axisLabels": ["x"],
            "gridSpacing": [1.0], "gridGlobalOffset": [0.0], "gridUnitSI": 1.0,
        })


class ParticleSpecies(dict):
    def __init__(self, path: str, series: "Series"):
        super().__init__()
        self._path = path
        self._series = series
        self.attributes: dict[str, Any] = {}

    def __getitem__(self, key) -> Record:
        if key not in self:
            super().__setitem__(key, Record(f"{self._path}/{key}", self._series))
        return super().__getitem__(key)


class _Container(dict):
    def __init__(self, factory):
        super().__init__()
        self._factory = factory

    def __getitem__(self, key):
        if key not in self:
            super().__setitem__(key, self._factory(key))
        return super().__getitem__(key)


class Iteration:
    def __init__(self, index: int, series: "Series"):
        self.index = index
        self._series = series
        self.time = 0.0
        self.dt = 1.0
        self.time_unit_SI = 1.0
        base = f"/data/{index}"
        self.meshes = _Container(
            lambda k: Mesh(f"{base}/meshes/{k}", series))
        self.particles = _Container(
            lambda k: ParticleSpecies(f"{base}/particles/{k}", series))
        self._closed = False

    def close(self):
        """Flush and seal — a closed iteration is never reopened."""
        self._series.flush()
        self._closed = True


class Series:
    """Root openPMD object. mode: 'w' (create) or 'r' (read).

    engine_config carries the ADIOS2-style knobs: aggregators
    (OPENPMD_ADIOS2_BP5_NumAgg), codec (blosc/bzip2), Lustre striping.
    """

    def __init__(self, path, mode: str = "w", *, n_ranks: int = 1,
                 engine_config: EngineConfig = EngineConfig(),
                 meta: Optional[dict] = None, async_io: bool = False,
                 queue_depth: int = 2, parallel_io: int = 0,
                 parallel_read: int = 0, async_commit: bool = False,
                 transport: str = "shm",
                 device_compress: Optional[bool] = None):
        self.path = pathlib.Path(str(path))
        self.mode = mode
        self.n_ranks = n_ranks
        if device_compress is not None:
            # convenience spelling of EngineConfig(device_compress=...): the
            # on-chip bitshuffle stage for jax.Array chunks
            engine_config = dataclasses.replace(
                engine_config, device_compress=bool(device_compress))
        self.engine_config = engine_config
        # read-side mirror of parallel_io: load_chunk/read_var fan
        # multi-chunk reads over a ReaderPool of this many workers
        self.parallel_read = int(parallel_read)
        # engine-plane combinations are validated HERE, not at first flush:
        # a bad combination must fail at construction with the fix named
        if parallel_io and async_io:
            raise ValueError(
                "async_io=True names the single-process pipelined engine and "
                "does not stack on the parallel write plane; to overlap the "
                "producer with the W-process two-phase commit, spell it "
                f"Series(parallel_io={int(parallel_io)}, async_commit=True)")
        if async_commit and not parallel_io:
            raise ValueError(
                "async_commit=True is the parallel plane's pipelined commit "
                "and requires parallel_io=W; for the single-process engine "
                "use async_io=True instead")
        from repro.core.shm_transport import validate_transport
        validate_transport(transport)
        self.async_io = async_io
        self.async_commit = bool(async_commit)
        self.transport = transport
        self.parallel_io = int(parallel_io)
        self.queue_depth = queue_depth
        self.iterations = _Container(lambda k: Iteration(k, self))
        self._dirty: set[RecordComponent] = set()
        self._closed = False
        self._writer: Optional[BpWriter] = None
        self._reader_obj: Optional[BpReader] = None
        self._open_step: Optional[int] = None
        self.attributes = {
            "openPMD": OPENPMD_VERSION,
            "openPMDextension": 0,
            "basePath": BASE_PATH,
            "meshesPath": MESHES_PATH,
            "particlesPath": PARTICLES_PATH,
            "iterationEncoding": "groupBased",
            "iterationFormat": BASE_PATH,
            "software": "repro-jbp",
        }
        if meta:
            self.attributes.update(meta)
        if mode == "r":
            self._reader()

    # ----------------------------------------------------------------- write
    def _get_writer(self) -> BpWriter:
        if self._closed:
            # constructing a new writer on an already-written path would
            # reopen md.0/md.idx with "wb" and truncate sealed iterations
            raise RuntimeError(f"Series {self.path} is closed")
        if self._writer is None:
            if self.parallel_io:
                from repro.core.parallel_engine import ParallelBpWriter
                self._writer = ParallelBpWriter(self.path, self.n_ranks,
                                                self.engine_config,
                                                n_writers=self.parallel_io,
                                                transport=self.transport,
                                                async_commit=self.async_commit,
                                                queue_depth=self.queue_depth)
            elif self.async_io:
                from repro.core.async_engine import AsyncBpWriter
                self._writer = AsyncBpWriter(self.path, self.n_ranks,
                                             self.engine_config,
                                             queue_depth=self.queue_depth)
            else:
                self._writer = BpWriter(self.path, self.n_ranks,
                                        self.engine_config)
            for k, v in self.attributes.items():
                self._writer.set_attribute(k, v)
        return self._writer

    def flush(self):
        """Write all dirty record components as one engine step."""
        if not self._dirty:
            return None
        by_step: dict[int, list[RecordComponent]] = {}
        for rc in self._dirty:
            step = int(rc._path.split("/")[2])
            by_step.setdefault(step, []).append(rc)
        w = self._get_writer()
        prof = None
        for step in sorted(by_step):
            w.begin_step(step)
            it = self.iterations[step]
            w.set_attribute(f"/data/{step}/time", it.time)
            w.set_attribute(f"/data/{step}/dt", it.dt)
            for rc in by_step[step]:
                for arr, off, rank in rc._chunks:
                    w.put(rc._path, arr, global_shape=rc._global_extent,
                          offset=off, rank=rank, codec=rc.codec)
                rc._chunks.clear()
            prof = w.end_step()
        self._dirty.clear()
        return prof

    def drain(self):
        """Durability barrier: with async_io, block until every flushed
        iteration's md.idx record is sealed on disk. No-op for sync."""
        if self._writer is not None and hasattr(self._writer, "drain"):
            self._writer.drain()

    def close(self):
        """Flush remaining iterations and shut the engine down. The writer
        is ALWAYS closed (thread + md handles released) even when a flush
        or a queued async write failed — the error still propagates, and
        the series is dead afterwards: a later flush()/close() is a no-op
        (it must never construct a fresh writer on the same path, which
        would truncate the sealed iterations already on disk)."""
        if self._closed:
            return
        try:
            self.flush()
        finally:
            self._closed = True
            self._dirty.clear()
            if self._reader_obj is not None:
                # the reader caches one open handle per subfile now —
                # a closed Series must not keep M data.* fds alive
                r, self._reader_obj = self._reader_obj, None
                r.close()
            if self._writer is not None:
                w, self._writer = self._writer, None
                w.close()            # async: drains; cleanup-then-raise

    # ------------------------------------------------------------------ read
    def _reader(self) -> BpReader:
        if self._reader_obj is None:
            self._reader_obj = BpReader(self.path,
                                        parallel=self.parallel_read)
        return self._reader_obj

    def read_iterations(self) -> list[int]:
        return self._reader().valid_steps()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
