"""Compression codecs for the BP engine (paper §IV-D).

  * "blosc"  — Blosc-style pipeline: byte shuffle preconditioner + fast LZ
               stage (zlib level 1 stands in for LZ4). The shuffle transposes
               the [n_items, itemsize] byte matrix so same-significance bytes
               are contiguous — floats compress far better. On a TPU pod the
               shuffle runs ON CHIP next to the data (kernels/bitshuffle, a
               Pallas kernel); here the numpy path is the host fallback and
               the kernel's oracle.
  * "bzip2"  — the paper's high-ratio/high-cost comparison point.
  * "zlib"   — plain deflate, no shuffle (ablation).
  * "none"   — pass-through.

All codecs are chunked (default 1 MiB) with a tiny self-describing header so
any block can be decompressed independently (needed for striped/aggregated
layouts and elastic re-sharding reads).
"""
from __future__ import annotations

import bz2
import struct
import zlib

import numpy as np

MAGIC = b"JBPC"
HEADER = struct.Struct("<4sBBHII")    # magic, codec_id, itemsize, _, raw, comp

CODEC_IDS = {"none": 0, "blosc": 1, "bzip2": 2, "zlib": 3}
CODEC_NAMES = {v: k for k, v in CODEC_IDS.items()}
DEFAULT_BLOCK = 1 * 1024 * 1024


class CorruptPayloadError(ValueError):
    """A stored payload failed validation while decoding: bad JBPC magic,
    truncated header/payload slice, unknown codec id, a codec stream the
    decompressor rejects, or a decompressed length that does not match the
    header. This is a REAL exception, not an `assert` — bit rot must be
    diagnosed identically under `python -O`, and service-plane callers
    (jbpd, jbpfsck-style deep scans) map it to a clean error response
    instead of surfacing garbage data or an opaque unpack traceback."""


def byte_shuffle(buf, itemsize: int) -> bytes:
    """[n, itemsize] byte-matrix transpose (Blosc's shuffle filter)."""
    if itemsize <= 1 or len(buf) % itemsize:
        return bytes(buf)
    a = np.frombuffer(buf, dtype=np.uint8).reshape(-1, itemsize)
    return a.T.tobytes()


def byte_unshuffle(buf: bytes, itemsize: int) -> bytes:
    if itemsize <= 1 or len(buf) % itemsize:
        return buf
    a = np.frombuffer(buf, dtype=np.uint8).reshape(itemsize, -1)
    return a.T.tobytes()


def _rle_deflate(buf: bytes) -> bytes:
    """Deflate with Z_RLE strategy — a fast LZ stage much closer to Blosc's
    LZ4 cost profile than default deflate (§Perf hillclimb C iteration r7).
    After the byte shuffle, runs dominate, so Z_RLE keeps most of the ratio
    at a fraction of the match-search cost."""
    co = zlib.compressobj(1, zlib.DEFLATED, 15, 9, zlib.Z_RLE)
    return co.compress(buf) + co.flush()


def _compress_block(block, codec: str, itemsize: int) -> bytes:
    if codec == "none":
        payload = bytes(block)
    elif codec == "blosc":
        payload = _rle_deflate(byte_shuffle(block, itemsize))
    elif codec == "zlib":
        payload = zlib.compress(block, 6)
    elif codec == "bzip2":
        payload = bz2.compress(block, 9)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    if len(payload) >= len(block):           # incompressible -> store raw
        codec, payload = "none", bytes(block)
    hdr = HEADER.pack(MAGIC, CODEC_IDS[codec], itemsize, 0,
                      len(block), len(payload))
    return hdr + payload


def _decompress_block(buf: bytes, off: int) -> tuple[bytes, int]:
    if off + HEADER.size > len(buf):
        raise CorruptPayloadError(
            f"truncated block header at offset {off}: "
            f"{len(buf) - off} bytes left, {HEADER.size} needed")
    magic, cid, itemsize, _, raw, comp = HEADER.unpack_from(buf, off)
    if magic != MAGIC:
        raise CorruptPayloadError(
            f"bad block magic at offset {off}: {magic!r} != {MAGIC!r} "
            f"(corrupt or misaligned payload)")
    start = off + HEADER.size
    if start + comp > len(buf):
        raise CorruptPayloadError(
            f"truncated block payload at offset {start}: header promises "
            f"{comp} bytes, {len(buf) - start} present")
    payload = buf[start:start + comp]
    codec = CODEC_NAMES.get(cid)
    if codec is None:
        raise CorruptPayloadError(
            f"unknown codec id {cid} in block header at offset {off}")
    try:
        if codec == "none":
            out = payload
        elif codec == "blosc":
            out = byte_unshuffle(zlib.decompress(payload), itemsize)
        elif codec == "zlib":
            out = zlib.decompress(payload)
        else:
            out = bz2.decompress(payload)
    except (zlib.error, OSError, ValueError) as e:
        raise CorruptPayloadError(
            f"{codec} stream at offset {start} failed to decode: {e}") from e
    if len(out) != raw:
        raise CorruptPayloadError(
            f"decompressed length mismatch at offset {off}: header promises "
            f"{raw} raw bytes, stream decoded to {len(out)}")
    return out, start + comp


def compress(data, codec: str = "none", itemsize: int = 1,
             block: int = DEFAULT_BLOCK) -> bytes:
    """Chunked compress; output is a sequence of self-describing blocks.
    `data` may be any buffer (bytes, memoryview, numpy .data) — block
    slicing is zero-copy via memoryview."""
    mv = memoryview(data).cast("B")
    out = []
    for i in range(0, max(len(mv), 1), block):
        out.append(_compress_block(mv[i:i + block], codec, itemsize))
    return b"".join(out)


def decompress(data: bytes) -> bytes:
    out = bytearray()
    off = 0
    while off < len(data):
        blk, off = _decompress_block(data, off)
        out += blk
    return bytes(out)


def array_payload(arr: np.ndarray, codec: str,
                  block: int = DEFAULT_BLOCK) -> bytes:
    a = np.ascontiguousarray(arr)
    # zero-copy into the chunked compressor (no .tobytes() duplication)
    return compress(a.reshape(-1).view(np.uint8).data, codec,
                    itemsize=a.dtype.itemsize, block=block)


def payload_to_array(buf: bytes, dtype, shape) -> np.ndarray:
    raw = decompress(buf)
    try:
        return np.frombuffer(raw, dtype=dtype).reshape(shape)
    except ValueError as e:
        raise CorruptPayloadError(
            f"decoded payload ({len(raw)} bytes) does not fit a "
            f"{np.dtype(dtype)} array of shape {tuple(shape)}: {e}") from e
