"""Compression codecs for the BP engine (paper §IV-D).

  * "blosc"  — Blosc-style pipeline: byte shuffle preconditioner + fast LZ
               stage (zlib level 1 stands in for LZ4). The shuffle transposes
               the [n_items, itemsize] byte matrix so same-significance bytes
               are contiguous — floats compress far better. The numpy path
               below is the host fallback and the kernel's oracle; device
               arrays take the on-chip path (kernels/bitshuffle, a Pallas
               kernel) via `device_array_payload` / `device_precondition`,
               so the host only pays the cheap Z_RLE stage.
  * "lossy"  — error-bounded lossy codec for particle data: uniform scalar
               quantization to a caller-chosen bound, then shuffle + Z_RLE
               on the quantized ints. Spec strings carry the bound:
               "lossy:1e-3" (absolute) or "lossy:rel:1e-3" (relative to the
               block's max |x|). Reconstruction error is <= the bound by
               construction (q = round(x / 2*eps), x_hat = q * 2*eps); the
               per-block sub-header records the quantization step, so every
               block is self-describing. Blocks that cannot honor the bound
               losslessly fall back (non-finite values, zero effective
               bound, quantizer overflow -> lossless blosc for that block).
  * "bzip2"  — the paper's high-ratio/high-cost comparison point.
  * "zlib"   — plain deflate, no shuffle (ablation).
  * "none"   — pass-through.

All codecs are chunked (default 1 MiB) with a tiny self-describing header so
any block can be decompressed independently (needed for striped/aggregated
layouts and elastic re-sharding reads). The header's flags field carries
FLAG_PRESHUFFLED: set by producers whose bytes were already byte-shuffled
on-device before the host encode (workers skip the shuffle; readers of
blosc blocks are oblivious because decode always unshuffles, and stored-raw
fallback blocks unshuffle iff the flag is set). Old payloads wrote 0 in the
field, so pre-flag series decode bit-identically.
"""
from __future__ import annotations

import bz2
import math
import struct
import time
import zlib

import numpy as np

from repro.core.dxt import TRACER
from repro.core.metrics import METRICS

MAGIC = b"JBPC"
HEADER = struct.Struct("<4sBBHII")  # magic, codec_id, itemsize, flags, raw, comp

#: stored bytes were byte-shuffled BEFORE the encode (on-device
#: preconditioning) — decode-relevant only for stored-raw ("none") blocks;
#: informational for "blosc" (its decode always unshuffles)
FLAG_PRESHUFFLED = 0x1

CODEC_IDS = {"none": 0, "blosc": 1, "bzip2": 2, "zlib": 3, "lossy": 4}
CODEC_NAMES = {v: k for k, v in CODEC_IDS.items()}
DEFAULT_BLOCK = 1 * 1024 * 1024

#: lossy block sub-header: quantization step (x_hat = q * scale, so the
#: error bound is scale/2) and the width of the stored quantized ints
LOSSY_SUB = struct.Struct("<dB")
_FLOAT_BY_ITEMSIZE = {2: np.float16, 4: np.float32, 8: np.float64}
_QINT_BY_SIZE = {4: np.int32, 8: np.int64}
#: one ulp, relative, per float width — the error the final cast back to
#: the stored dtype can add on top of the float64 quantization error
_CAST_ULP = {2: 2.0 ** -10, 4: 2.0 ** -23, 8: 2.0 ** -52}


class CorruptPayloadError(ValueError):
    """A stored payload failed validation while decoding: bad JBPC magic,
    truncated header/payload slice, unknown codec id, a codec stream the
    decompressor rejects, or a decompressed length that does not match the
    header. This is a REAL exception, not an `assert` — bit rot must be
    diagnosed identically under `python -O`, and service-plane callers
    (jbpd, jbpfsck-style deep scans) map it to a clean error response
    instead of surfacing garbage data or an opaque unpack traceback."""


def parse_codec(spec) -> tuple[str, float, bool]:
    """Parse a codec spec -> (name, lossy_bound, lossy_is_relative).

    Lossless specs are their own name ("blosc" -> ("blosc", 0.0, False));
    the lossy codec carries its error bound in the spec string:
    "lossy:1e-3" (absolute) or "lossy:rel:1e-3" (relative to each block's
    max |x|). Raises ValueError for unknown names or unusable bounds."""
    s = str(spec)
    if s == "lossy" or s.startswith("lossy:"):
        parts = s.split(":")
        rel = len(parts) == 3 and parts[1] == "rel"
        if len(parts) < 2 or not (len(parts) == 2 or rel):
            raise ValueError(
                f"bad lossy codec spec {spec!r} — use 'lossy:<abs_bound>' "
                f"or 'lossy:rel:<rel_bound>'")
        try:
            bound = float(parts[-1])
        except ValueError:
            raise ValueError(
                f"bad lossy codec bound in {spec!r}: {parts[-1]!r} is not "
                f"a number") from None
        if not (bound > 0.0 and math.isfinite(bound)):
            raise ValueError(
                f"lossy codec bound must be finite and > 0, got {bound!r}")
        return "lossy", bound, rel
    if s not in CODEC_IDS:
        raise ValueError(f"unknown codec {spec!r}")
    return s, 0.0, False


def byte_shuffle(buf, itemsize: int) -> bytes:
    """[n, itemsize] byte-matrix transpose (Blosc's shuffle filter)."""
    if itemsize <= 1 or len(buf) % itemsize:
        return bytes(buf)
    a = np.frombuffer(buf, dtype=np.uint8).reshape(-1, itemsize)
    return a.T.tobytes()


def byte_unshuffle(buf: bytes, itemsize: int) -> bytes:
    if itemsize <= 1 or len(buf) % itemsize:
        return buf
    a = np.frombuffer(buf, dtype=np.uint8).reshape(itemsize, -1)
    return a.T.tobytes()


def _rle_deflate(buf) -> bytes:
    """Deflate with Z_RLE strategy — a fast LZ stage much closer to Blosc's
    LZ4 cost profile than default deflate (§Perf hillclimb C iteration r7).
    After the byte shuffle, runs dominate, so Z_RLE keeps most of the ratio
    at a fraction of the match-search cost."""
    co = zlib.compressobj(1, zlib.DEFLATED, 15, 9, zlib.Z_RLE)
    return co.compress(buf) + co.flush()


def _lossy_block(block, itemsize: int, bound: float, rel: bool):
    """Quantize-to-bound one block: q = round(x / (2*eps)) stored as
    shuffled+Z_RLE'd int32/int64. Returns the payload (sub-header + body)
    or None when the block must fall back to lossless — not a float-width
    itemsize, non-finite values, zero effective bound (all-zero block under
    a relative bound), or quantizer overflow."""
    fdtype = _FLOAT_BY_ITEMSIZE.get(itemsize)
    if fdtype is None or len(block) % itemsize:
        return None
    x = np.frombuffer(block, dtype=fdtype).astype(np.float64)
    if x.size and not np.isfinite(x).all():
        return None
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    eps = bound * amax if rel else (bound if not rel else 0.0)
    if not eps > 0.0:
        return None
    # reconstruction happens in float64 then casts back to the stored
    # width; shave one ulp of the largest representable reconstruction off
    # the quantization step so the bound holds strictly IN THE STORED
    # DTYPE, not just in float64. A bound below that representability
    # floor cannot be honored lossily -> lossless fallback.
    eps_int = eps - (amax + eps) * _CAST_ULP[itemsize]
    if not eps_int > 0.0:
        return None
    scale = 2.0 * eps_int
    q = np.round(x / scale)
    qmax = float(np.max(np.abs(q))) if q.size else 0.0
    if qmax <= 2.0 ** 31 - 1:
        qdtype = np.int32
    elif qmax <= 2.0 ** 63 - 1:
        qdtype = np.int64
    else:
        return None
    qa = q.astype(qdtype)
    body = _rle_deflate(byte_shuffle(qa.tobytes(), qa.dtype.itemsize))
    return LOSSY_SUB.pack(scale, qa.dtype.itemsize) + body


def _compress_block(block, codec: str, itemsize: int, *,
                    preshuffled: bool = False, lossy_bound: float = 0.0,
                    lossy_rel: bool = False) -> bytes:
    flags = 0
    if preshuffled:
        if codec not in ("blosc", "none"):
            raise ValueError(
                f"codec {codec!r} cannot encode pre-shuffled bytes — only "
                f"blosc/none understand the device-preconditioned layout")
        if itemsize > 1 and len(block) and len(block) % itemsize == 0:
            flags = FLAG_PRESHUFFLED
    if codec == "lossy":
        payload = _lossy_block(block, itemsize, lossy_bound, lossy_rel)
        if payload is not None:
            if len(payload) >= len(block):     # incompressible -> store raw
                hdr = HEADER.pack(MAGIC, CODEC_IDS["none"], itemsize, 0,
                                  len(block), len(block))
                return hdr + bytes(block)
            hdr = HEADER.pack(MAGIC, CODEC_IDS["lossy"], itemsize, 0,
                              len(block), len(payload))
            return hdr + payload
        codec = "blosc"                        # lossless fallback, this block
    if codec == "none":
        payload = bytes(block)
    elif codec == "blosc":
        payload = _rle_deflate(block if flags & FLAG_PRESHUFFLED
                               else byte_shuffle(block, itemsize))
    elif codec == "zlib":
        payload = zlib.compress(block, 6)
    elif codec == "bzip2":
        payload = bz2.compress(block, 9)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    if len(payload) >= len(block):           # incompressible -> store raw
        # flags survive: a pre-shuffled raw store keeps FLAG_PRESHUFFLED so
        # decode knows to unshuffle the stored bytes
        codec, payload = "none", bytes(block)
    elif codec == "blosc":
        # blosc decode unshuffles unconditionally, so the flag carries no
        # decode information for a compressed block — clear it and the
        # device pipeline's payload stays BIT-IDENTICAL to the host path's
        flags = 0
    hdr = HEADER.pack(MAGIC, CODEC_IDS[codec], itemsize, flags,
                      len(block), len(payload))
    return hdr + payload


def iter_block_headers(data):
    """Walk a payload's JBPC block headers WITHOUT touching payload bytes:
    yields (offset, codec_id, itemsize, flags, raw, comp) per block after
    validating magic, codec id and the length chain. This is the
    `decompress` pre-scan and the `jbpfsck --deep` walk."""
    n = len(data)
    off = 0
    while off < n:
        if off + HEADER.size > n:
            raise CorruptPayloadError(
                f"truncated block header at offset {off}: "
                f"{n - off} bytes left, {HEADER.size} needed")
        magic, cid, itemsize, flags, raw, comp = HEADER.unpack_from(data, off)
        if magic != MAGIC:
            raise CorruptPayloadError(
                f"bad block magic at offset {off}: {magic!r} != {MAGIC!r} "
                f"(corrupt or misaligned payload)")
        if cid not in CODEC_NAMES:
            raise CorruptPayloadError(
                f"unknown codec id {cid} in block header at offset {off}")
        if cid == CODEC_IDS["lossy"] and comp < LOSSY_SUB.size:
            raise CorruptPayloadError(
                f"lossy block at offset {off} too short for its sub-header "
                f"({comp} bytes, {LOSSY_SUB.size} needed)")
        if off + HEADER.size + comp > n:
            raise CorruptPayloadError(
                f"truncated block payload at offset {off + HEADER.size}: "
                f"header promises {comp} bytes, "
                f"{n - off - HEADER.size} present")
        yield off, cid, itemsize, flags, raw, comp
        off += HEADER.size + comp


def _decompress_block(buf, off: int) -> tuple[bytes, int]:
    if off + HEADER.size > len(buf):
        raise CorruptPayloadError(
            f"truncated block header at offset {off}: "
            f"{len(buf) - off} bytes left, {HEADER.size} needed")
    magic, cid, itemsize, flags, raw, comp = HEADER.unpack_from(buf, off)
    if magic != MAGIC:
        raise CorruptPayloadError(
            f"bad block magic at offset {off}: {magic!r} != {MAGIC!r} "
            f"(corrupt or misaligned payload)")
    start = off + HEADER.size
    if start + comp > len(buf):
        raise CorruptPayloadError(
            f"truncated block payload at offset {start}: header promises "
            f"{comp} bytes, {len(buf) - start} present")
    payload = buf[start:start + comp]
    codec = CODEC_NAMES.get(cid)
    if codec is None:
        raise CorruptPayloadError(
            f"unknown codec id {cid} in block header at offset {off}")
    if codec == "lossy":
        # sub-header validation happens OUTSIDE the stream-decode try so a
        # malformed sub-header reports itself, not a wrapped decode error
        if len(payload) < LOSSY_SUB.size:
            raise CorruptPayloadError(
                f"lossy block at offset {off} too short for its sub-header "
                f"({len(payload)} bytes, {LOSSY_SUB.size} needed)")
        scale, qsize = LOSSY_SUB.unpack_from(payload)
        fdtype = _FLOAT_BY_ITEMSIZE.get(itemsize)
        qdtype = _QINT_BY_SIZE.get(qsize)
        if fdtype is None or qdtype is None:
            raise CorruptPayloadError(
                f"lossy block at offset {off} has unsupported widths "
                f"(float itemsize {itemsize}, quantized width {qsize})")
    try:
        if codec == "none":
            out = (byte_unshuffle(bytes(payload), itemsize)
                   if flags & FLAG_PRESHUFFLED else payload)
        elif codec == "blosc":
            out = byte_unshuffle(zlib.decompress(payload), itemsize)
        elif codec == "zlib":
            out = zlib.decompress(payload)
        elif codec == "lossy":
            ints = byte_unshuffle(
                zlib.decompress(payload[LOSSY_SUB.size:]), qsize)
            q = np.frombuffer(ints, dtype=qdtype)
            out = (q.astype(np.float64) * scale).astype(fdtype).tobytes()
        else:
            out = bz2.decompress(payload)
    except (zlib.error, OSError, ValueError) as e:
        raise CorruptPayloadError(
            f"{codec} stream at offset {start} failed to decode: {e}") from e
    if len(out) != raw:
        raise CorruptPayloadError(
            f"decompressed length mismatch at offset {off}: header promises "
            f"{raw} raw bytes, stream decoded to {len(out)}")
    return out, start + comp


def compress(data, codec: str = "none", itemsize: int = 1,
             block: int = DEFAULT_BLOCK, *, preshuffled: bool = False) -> bytes:
    """Chunked compress; output is a sequence of self-describing blocks.
    `data` may be any buffer (bytes, memoryview, numpy .data) — block
    slicing is zero-copy via memoryview. `codec` accepts spec strings
    ("blosc", "lossy:1e-3", "lossy:rel:1e-3"); `preshuffled=True` marks the
    input bytes as already byte-shuffled per block (device path)."""
    name, bound, rel = parse_codec(codec)
    mv = memoryview(data).cast("B")
    out = []
    for i in range(0, max(len(mv), 1), block):
        out.append(_compress_block(mv[i:i + block], name, itemsize,
                                   preshuffled=preshuffled,
                                   lossy_bound=bound, lossy_rel=rel))
    return b"".join(out)


def _decompress_into(data) -> bytearray:
    """Pre-scan the headers to size the output exactly, then decode each
    block into a preallocated bytearray — no quadratic `out +=` growth."""
    out = bytearray(sum(h[4] for h in iter_block_headers(data)))
    pos = 0
    off = 0
    n = len(data)
    while off < n:
        blk, off = _decompress_block(data, off)
        out[pos:pos + len(blk)] = blk
        pos += len(blk)
    return out


def decompress(data: bytes) -> bytes:
    return bytes(_decompress_into(data))


def array_payload(arr: np.ndarray, codec: str,
                  block: int = DEFAULT_BLOCK) -> bytes:
    a = np.ascontiguousarray(arr)
    if parse_codec(codec)[0] == "lossy" and a.dtype.kind != "f":
        # error-bounded quantization is defined over IEEE floats only —
        # the byte-level compress() would misread ints (or bfloat16) as
        # same-width floats. Integers etc. get the lossless pipeline.
        codec = "blosc"
    # zero-copy into the chunked compressor (no .tobytes() duplication)
    return compress(a.reshape(-1).view(np.uint8).data, codec,
                    itemsize=a.dtype.itemsize, block=block)


def payload_to_array(buf: bytes, dtype, shape) -> np.ndarray:
    dtype = np.dtype(dtype)
    first = next(iter_block_headers(buf), None)
    if first is not None:
        off, cid, _isz, flags, raw, comp = first
        if (off + HEADER.size + comp == len(buf)
                and cid == CODEC_IDS["none"]
                and not flags & FLAG_PRESHUFFLED
                and comp == raw and raw
                and raw % dtype.itemsize == 0):
            # single stored-raw block: view straight into the payload
            # buffer, zero-copy (read-only, same as the frombuffer path)
            try:
                return np.frombuffer(
                    buf, dtype=dtype, count=raw // dtype.itemsize,
                    offset=HEADER.size).reshape(shape)
            except ValueError as e:
                raise CorruptPayloadError(
                    f"stored-raw payload ({raw} bytes) does not fit a "
                    f"{dtype} array of shape {tuple(shape)}: {e}") from e
    raw_buf = _decompress_into(buf)
    try:
        return np.frombuffer(raw_buf, dtype=dtype).reshape(shape)
    except ValueError as e:
        raise CorruptPayloadError(
            f"decoded payload ({len(raw_buf)} bytes) does not fit a "
            f"{dtype} array of shape {tuple(shape)}: {e}") from e


# --------------------------------------------------------------------------
# Device path: on-chip byte-shuffle preconditioning (kernels/bitshuffle)
# --------------------------------------------------------------------------

def is_device_array(x) -> bool:
    """Duck-typed 'accelerator-resident array' check that never imports
    jax: device arrays are not numpy ndarrays but expose the async D2H
    primitive the pipeline is built on."""
    return (not isinstance(x, np.ndarray)
            and hasattr(x, "copy_to_host_async") and hasattr(x, "dtype"))


def codec_wants_device(codec) -> bool:
    """True when the codec's preconditioner can run on-device (the blosc
    byte shuffle). Lossy quantizes on host; zlib/bzip2 have no shuffle."""
    return parse_codec(codec)[0] == "blosc"


class DeviceStats:
    """Accounting a device-path encode hands back to the engine: bytes
    shuffled on-chip, host-LZ seconds that overlapped an in-flight device
    block, and the device-computed chunk stats (min/max without a second
    host pass)."""

    __slots__ = ("device_bytes", "overlap_s", "vmin", "vmax")

    def __init__(self, device_bytes: int = 0, overlap_s: float = 0.0,
                 vmin: float = 0.0, vmax: float = 0.0):
        self.device_bytes = device_bytes
        self.overlap_s = overlap_s
        self.vmin = vmin
        self.vmax = vmax


class PreshuffledChunk:
    """Host-side carrier of a device-preconditioned chunk: the
    byte-shuffled bytes (shuffled per codec block on the accelerator, so
    block boundaries match the host encoder's) plus the metadata a writer
    worker needs to finish the encode WITHOUT re-shuffling. The JBPC
    pre-shuffled header flag keeps every reader oblivious."""

    __slots__ = ("data", "dtype", "shape", "block", "vmin", "vmax",
                 "device_bytes")

    def __init__(self, data: np.ndarray, dtype, shape, block: int,
                 vmin: float = 0.0, vmax: float = 0.0, device_bytes: int = 0):
        self.data = data                       # uint8[nbytes], shuffled
        self.dtype = np.dtype(dtype)
        self.shape = tuple(int(s) for s in shape)
        self.block = int(block)
        self.vmin = float(vmin)
        self.vmax = float(vmax)
        self.device_bytes = int(device_bytes)  # bytes actually shuffled on-chip

    @property
    def itemsize(self) -> int:
        return self.dtype.itemsize

    @property
    def nbytes(self) -> int:
        return int(self.data.nbytes)

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def _device_byte_view(arr):
    """uint8 [nbytes] view of a device array's raw bytes, on-device."""
    import jax
    import jax.numpy as jnp
    flat = arr.reshape(-1)
    if flat.dtype == jnp.uint8:
        return flat
    return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)


def _device_minmax(arr):
    """Launch the min/max reduction on-device (async); returns lazily-
    materialized scalars or None for dtypes without an order."""
    import jax.numpy as jnp
    kind = np.dtype(arr.dtype).kind
    if kind not in "fiub" or not arr.size:
        return None
    if kind == "f":                # NaN-tolerant, like host chunk_stats
        return jnp.nanmin(arr), jnp.nanmax(arr)
    return jnp.min(arr), jnp.max(arr)


def _device_shuffled_blocks(arr, block: int, itemsize: int, interpret):
    """Submit the per-codec-block on-chip shuffles and start each block's
    async D2H — the device queue runs ahead of the host. Returns
    (blocks=[(jax_block, was_shuffled)], nbytes, device_bytes, minmax)."""
    from repro.kernels.bitshuffle import ops as bops
    byts = _device_byte_view(arr)
    nbytes = int(byts.shape[0])
    minmax = _device_minmax(arr)
    blocks = []
    device_bytes = 0
    for i in range(0, max(nbytes, 1), block):
        s = byts[i:i + block]
        blen = int(s.shape[0])
        # mirror the host byte_shuffle no-op cases exactly so payloads are
        # bit-compatible: itemsize 1 or a non-multiple tail pass through
        shuf = itemsize > 1 and blen > 0 and blen % itemsize == 0
        if shuf:
            s = bops.shuffle_block(s, itemsize=itemsize, interpret=interpret)
            device_bytes += blen
        s.copy_to_host_async()      # block k's D2H overlaps block k+1's work
        blocks.append((s, shuf))
    return blocks, nbytes, device_bytes, minmax


def device_precondition(arr, *, block: int = DEFAULT_BLOCK,
                        interpret=None) -> PreshuffledChunk:
    """Run the bitshuffle preconditioner on-device and land the shuffled
    bytes on host as a `PreshuffledChunk` (the shm-transportable form the
    ParallelBpWriter hands its workers — they skip the shuffle). Min/max
    chunk stats ride along from a device-side reduction."""
    t0 = time.perf_counter()
    dt = np.dtype(arr.dtype)
    with TRACER.span("device_shuffle", length=int(arr.size) * dt.itemsize):
        blocks, nbytes, dev_bytes, minmax = _device_shuffled_blocks(
            arr, block, dt.itemsize, interpret)
        host = np.empty(nbytes, np.uint8)
        pos = 0
        for s, _shuf in blocks:
            h = np.asarray(s)
            host[pos:pos + h.size] = h
            pos += h.size
    vmin = float(np.asarray(minmax[0])) if minmax else 0.0
    vmax = float(np.asarray(minmax[1])) if minmax else 0.0
    if METRICS.enabled:
        METRICS.observe("device_shuffle", time.perf_counter() - t0,
                        nbytes=nbytes)
    return PreshuffledChunk(host, dt, arr.shape, block, vmin, vmax,
                            device_bytes=dev_bytes)


def array_payload_preshuffled(chunk: PreshuffledChunk, codec: str) -> bytes:
    """Finish a device-preconditioned chunk's encode on host: Z_RLE each
    already-shuffled block (the worker-side half of the split pipeline).
    Block boundaries were fixed at precondition time (`chunk.block`)."""
    name, _bound, _rel = parse_codec(codec)
    if name not in ("blosc", "none"):
        raise ValueError(
            f"codec {codec!r} cannot encode a pre-shuffled chunk — "
            f"precondition only when codec_wants_device() says so")
    mv = memoryview(chunk.data).cast("B")
    out = []
    for i in range(0, max(len(mv), 1), chunk.block):
        out.append(_compress_block(mv[i:i + chunk.block], name,
                                   chunk.itemsize, preshuffled=True))
    return b"".join(out)


def device_array_payload(arr, codec: str, block: int = DEFAULT_BLOCK, *,
                         interpret=None) -> tuple[bytes, DeviceStats]:
    """Full on-device encode pipeline (the thread-pool engine's path):
    per codec block, shuffle on-chip and start the async D2H, then run the
    host Z_RLE stage on block k-1 while block k is still in flight —
    double-buffered overlap. Returns (payload, DeviceStats).

    Codecs whose preconditioner cannot run on-device (lossy quantization,
    zlib/bzip2 ablations, plain "none") materialize the array once and
    take the host encoder."""
    name, _bound, _rel = parse_codec(codec)
    dt = np.dtype(arr.dtype)
    if name != "blosc":
        a = np.asarray(arr)
        stats = DeviceStats()
        if dt.kind in "fiub" and a.size:
            stats.vmin = float(np.min(a))
            stats.vmax = float(np.max(a))
        return array_payload(a, codec, block), stats
    t0 = time.perf_counter()
    with TRACER.span("device_shuffle", length=int(arr.size) * dt.itemsize):
        blocks, nbytes, device_bytes, minmax = _device_shuffled_blocks(
            arr, block, dt.itemsize, interpret)
        out = []
        lz_s = lz_last = 0.0
        for s, shuf in blocks:
            h = np.asarray(s)       # lands block k; k+1's D2H is in flight
            t1 = time.perf_counter()
            out.append(_compress_block(h.data, name, dt.itemsize,
                                       preshuffled=shuf))
            t2 = time.perf_counter()
            lz_s += t2 - t1
            lz_last = t2 - t1
    wall = time.perf_counter() - t0
    stats = DeviceStats(
        device_bytes=device_bytes,
        # LZ seconds that ran while a later block was still in the device/
        # transfer stage — every block's LZ except the last overlaps
        overlap_s=lz_s - lz_last if len(blocks) > 1 else 0.0,
        vmin=float(np.asarray(minmax[0])) if minmax else 0.0,
        vmax=float(np.asarray(minmax[1])) if minmax else 0.0)
    if METRICS.enabled:
        METRICS.observe("device_shuffle", wall, nbytes=nbytes)
    return b"".join(out), stats
