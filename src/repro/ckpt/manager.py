"""CheckpointManager: async double-buffered writes, retention, auto-restart.

Fault-tolerance contract (DESIGN.md §6):
  * training never blocks on storage — save() snapshots the state to host
    (device->host copy) and hands it to a writer thread; the checkpoint
    write (through the JBP async pipeline when `engine_async`) then
    OVERLAPS the next train step, and `wait()` is the barrier that
    re-serialises producer and writer,
  * a checkpoint becomes visible only after its atomic rename; a crash
    mid-write leaves a .tmp the next run ignores,
  * restore_latest() walks checkpoints newest-first and returns the first
    one whose md.idx validates (torn/corrupt ones are skipped),
  * keep_n retention runs behind the durability barrier: old checkpoints
    are evicted only AFTER the newer one's sealed md.idx + rename — the
    same wait()-before-eviction ordering the writer job enforces in-line.
"""
from __future__ import annotations

import pathlib
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as CK
from repro.core.bp_engine import EngineConfig


class CheckpointManager:
    def __init__(self, directory, *, every: int = 100, keep_n: int = 3,
                 n_io_ranks: int = 8,
                 engine_config: EngineConfig = EngineConfig(),
                 async_write: bool = True, engine_async: bool = False,
                 parallel_io: int = 0, transport: str = "shm",
                 device_compress: bool = False):
        # async_write is what hides checkpoint I/O behind the next train
        # step (the writer thread). engine_async additionally routes the
        # write through AsyncBpWriter — correctness-neutral (checkpoints
        # force fsync_policy="step", a blocking seal), useful when shared
        # pipeline profiling is wanted; off by default. parallel_io=W
        # routes the write through W real writer processes instead
        # (repro.core.parallel_engine) — compression and subfile appends
        # leave the training process entirely; takes precedence over
        # engine_async. The W processes are a PERSISTENT WriterPlane:
        # spawned lazily on the first save and retargeted per checkpoint,
        # so the spawn cost is paid once per run, not once per `every`
        # steps; with transport="shm" (default) the plane's per-worker
        # shared-memory rings stay mapped across saves too, so every save
        # ships leaf chunks by memcpy + header instead of pickling the
        # whole state down worker queues. `close()` tears the plane down
        # and unlinks the rings (a finalizer covers abnormal exits).
        # device_compress=True keeps device leaves ON-CHIP at save():
        # jax.Arrays are immutable, so the snapshot needs no host copy,
        # and save_checkpoint byte-shuffles each shard on the accelerator
        # before the writer handoff (workers then skip the shuffle).
        self.dir = pathlib.Path(str(directory))
        self.dir.mkdir(parents=True, exist_ok=True)
        self.every = every
        self.keep_n = keep_n
        self.n_io_ranks = n_io_ranks
        self.engine_config = engine_config
        self.async_write = async_write
        self.engine_async = engine_async
        self.parallel_io = int(parallel_io)
        self.transport = transport
        self.device_compress = bool(device_compress)
        self._plane = None                       # lazy persistent write plane
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps: list[int] = []
        # overlap accounting: how long save()/wait() actually stalled the
        # producer vs how long the background writes took
        self.stats = {"saves": 0, "blocked_s": 0.0, "write_s": 0.0}

    # ----------------------------------------------------------------- save
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def wait(self):
        """Barrier: the in-flight checkpoint (if any) is durable on return.
        Must run before eviction and before the manager is torn down."""
        t0 = time.perf_counter()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            self.stats["blocked_s"] += time.perf_counter() - t0
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def overlap_fraction(self) -> float:
        """Share of checkpoint write time hidden behind training compute."""
        w = self.stats["write_s"]
        return max(0.0, 1.0 - self.stats["blocked_s"] / w) if w > 0 else 0.0

    def _writer_plane(self):
        """The persistent parallel write plane, spawned on first use and
        respawned if its workers died (e.g. a prior save crashed them)."""
        if not self.parallel_io:
            return None
        if self._plane is not None and not self._plane.alive():
            self._plane.shutdown()
            self._plane = None
        if self._plane is None:
            from repro.core.parallel_engine import WriterPlane
            self._plane = WriterPlane(self.parallel_io,
                                      transport=self.transport)
        return self._plane

    def save(self, state, step: int, *, force: bool = False):
        if not force and not self.should_save(step):
            return False
        self.wait()                                  # one write in flight max

        def snap(x):
            # with device_compress a jax.Array stays on-chip: it is
            # immutable, so the producer can keep training on it while
            # the writer shuffles/compresses this very buffer
            from repro.core import compression as C
            if self.device_compress and C.is_device_array(x):
                return x
            return np.asarray(jax.device_get(x))

        host_state = jax.tree_util.tree_map(snap, state)

        def job():
            try:
                t0 = time.perf_counter()
                CK.save_checkpoint(self.dir, host_state, step,
                                   n_io_ranks=self.n_io_ranks,
                                   engine_config=self.engine_config,
                                   async_io=(self.engine_async
                                             and not self.parallel_io),
                                   parallel_io=self.parallel_io,
                                   writer_plane=self._writer_plane(),
                                   device_compress=self.device_compress)
                self.stats["write_s"] += time.perf_counter() - t0
                self.saved_steps.append(step)
                # durability barrier passed (sealed md.idx + rename above):
                # only now may older checkpoints be evicted
                self._retain()
            except BaseException as e:               # noqa: BLE001
                self._error = e

        self.stats["saves"] += 1
        if self.async_write:
            self._thread = threading.Thread(target=job, daemon=True)
            self._thread.start()
        else:
            t0 = time.perf_counter()
            job()                    # inline write: all of it blocks training
            self.stats["blocked_s"] += time.perf_counter() - t0
        return True

    def _retain(self):
        steps = CK.list_checkpoints(self.dir)
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(CK.checkpoint_path(self.dir, s), ignore_errors=True)
        for tmp in self.dir.glob("*.bp4.tmp"):       # torn writes
            shutil.rmtree(tmp, ignore_errors=True)

    def close(self):
        """Drain the in-flight save and tear down the persistent writer
        plane (if any). The manager stays usable — a later save respawns
        the plane lazily."""
        try:
            self.wait()
        finally:
            plane, self._plane = self._plane, None
            if plane is not None:
                plane.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    # -------------------------------------------------------------- restore
    def restore_latest(self, like, shardings=None, *, parallel: int = 0):
        """Newest valid checkpoint, or None if there is none. `parallel=N`
        fans each leaf's chunk reads over a ReaderPool."""
        self.wait()
        steps = CK.list_checkpoints(self.dir)
        for step in reversed(steps):
            try:
                if shardings is not None:
                    return CK.restore_sharded(self.dir, like, shardings,
                                              step=step, parallel=parallel)
                return CK.restore_checkpoint(self.dir, like, step=step,
                                             parallel=parallel)
            except Exception:                        # noqa: BLE001
                continue
        return None
