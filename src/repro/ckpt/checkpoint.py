"""Training-state checkpoint/restore through the JBP (openPMD/BP4) engine.

The checkpoint is one openPMD-style step whose variables are the flattened
TrainState leaves ("params/stack/layers/attn/wq/w", ...). Each leaf is
written as chunks by logical I/O rank — from real jax.Array shards when the
array is sharded, else by row-split — so N ranks -> M aggregator subfiles
exactly as the paper's BIT1 checkpoints (.dmp) map onto BP4.

Restore supports ELASTIC RE-SHARDING: `restore_sharded` reads, per device of
the *new* mesh, exactly the box that shard needs (BpReader box selection),
so a job restarted at a different scale never reads the full state.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core import compression as C
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import CTR, MONITOR

SEP = "/"


def _to_storage(arr: np.ndarray) -> np.ndarray:
    """bfloat16 (ml_dtypes) round-trips through raw uint16 storage."""
    if arr.dtype.itemsize == 2 and "bfloat16" in str(arr.dtype):
        return arr.view(np.uint16)
    return arr


def _from_storage(arr: np.ndarray, target_dtype) -> np.ndarray:
    if arr.dtype == np.uint16 and "bfloat16" in str(np.dtype(target_dtype)):
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    return arr.astype(target_dtype)


def flatten_state(state) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        name = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[name] = leaf
    return flat


def _leaf_chunks(arr: np.ndarray, n_ranks: int):
    """(rank, offset, chunk) row-split of a host array (scalars -> [1])."""
    if arr.ndim == 0:
        yield 0, (0,), arr.reshape(1)
        return
    n = min(n_ranks, arr.shape[0]) or 1
    bounds = np.linspace(0, arr.shape[0], n + 1).astype(int)
    for r in range(n):
        lo, hi = int(bounds[r]), int(bounds[r + 1])
        if hi > lo:
            yield r, (lo,) + (0,) * (arr.ndim - 1), arr[lo:hi]


def save_checkpoint(directory, state, step: int, *, n_io_ranks: int = 8,
                    engine_config: EngineConfig = EngineConfig(),
                    extra_attrs: Optional[dict] = None,
                    async_io: bool = False,
                    parallel_io: int = 0,
                    writer_plane=None,
                    transport: str = "shm",
                    device_compress: bool = False) -> pathlib.Path:
    """Atomic checkpoint write: <dir>/step_<N>.bp4 (.tmp + rename).

    With `async_io` the write goes through the AsyncBpWriter pipeline;
    fsync_policy is still forced to "step", which the async engine honours
    with a BLOCKING seal — so by the time the .tmp is renamed the step's
    md.idx record is durable either way. `parallel_io=W` instead writes
    through W real writer processes (two-phase commit; the md.idx seal and
    every subfile/shard fsync precede the rename), with chunk bytes moved
    over per-worker shared-memory rings (`transport="shm"`, the default)
    rather than pickled down queues. `writer_plane` (a
    `repro.core.parallel_engine.WriterPlane`) supplies ALREADY-RUNNING
    writer processes for the parallel path — the spawn cost is the plane
    owner's, paid once per run instead of once per save, and the plane's
    rings stay mapped across saves (the plane inherits its own transport;
    `transport` applies to the spawn-per-save path).

    `device_compress=True` byte-shuffles sharded device leaves ON-CHIP
    (repro.core.compression.device_precondition) before the writer hand-
    off — with parallel_io the workers receive pre-shuffled bytes over
    the shm rings and pay only the LZ stage. Unsharded/host leaves and
    bfloat16 (raw uint16 storage) keep the host path."""
    directory = pathlib.Path(str(directory))
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}.bp4"
    tmp = directory / f"step_{step:08d}.bp4.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)

    flat = flatten_state(state)
    import dataclasses as _dc
    cfg = _dc.replace(engine_config, fsync_policy="step",
                      device_compress=(device_compress
                                       or engine_config.device_compress))
    use_dev = cfg.device_compress and C.codec_wants_device(cfg.codec)
    if parallel_io or writer_plane is not None:
        from repro.core.parallel_engine import ParallelBpWriter
        w = ParallelBpWriter(tmp, n_io_ranks, cfg,
                             n_writers=parallel_io or None,
                             plane=writer_plane, transport=transport)
    elif async_io:
        from repro.core.async_engine import AsyncBpWriter
        w = AsyncBpWriter(tmp, n_io_ranks, cfg)
    else:
        w = BpWriter(tmp, n_io_ranks, cfg)
    try:
        w.begin_step(step)
        w.set_attribute("checkpoint/step", step)
        w.set_attribute("checkpoint/n_leaves", len(flat))
        for k, v in (extra_attrs or {}).items():
            w.set_attribute(k, v)
        for name, leaf in flat.items():
            dev_ok = (use_dev and "bfloat16" not in str(leaf.dtype)
                      and getattr(leaf, "ndim", 0) > 0)
            if hasattr(leaf, "addressable_shards") and len(leaf.addressable_shards) > 1:
                gshape = tuple(leaf.shape)
                for sh in leaf.addressable_shards:
                    off = tuple(sl.start or 0 for sl in sh.index) if sh.index else ()
                    if dev_ok:
                        # on-chip bitshuffle per shard BEFORE the writer
                        # handoff: downstream (threads or shm workers)
                        # only runs the LZ stage on pre-shuffled bytes
                        chunk = C.device_precondition(
                            sh.data, block=cfg.compression_block)
                        MONITOR.record(0, str(tmp),
                                       CTR.COMPRESS_DEVICE_BYTES,
                                       inc=float(chunk.device_bytes))
                        w.put(f"state/{name}", chunk, global_shape=gshape,
                              offset=off, rank=sh.device.id)
                    else:
                        w.put(f"state/{name}", _to_storage(np.asarray(sh.data)),
                              global_shape=gshape, offset=off,
                              rank=sh.device.id)
            elif dev_ok and C.is_device_array(leaf):
                # single-shard device leaf: keep it on-device — the engine
                # preconditions it itself (cfg.device_compress is set)
                w.put(f"state/{name}", leaf, global_shape=tuple(leaf.shape),
                      offset=(0,) * leaf.ndim, rank=0)
            else:
                host = _to_storage(np.asarray(jax.device_get(leaf)))
                gshape = host.shape if host.ndim else (1,)
                for r, off, chunk in _leaf_chunks(host, n_io_ranks):
                    w.put(f"state/{name}", chunk, global_shape=gshape,
                          offset=off, rank=r)
        w.end_step()
    except BaseException:
        # a failed save must not leak the writer thread / open md handles;
        # the ORIGINAL error is what propagates
        try:
            w.close()
        except BaseException:        # noqa: BLE001
            pass
        raise
    w.close()
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (directory / "latest.txt").write_text(str(step))
    return final


def list_checkpoints(directory) -> list[int]:
    directory = pathlib.Path(str(directory))
    out = []
    for p in sorted(directory.glob("step_*.bp4")):
        try:
            with BpReader(p) as reader:
                if reader.valid_steps():
                    out.append(int(p.name[5:13]))
        except Exception:       # noqa: BLE001 — corrupt checkpoint: skip
            continue
    return sorted(out)


def checkpoint_path(directory, step: int) -> pathlib.Path:
    return pathlib.Path(str(directory)) / f"step_{step:08d}.bp4"


def restore_checkpoint(directory, like, step: Optional[int] = None,
                       *, parallel: int = 0):
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs). Full-array read (single-host path). `parallel=N`
    fans multi-chunk leaf reads over a ReaderPool; the context manager
    guarantees the reader (pool + subfile handles) is released even when
    a leaf is missing or corrupt mid-restore."""
    directory = pathlib.Path(str(directory))
    steps = list_checkpoints(directory)
    if not steps:
        raise FileNotFoundError(f"no valid checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    flat = flatten_state(like)
    out = {}
    with BpReader(checkpoint_path(directory, step),
                  parallel=parallel) as reader:
        for name, leaf in flat.items():
            arr = reader.read_var(step, f"state/{name}")
            out[name] = _from_storage(arr, leaf.dtype).reshape(leaf.shape)
    return unflatten_like(like, out), step


def restore_sharded(directory, like, shardings, step: Optional[int] = None,
                    *, parallel: int = 0):
    """Elastic restore: `like` + `shardings` describe the NEW mesh layout;
    every device shard reads exactly its box from the chunk table."""
    directory = pathlib.Path(str(directory))
    steps = list_checkpoints(directory)
    if not steps:
        raise FileNotFoundError(f"no valid checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    flat_like = flatten_state(like)
    flat_sh = flatten_state(shardings)
    out = {}
    with BpReader(checkpoint_path(directory, step),
                  parallel=parallel) as reader:
        for name, leaf in flat_like.items():
            sh = flat_sh[name]
            var = f"state/{name}"

            def fetch(idx, _var=var, _leaf=leaf):
                off = tuple((sl.start or 0) for sl in idx)
                ext = tuple((sl.stop if sl.stop is not None else s) -
                            (sl.start or 0) for sl, s in zip(idx, _leaf.shape))
                a = reader.read_var(step, _var, off, ext)
                return _from_storage(a, _leaf.dtype)

            if leaf.ndim == 0:
                arr = _from_storage(reader.read_var(step, var),
                                    leaf.dtype).reshape(())
                out[name] = jax.device_put(arr, sh)
            else:
                out[name] = jax.make_array_from_callback(leaf.shape, sh, fetch)
    return unflatten_like(like, out), step


def unflatten_like(like, flat: dict):
    treedef = jax.tree_util.tree_structure(like)
    paths = [SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
             for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    return jax.tree_util.tree_unflatten(treedef, [flat[p] for p in paths])
