"""1D grid operations: charge deposition (CIC) and binomial smoothing.

Deposition is the classic PIC particle-to-grid scatter; the jnp
implementation here is the oracle for the Pallas `deposit` kernel
(kernels/deposit), which restates it as one-hot matmuls for the MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def deposit_cic(x, weight, alive, n_cells: int, dx: float):
    """Cloud-in-cell deposition. x: [N] positions, weight: [N], alive: [N]
    -> density [n_cells] (guard cells folded)."""
    xi = x / dx
    i0 = jnp.floor(xi).astype(jnp.int32)
    frac = xi - i0
    w = weight * alive
    i0c = jnp.clip(i0, 0, n_cells - 1)
    i1c = jnp.clip(i0 + 1, 0, n_cells - 1)
    rho = jnp.zeros((n_cells,), jnp.float32)
    rho = rho.at[i0c].add(w * (1.0 - frac))
    rho = rho.at[i1c].add(w * frac)
    return rho / dx


def smooth_121(rho):
    """Binomial (1,2,1)/4 digital filter — BIT1's density smoothing phase."""
    left = jnp.roll(rho, 1).at[0].set(rho[0])
    right = jnp.roll(rho, -1).at[-1].set(rho[-1])
    return 0.25 * left + 0.5 * rho + 0.25 * right


def gather_field(E, x, dx: float):
    """Grid-to-particle linear interpolation of the field at positions x."""
    n = E.shape[0]
    xi = x / dx
    i0 = jnp.floor(xi).astype(jnp.int32)
    frac = xi - i0
    i0c = jnp.clip(i0, 0, n - 1)
    i1c = jnp.clip(i0 + 1, 0, n - 1)
    return E[i0c] * (1.0 - frac) + E[i1c] * frac
