"""1D electrostatic field solver: -phi'' = rho/eps0, E = -phi'.

Tridiagonal Thomas algorithm expressed as two lax.scans (O(n), stable for
the diagonally-dominant Poisson system), Dirichlet walls phi(0)=phi(L)=0 —
BIT1's field-solver phase."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def thomas_solve(a, b, c, d):
    """Solve tridiag(a,b,c) x = d. a[0] and c[-1] ignored. All [n]."""
    def fwd(carry, ys):
        cp_prev, dp_prev = carry
        ai, bi, ci, di = ys
        denom = bi - ai * cp_prev
        cp = ci / denom
        dp = (di - ai * dp_prev) / denom
        return (cp, dp), (cp, dp)

    (_, _), (cps, dps) = jax.lax.scan(fwd, (jnp.array(0.0, d.dtype),
                                            jnp.array(0.0, d.dtype)),
                                      (a, b, c, d))

    def bwd(x_next, ys):
        cp, dp = ys
        x = dp - cp * x_next
        return x, x

    _, xs = jax.lax.scan(bwd, jnp.array(0.0, d.dtype), (cps, dps),
                         reverse=True)
    return xs


def solve_poisson(rho, dx: float, eps0: float = 1.0):
    """phi on cell centers with phi=0 walls; returns (phi, E) on the grid."""
    n = rho.shape[0]
    h2 = dx * dx
    a = jnp.full((n,), -1.0, rho.dtype)
    b = jnp.full((n,), 2.0, rho.dtype)
    c = jnp.full((n,), -1.0, rho.dtype)
    d = rho * h2 / eps0
    phi = thomas_solve(a, b, c, d)
    # E = -dphi/dx, central differences; one-sided at walls
    E = jnp.zeros_like(phi)
    E = E.at[1:-1].set(-(phi[2:] - phi[:-2]) / (2 * dx))
    E = E.at[0].set(-(phi[1] - phi[0]) / dx)
    E = E.at[-1].set(-(phi[-1] - phi[-2]) / dx)
    return phi, E
