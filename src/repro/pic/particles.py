"""Species containers (SoA, fixed capacity + alive mask) and the particle
mover — BIT1 is 1D3V: one spatial dim, three velocity dims."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Species(NamedTuple):
    x: jnp.ndarray          # [C] position
    v: jnp.ndarray          # [C, 3] velocity (vx drives motion)
    w: jnp.ndarray          # [C] macro-particle weight
    alive: jnp.ndarray      # [C] float mask (1.0 alive / 0.0 dead)
    charge: float
    mass: float

    @property
    def capacity(self):
        return self.x.shape[0]

    def count(self):
        return jnp.sum(self.alive)

    def density_weight(self):
        return jnp.sum(self.w * self.alive)


def init_species(key, capacity: int, n_active: int, *, L: float,
                 v_thermal: float, charge: float, mass: float,
                 weight: float = 1.0) -> Species:
    kx, kv = jax.random.split(key)
    x = jax.random.uniform(kx, (capacity,), jnp.float32, 0.0, L)
    v = jax.random.normal(kv, (capacity, 3), jnp.float32) * v_thermal
    alive = (jnp.arange(capacity) < n_active).astype(jnp.float32)
    w = jnp.full((capacity,), weight, jnp.float32)
    return Species(x, v, w, alive, charge, mass)


def push(sp: Species, E_at_p, dt: float, L: float, *,
         boundary: str = "periodic"):
    """Leapfrog: v += (q/m) E dt; x += vx dt. Returns (species, wall_flux)."""
    accel = (sp.charge / sp.mass) * E_at_p * dt
    v = sp.v.at[:, 0].add(accel)
    x = sp.x + v[:, 0] * dt
    wall = jnp.zeros((), jnp.float32)
    if boundary == "periodic":
        x = jnp.mod(x, L)
        alive = sp.alive
    else:  # absorbing walls (divertor plates) — BIT1 plasma-wall transition
        hit = ((x < 0.0) | (x >= L)) & (sp.alive > 0)
        wall = jnp.sum(jnp.where(hit, sp.w, 0.0))
        alive = jnp.where(hit, 0.0, sp.alive)
        x = jnp.clip(x, 0.0, L * (1.0 - 1e-7))
    return sp._replace(x=x, v=v, alive=alive), wall


def spawn(sp: Species, new_x, new_v, new_w, n_new_mask) -> Species:
    """Write new particles into dead slots (static shapes: the k-th new
    particle goes to the k-th dead slot; overflow is dropped & counted).

    new_x/new_v/new_w: candidate arrays [M]; n_new_mask: [M] bool."""
    C = sp.capacity
    dead_order = jnp.argsort(sp.alive, stable=True)      # dead slots first
    k = jnp.cumsum(n_new_mask.astype(jnp.int32)) - 1     # rank among events
    n_dead = jnp.sum(sp.alive <= 0).astype(jnp.int32)
    ok = n_new_mask & (k < n_dead)
    slot = dead_order[jnp.clip(k, 0, C - 1)]
    slot = jnp.where(ok, slot, C)                        # C = trash slot
    x = jnp.concatenate([sp.x, jnp.zeros((1,), sp.x.dtype)])
    v = jnp.concatenate([sp.v, jnp.zeros((1, 3), sp.v.dtype)])
    w = jnp.concatenate([sp.w, jnp.zeros((1,), sp.w.dtype)])
    al = jnp.concatenate([sp.alive, jnp.zeros((1,), sp.alive.dtype)])
    x = x.at[slot].set(new_x)
    v = v.at[slot].set(new_v)
    w = w.at[slot].set(new_w)
    al = al.at[slot].set(1.0)
    dropped = jnp.sum(n_new_mask & ~ok)
    return sp._replace(x=x[:C], v=v[:C], w=w[:C], alive=al[:C]), dropped
