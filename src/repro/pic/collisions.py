"""Monte-Carlo collisions — the paper's use case (§III-C): electron-impact
ionization e + D -> 2e + D+ in an unbounded unmagnetized plasma, where the
neutral density decays as  dn/dt = -n * n_e * R  (R: ionization rate
coefficient). Each MC event transfers weight from the neutral species to a
newly spawned electron/ion pair."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pic.particles import Species, spawn


def ionize(key, electrons: Species, ions: Species, neutrals: Species,
           *, rate_R: float, dt: float, L: float, n_cells: int,
           electron_density_per_cell):
    """One MC ionization substep.

    For every alive NEUTRAL macro-particle, the ionization probability over
    dt is  p = 1 - exp(-n_e(x) * R * dt)  with n_e interpolated at the
    neutral's position. On an event the neutral dies and an electron/ion
    pair inherits its position and weight.
    """
    dx = L / n_cells
    ci = jnp.clip((neutrals.x / dx).astype(jnp.int32), 0, n_cells - 1)
    ne_local = electron_density_per_cell[ci]                     # [C]
    p = 1.0 - jnp.exp(-ne_local * rate_R * dt)
    u = jax.random.uniform(key, neutrals.x.shape)
    event = (u < p) & (neutrals.alive > 0)

    # neutral dies
    new_neutrals = neutrals._replace(
        alive=jnp.where(event, 0.0, neutrals.alive))

    # electron + ion inherit position/weight; thermal kick for the electron
    kv = jax.random.fold_in(key, 1)
    v_e = neutrals.v + jax.random.normal(kv, neutrals.v.shape) * 1e-2
    new_electrons, drop_e = spawn(electrons, neutrals.x, v_e, neutrals.w, event)
    new_ions, drop_i = spawn(ions, neutrals.x, neutrals.v, neutrals.w, event)
    n_events = jnp.sum(event)
    return (new_electrons, new_ions, new_neutrals,
            {"ionizations": n_events, "dropped": drop_e + drop_i})
