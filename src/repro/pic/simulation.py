"""BIT1-like 1D3V electrostatic PIC-MC simulation driver.

Implements the five-phase PIC cycle of the paper (§II): deposition ->
smoothing -> field solve -> MC collisions/walls -> push. The paper's use
case (§III-C — neutral ionization in an unbounded unmagnetized plasma,
no field solver or smoother) is `PicConfig(field_solve=False,
boundary='periodic')` with three species (e, D+, D).

Diagnostics mirror BIT1's five I/O knobs: `mvstep`-periodic profile/
distribution diagnostics (.dat analogue -> openPMD meshes) and
`dmpstep`-periodic full particle state dumps (.dmp analogue -> openPMD
particle species through the JBP engine).

With `open_diagnostic_series(..., async_io=True)` (the default) a dump only
snapshots host arrays and enqueues the step: compression, aggregation and
the subfile/metadata writes happen on the engine's background pipeline
while the next `pic_run_chunk` is already pushing/depositing on device —
the paper's "I/O as a background activity" claim, end to end.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.pic import collisions, fields, grid
from repro.pic.particles import Species, init_species, push


@dataclasses.dataclass(frozen=True)
class PicConfig:
    n_cells: int = 1024
    L: float = 1.0
    dt: float = 1e-3
    capacity: int = 1 << 15           # per species
    n_electrons: int = 8192
    n_ions: int = 8192
    n_neutrals: int = 8192
    v_thermal_e: float = 1.0
    v_thermal_i: float = 0.02
    rate_R: float = 0.05              # ionization rate coefficient
    boundary: str = "periodic"        # periodic | absorbing
    field_solve: bool = False         # paper's use case skips solver+smoother
    smoothing: bool = False
    eps0: float = 1.0

    @property
    def dx(self):
        return self.L / self.n_cells


class PicState(NamedTuple):
    electrons: Species
    ions: Species
    neutrals: Species
    key: jnp.ndarray
    step: jnp.ndarray
    wall_flux_e: jnp.ndarray
    wall_flux_i: jnp.ndarray
    total_ionizations: jnp.ndarray


def init_sim(cfg: PicConfig, key) -> PicState:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e = init_species(k1, cfg.capacity, cfg.n_electrons, L=cfg.L,
                     v_thermal=cfg.v_thermal_e, charge=-1.0, mass=1.0)
    i = init_species(k2, cfg.capacity, cfg.n_ions, L=cfg.L,
                     v_thermal=cfg.v_thermal_i, charge=+1.0, mass=1836.0)
    n = init_species(k3, cfg.capacity, cfg.n_neutrals, L=cfg.L,
                     v_thermal=cfg.v_thermal_i, charge=0.0, mass=1836.0)
    z = jnp.zeros((), jnp.float32)
    return PicState(e, i, n, k4, jnp.zeros((), jnp.int32), z, z, z)


@functools.partial(jax.jit, static_argnums=(1,))
def pic_step(state: PicState, cfg: PicConfig) -> PicState:
    e, i, n = state.electrons, state.ions, state.neutrals
    dx = cfg.dx

    # 1-2. deposition + smoothing
    rho_e = grid.deposit_cic(e.x, e.w, e.alive, cfg.n_cells, dx)
    rho_i = grid.deposit_cic(i.x, i.w, i.alive, cfg.n_cells, dx)
    rho = i.charge * rho_i + e.charge * rho_e
    if cfg.smoothing:
        rho = grid.smooth_121(rho)

    # 3. field solve
    if cfg.field_solve:
        _, E = fields.solve_poisson(rho, dx, cfg.eps0)
    else:
        E = jnp.zeros((cfg.n_cells,), jnp.float32)

    # 4. MC collisions (ionization) — needs n_e per cell
    key, sub = jax.random.split(state.key)
    e, i, n, info = collisions.ionize(
        sub, e, i, n, rate_R=cfg.rate_R, dt=cfg.dt, L=cfg.L,
        n_cells=cfg.n_cells, electron_density_per_cell=rho_e * dx)

    # 5. push + walls
    e, wf_e = push(e, grid.gather_field(E, e.x, dx), cfg.dt, cfg.L,
                   boundary=cfg.boundary)
    i, wf_i = push(i, grid.gather_field(E, i.x, dx), cfg.dt, cfg.L,
                   boundary=cfg.boundary)
    n, _ = push(n, jnp.zeros_like(n.x), cfg.dt, cfg.L, boundary=cfg.boundary)

    return PicState(e, i, n, key, state.step + 1,
                    state.wall_flux_e + wf_e, state.wall_flux_i + wf_i,
                    state.total_ionizations + info["ionizations"])


@functools.partial(jax.jit, static_argnums=(1, 2))
def pic_run_chunk(state: PicState, cfg: PicConfig, n_steps: int) -> PicState:
    return jax.lax.fori_loop(0, n_steps, lambda _, s: pic_step(s, cfg), state)


# ---------------------------------------------------------------- diagnostics
def diagnostics(state: PicState, cfg: PicConfig, *, v_bins: int = 64) -> dict:
    """BIT1 'slow' diagnostics: plasma profiles + velocity/energy dists."""
    out = {}
    for name, sp in (("e", state.electrons), ("D_plus", state.ions),
                     ("D", state.neutrals)):
        dens = grid.deposit_cic(sp.x, sp.w, sp.alive, cfg.n_cells, cfg.dx)
        out[f"density/{name}"] = np.asarray(dens)
        vmag = jnp.linalg.norm(sp.v, axis=-1)
        hist, _ = jnp.histogram(vmag, bins=v_bins, range=(0.0, 5.0),
                                weights=sp.w * sp.alive)
        out[f"vdist/{name}"] = np.asarray(hist)
        energy = 0.5 * sp.mass * vmag**2
        ehist, _ = jnp.histogram(energy, bins=v_bins, range=(0.0, 10.0),
                                 weights=sp.w * sp.alive)
        out[f"edist/{name}"] = np.asarray(ehist)
        out[f"count/{name}"] = float(sp.count())
    out["wall_flux/e"] = float(state.wall_flux_e)
    out["wall_flux/i"] = float(state.wall_flux_i)
    out["ionizations"] = float(state.total_ionizations)
    return out


def write_diagnostics_openpmd(series, state: PicState, cfg: PicConfig,
                              *, n_io_ranks: int = 8, diag: Optional[dict] = None):
    """Stream one diagnostic snapshot through openPMD (datfile analogue).
    Pass a precomputed `diag` to share one snapshot between the openPMD
    write and in-situ consumers (reducers / SST streams)."""
    step = int(state.step)
    it = series.iterations[step]
    it.time = step * cfg.dt
    if diag is None:
        diag = diagnostics(state, cfg)
    for name, arr in diag.items():
        if not isinstance(arr, np.ndarray):
            continue
        rc = it.meshes[name.replace("/", "_")][""]
        rc.reset_dataset(arr.dtype, arr.shape)
        # profile diagnostics are rank-decomposed like BIT1's grid split
        n = arr.shape[0]
        per = max(n // n_io_ranks, 1)
        for r in range(min(n_io_ranks, n)):
            lo = r * per
            hi = n if r == min(n_io_ranks, n) - 1 else (r + 1) * per
            rc.store_chunk(arr[lo:hi], offset=(lo,), rank=r)
    return it


def open_diagnostic_series(path, *, n_io_ranks: int = 8, async_io: bool = True,
                           engine_config=None, queue_depth: int = 2,
                           parallel_io: int = 0,
                           device_compress: bool = False):
    """Series for BIT1-style diagnostic output, async by default so dumps
    never stall the push/deposit loop.

    `parallel_io=W` opts in to the multi-process write plane: W real
    writer processes stream into W aggregated subfiles (compression and
    subfile appends leave this process entirely, chunks shipped over
    shared-memory rings), each dump committed by a two-phase commit. The
    async default COMPOSES with it — the commit runs behind a bounded
    snapshot queue (`async_commit`), so the push/deposit loop sees
    neither compression nor commit latency.

    `device_compress=True` turns on the on-chip compression precondition:
    jax.Array chunks stored on the series are byte-shuffled on the
    accelerator (the Pallas bitshuffle kernel) before the host runs only
    the cheap LZ stage."""
    from repro.core.bp_engine import EngineConfig
    from repro.core.openpmd import Series
    if engine_config is None:
        engine_config = EngineConfig(aggregators=min(4, n_io_ranks),
                                     codec="blosc")
    dc = True if device_compress else None   # None: engine_config decides
    if parallel_io:
        return Series(path, "w", n_ranks=n_io_ranks,
                      engine_config=engine_config, parallel_io=parallel_io,
                      async_commit=async_io, queue_depth=queue_depth,
                      device_compress=dc)
    return Series(path, "w", n_ranks=n_io_ranks, engine_config=engine_config,
                  async_io=async_io, queue_depth=queue_depth,
                  device_compress=dc)


def run_with_diagnostics(state: PicState, cfg: PicConfig, series=None, *,
                         n_chunks: int, steps_per_chunk: int,
                         dump_every: int = 0, n_io_ranks: int = 8,
                         reducers=None, stream=None) -> PicState:
    """BIT1 main loop: jitted compute chunks interleaved with mvstep
    diagnostics (every chunk) and dmpstep particle dumps (every
    `dump_every` chunks). With an async series, `flush()` returns after the
    snapshot and the next chunk's compute overlaps the write pipeline; the
    final `drain()` is the durability barrier before returning.

    In-situ hooks (repro.insitu): each chunk's diagnostic snapshot is
    computed ONCE and fanned out to
      * `series`   — openPMD persistence (optional: pass None to run a
                     pure in-situ pipeline with no filesystem in the loop),
      * `stream`   — an `SstStream`; consumers (e.g. `attach_reducers`)
                     analyze live while the next chunk computes,
      * `reducers` — a `ReducerSet` updated inline on the producer thread
                     (run-time diagnostics without a consumer thread).
    """
    for c in range(n_chunks):
        state = pic_run_chunk(state, cfg, steps_per_chunk)
        step = int(state.step)
        diag = diagnostics(state, cfg)
        arrays = {k: v for k, v in diag.items() if isinstance(v, np.ndarray)}
        if series is not None:
            write_diagnostics_openpmd(series, state, cfg,
                                      n_io_ranks=n_io_ranks, diag=diag)
            if dump_every and (c + 1) % dump_every == 0:
                write_particle_dump_openpmd(series, state, cfg,
                                            n_io_ranks=n_io_ranks)
            series.flush()
        if stream is not None:
            stream.begin_step(step)
            for name, arr in arrays.items():
                stream.put(name, arr, global_shape=arr.shape,
                           offset=(0,) * arr.ndim)
            stream.end_step()
        if reducers is not None:
            reducers.update(step, arrays)
    if series is not None:
        series.drain()
    return state


def write_particle_dump_openpmd(series, state: PicState, cfg: PicConfig,
                                *, n_io_ranks: int = 8):
    """Full particle state (dmp analogue): species records chunked by rank."""
    step = int(state.step)
    it = series.iterations[step]
    for name, sp in (("e", state.electrons), ("D_plus", state.ions),
                     ("D", state.neutrals)):
        species = it.particles[name]
        arrays = {"position/x": np.asarray(sp.x),
                  "momentum/x": np.asarray(sp.v[:, 0]),
                  "momentum/y": np.asarray(sp.v[:, 1]),
                  "momentum/z": np.asarray(sp.v[:, 2]),
                  "weighting": np.asarray(sp.w * sp.alive)}
        C = sp.capacity
        per = max(C // n_io_ranks, 1)
        for rec_name, arr in arrays.items():
            rec, comp = (rec_name.split("/") + [""])[:2]
            rc = species[rec][comp]
            rc.reset_dataset(arr.dtype, arr.shape)
            for r in range(min(n_io_ranks, C)):
                lo = r * per
                hi = C if r == min(n_io_ranks, C) - 1 else (r + 1) * per
                rc.store_chunk(arr[lo:hi], offset=(lo,), rank=r)
    return it
