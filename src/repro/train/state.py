"""TrainState: params + AdamW moments + step (+ optional error-feedback
residuals for int8 cross-pod gradient compression)."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.optim.grad_compress import init_residuals


def init_train_state(cfg, key, *, grad_compression: bool = False) -> dict:
    params = M.init_params(cfg, key)
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    if grad_compression:
        state["residuals"] = init_residuals(params)
    return state


def train_state_shapes(cfg, *, grad_compression: bool = False):
    return jax.eval_shape(
        lambda k: init_train_state(cfg, k, grad_compression=grad_compression),
        jax.random.PRNGKey(0))


def train_state_shardings(cfg, mesh, *, grad_compression: bool = False):
    """NamedSharding pytree for the full TrainState.

    Params: TP over `model` + ZeRO-3 over `data` (replicated across pods —
    DCN carries only gradients). Optimizer moments additionally shard over
    `pod` (ZeRO-1 across DCN): they are touched once per step, so the extra
    pod-axis reshard is one params-sized exchange — and it is what lets
    arctic-480b's 3.8 TB of f32 moments fit 16 GB chips on 2 pods."""
    from repro.launch import sharding as S
    shapes = train_state_shapes(cfg, grad_compression=grad_compression)
    pshard = S.param_sharding_tree(cfg, mesh, shapes["params"])
    oshard = S.opt_sharding_tree(cfg, mesh, shapes["params"])
    out: dict[str, Any] = {
        "params": pshard,
        "opt": {"m": oshard, "v": oshard},
        "step": S.replicated(mesh),
    }
    if grad_compression:
        out["residuals"] = oshard
    return out
