"""The jitted train step: remat'd forward/backward + sharded AdamW.

Gradient reduction over `data`/`pod` is inserted by the SPMD partitioner from
the sharding constraints; optional int8 error-feedback compression models the
cross-pod (DCN) all-reduce (see optim/grad_compress.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.grad_compress import compress_with_feedback


def make_train_step(cfg, hp: AdamWConfig, *, grad_compression: bool = False,
                    remat: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024, ssd_chunk: int = 128,
                    microbatches: int = 1):
    """microbatches > 1 = gradient accumulation: the global batch is split
    into k sequential microbatches (lax.scan), shrinking live activations by
    k at the cost of k smaller collective rounds. This is what makes the
    90B/480B train_4k cells fit 16 GB HBM (EXPERIMENTS.md §Perf it.5)."""
    from repro.meshctx import shard_hint

    def grads_and_metrics(params, batch):
        def lf(p):
            return M.loss_fn(p, cfg, batch, remat=remat, q_chunk=q_chunk,
                             kv_chunk=kv_chunk, ssd_chunk=ssd_chunk)
        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return grads, metrics

    def train_step(state, batch):
        if microbatches == 1:
            grads, metrics = grads_and_metrics(state["params"], batch)
        else:
            k = microbatches

            def split(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])

            mb_batch = {kk: split(v) for kk, v in batch.items()
                        if v is not None}

            def body(acc, mb):
                mb = {kk: shard_hint(v, ("pod", "data"),
                                     *([None] * (v.ndim - 1)))
                      for kk, v in mb.items()}
                g, m = grads_and_metrics(state["params"], mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            grads, ms = jax.lax.scan(body, zeros, mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            metrics = jax.tree_util.tree_map(lambda m: m.mean(), ms)

        new_state = dict(state)
        if grad_compression:
            grads, new_state["residuals"] = compress_with_feedback(
                grads, state["residuals"])
        new_params, new_opt, om = adamw_update(state["params"], grads,
                                               state["opt"], state["step"], hp)
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        return new_state, {**metrics, **om}

    return train_step


def make_eval_step(cfg, *, q_chunk: int = 1024, kv_chunk: int = 1024,
                   ssd_chunk: int = 128):
    def eval_step(params, batch):
        _, metrics = M.loss_fn(params, cfg, batch, remat=False, q_chunk=q_chunk,
                               kv_chunk=kv_chunk, ssd_chunk=ssd_chunk)
        return metrics
    return eval_step
