"""Fault-tolerant training loop.

Wires together: jitted train step, synthetic data pipeline, async openPMD/JBP
checkpointing (CheckpointManager), automatic restart from the newest valid
checkpoint, and a crash-injection hook used by the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import SyntheticTokens
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_keep: int = 3
    seed: int = 0
    seq_len: int = 256
    global_batch: int = 8
    grad_compression: bool = False


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, hp: AdamWConfig,
                 ckpt_dir, *, engine_config=None):
        from repro.core.bp_engine import EngineConfig
        self.cfg = cfg
        self.tcfg = tcfg
        self.hp = hp
        self.manager = CheckpointManager(
            ckpt_dir, every=tcfg.ckpt_every, keep_n=tcfg.ckpt_keep,
            engine_config=engine_config or EngineConfig(aggregators=2,
                                                        codec="blosc"))
        self.data = SyntheticTokens(cfg.padded_vocab if cfg.family != "audio"
                                    else cfg.vocab_size,
                                    tcfg.seq_len, tcfg.global_batch,
                                    seed=tcfg.seed)
        self.step_fn = jax.jit(make_train_step(
            cfg, hp, grad_compression=tcfg.grad_compression,
            q_chunk=min(256, tcfg.seq_len), kv_chunk=min(256, tcfg.seq_len),
            ssd_chunk=min(64, tcfg.seq_len)),
            donate_argnums=(0,))
        self.history: list[dict] = []

    def _fresh_state(self):
        return init_train_state(self.cfg, jax.random.PRNGKey(self.tcfg.seed),
                                grad_compression=self.tcfg.grad_compression)

    def _make_batch(self, step: int):
        b = self.data.batch_at(step)
        if self.cfg.family == "audio":
            emb = np.random.default_rng(step).normal(
                size=(b["tokens"].shape[0], b["tokens"].shape[1],
                      self.cfg.d_model)).astype(np.float32)
            return {"embeds": emb, "labels": b["labels"]}
        if self.cfg.family == "vlm":
            vis = np.random.default_rng(step).normal(
                size=(b["tokens"].shape[0], self.cfg.n_vision_tokens,
                      self.cfg.d_model)).astype(np.float32)
            return {**b, "vision_embeds": vis}
        return b

    def run(self, *, crash_at: Optional[int] = None,
            on_step: Optional[Callable] = None) -> dict:
        """Train to tcfg.steps, resuming from the newest valid checkpoint.
        `crash_at` raises after that step (fault-injection for tests)."""
        state = self._fresh_state()
        restored = self.manager.restore_latest(state)
        if restored is not None:
            state, at = restored
            print(f"[trainer] resumed from checkpoint step {at}")
        start = int(jax.device_get(state["step"]))
        t0 = time.time()
        for step in range(start, self.tcfg.steps):
            batch = self._make_batch(step)
            state, metrics = self.step_fn(state, batch)
            if (step + 1) % self.tcfg.log_every == 0 or step == start:
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                m["step"] = step + 1
                m["wall_s"] = time.time() - t0
                self.history.append(m)
                print(f"[trainer] step {step+1} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}")
            self.manager.save(state, step + 1)
            if on_step:
                on_step(step + 1, state)
            if crash_at is not None and step + 1 >= crash_at:
                self.manager.wait()
                raise RuntimeError(f"injected crash at step {step+1}")
        self.manager.save(state, self.tcfg.steps, force=True)
        self.manager.wait()
        return {"state": state, "history": self.history}
