"""smollm-360m — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M]

H=15 / kv=5 do not divide the 16-way model axis: attention runs replicated
over `model` (FFN + embeddings carry the TP) — see launch/sharding.py.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
))
