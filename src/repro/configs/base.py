"""Architecture configuration registry.

One config per assigned architecture (see DESIGN.md §5). Configs are exact
per the assignment block; reduced smoke variants are derived mechanically so
tests exercise the same code path at laptop scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention details -------------------------------------------------
    head_dim: Optional[int] = None   # default d_model // n_heads
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 10_000.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0        # deepseek-moe: 2 shared experts
    dense_residual: bool = False     # arctic: parallel dense FFN on every layer
    first_dense_layers: int = 0      # deepseek-moe: layer 0 is dense
    dense_d_ff: int = 0              # d_ff of the dense layers/residual path
    capacity_factor: float = 1.25

    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (zamba2): shared attention block every k layers -------------
    shared_attn_interval: int = 0

    # --- vlm: cross-attention to vision tokens every k layers ---------------
    cross_attn_interval: int = 0
    n_vision_tokens: int = 0

    # --- audio (musicgen): EnCodec codebooks (frontend stub) ----------------
    n_codebooks: int = 0

    # --- numerics / misc -----------------------------------------------------
    norm_eps: float = 1e-5
    vocab_pad_to: int = 128          # pad vocab so TP divides it
    tie_embeddings: bool = False
    param_dtype: str = "float32"     # master params; compute is bf16

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True when decode cost is sub-quadratic in context (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.family not in FAMILIES:
        raise ValueError(f"unknown model family {cfg.family!r} for "
                         f"{cfg.name!r}; known families: {FAMILIES}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in (
        "zamba2_2p7b", "mamba2_2p7b", "phi3_mini_3p8b", "smollm_360m",
        "qwen3_4b", "qwen1p5_0p5b", "musicgen_large", "arctic_480b",
        "deepseek_moe_16b", "llama32_vision_90b",
    ):
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Scale a config down to laptop size, preserving its family structure."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else None,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2), dense_d_ff=256 if cfg.dense_d_ff else 0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=32)
    if cfg.shared_attn_interval:
        kw.update(shared_attn_interval=2, n_layers=4)
    if cfg.cross_attn_interval:
        kw.update(cross_attn_interval=2, n_layers=4, n_vision_tokens=16)
    if cfg.first_dense_layers:
        kw.update(n_layers=max(kw["n_layers"], cfg.first_dense_layers + 1))
    return dataclasses.replace(cfg, **kw)
