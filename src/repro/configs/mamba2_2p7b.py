"""mamba2-2.7b — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                     # attention-free, no FFN: pure Mamba2 blocks
    vocab_size=50280,           # padded to 50432 for TP (see DESIGN.md §5)
    vocab_pad_to=256,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
))
