"""musicgen-large — decoder-only over EnCodec tokens. [arXiv:2306.05284]

Backbone only: the EnCodec frontend is a STUB — input_specs() provides
precomputed frame embeddings (sum of n_codebooks embedding lookups).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
))
