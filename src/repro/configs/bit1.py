"""The paper's own use case (§III-C): neutral ionization in an unbounded
unmagnetized plasma — electrons, D+ ions, D neutrals; 1D geometry; no field
solver or smoother.

Paper scale: 100K cells, 10M particles/cell/species (30M total), 200K steps
on up to 25600 ranks. `paper_config()` keeps the exact grid; `cpu_config()`
scales particle counts/steps to this container while preserving the physics
(ionization decay rate constant n_e*R*dt per step).
"""
from __future__ import annotations

from repro.pic.simulation import PicConfig

# BIT1's five I/O knobs (paper §II)
IO_KNOBS = dict(
    datfile="diagnostic snapshot series (openPMD meshes)",
    dmpstep=10_000,       # checkpoint every N steps
    mvflag=1,             # time-dependent diagnostics on
    mvstep=1_000,         # diagnostics every N steps
    last_step=200_000,
)


def paper_config() -> PicConfig:
    return PicConfig(
        n_cells=100_000,
        L=1.0,
        dt=1e-3,
        capacity=1 << 25,            # 33.5M slots: 30M particles + growth
        n_electrons=10_000_000,
        n_ions=10_000_000,
        n_neutrals=10_000_000,
        rate_R=0.05,
        boundary="periodic",
        field_solve=False,           # the use case skips solver + smoother
        smoothing=False,
    )


def cpu_config(scale: int = 64) -> PicConfig:
    return PicConfig(
        n_cells=100_000 // scale,
        L=1.0,
        dt=1e-3,
        capacity=(1 << 25) // scale,
        n_electrons=10_000_000 // scale,
        n_ions=10_000_000 // scale,
        n_neutrals=10_000_000 // scale,
        # per-cell electron count is scale-invariant (particles and cells
        # shrink together), so the MC rate stays the paper's R
        rate_R=0.05,
        boundary="periodic",
        field_solve=False,
        smoothing=False,
    )
