"""deepseek-moe-16b — 2 shared + 64 routed top-6, fine-grained experts;
layer 0 is dense. [arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                  # fine-grained expert width
    vocab_size=102400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    dense_d_ff=10944,           # width of the dense first layer
))
