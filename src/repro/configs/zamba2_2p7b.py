"""zamba2-2.7b — Mamba2 backbone + shared attention block. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    shared_attn_interval=6,     # shared transformer block applied every 6 layers
))
