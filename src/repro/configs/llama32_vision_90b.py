"""llama-3.2-vision-90b — cross-attention image layers every 5 layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

Backbone only: the vision tower is a STUB — input_specs() provides
precomputed patch embeddings [B, n_vision_tokens, d_model].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_interval=5,      # every 5th layer gets a cross-attn sublayer
    n_vision_tokens=1600,
))
