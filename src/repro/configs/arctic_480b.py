"""arctic-480b — 128-expert top-2 MoE + dense residual per layer.
[hf:Snowflake/snowflake-arctic-base]

Master params are kept bf16 (f32 Adam moments): 480B params × (2+4+4) B/param
= 4.8 TB → 9.4 GB/chip on 512 chips. f32 masters would not fit 16 GB HBM.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                  # expert FFN width
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    dense_residual=True,        # parallel dense FFN on every layer
    dense_d_ff=4864,
    param_dtype="bfloat16",
))
