"""The jbplint core: findings, suppressions, baselines, the file driver.

Design notes:

  * A `Finding` is identified for BASELINE purposes by content, not line
    number (`Finding.key` hashes the stripped source line), so unrelated
    edits above a legacy finding don't churn the baseline.
  * Suppressions are per-line: a `# jbplint: disable=JBPxxx[,JBPyyy]`
    comment on the flagged line, or on a comment-only line directly above
    it. There is deliberately no file-level kill switch — a whole file
    that needs one should be carved out of the checker's scope instead.
  * Checkers scope themselves by directory COMPONENT of the absolute path
    (`path_includes` / `path_excludes`), so `core/` rules apply equally to
    the real tree and to test fixtures written under a `core/` tmp dir.
  * A file that does not parse is itself a finding (rule JBP000) — a
    syntax error must gate CI exactly like any other issue.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import pathlib
import re
from typing import Iterable, Optional, Sequence

_SUPPRESS_RE = re.compile(r"#\s*jbplint:\s*disable=([A-Z0-9,\s]+)")

PARSE_RULE = "JBP000"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # path as reported (cwd-relative when possible)
    line: int
    col: int
    message: str
    symbol: str = ""   # enclosing `Class.method` qualname, "" at module level
    snippet: str = ""  # stripped source line — the baseline-key input

    @property
    def key(self) -> str:
        """Stable identity for baselines: survives line drift from
        unrelated edits (keyed on the line's content, not its number)."""
        h = hashlib.sha1(self.snippet.encode()).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{self.symbol}:{h}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "symbol": self.symbol,
                "message": self.message, "key": self.key}

    def render(self) -> str:
        where = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}{where} " \
               f"{self.message}"


def _parse_suppressions(lines: Sequence[str]) -> dict:
    """line number -> frozenset of suppressed rule ids. A directive on a
    comment-only line also covers the line below it."""
    out: dict[int, frozenset] = {}
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = frozenset(r.strip() for r in m.group(1).split(",")
                          if r.strip())
        out[i] = out.get(i, frozenset()) | rules
        if text.lstrip().startswith("#"):
            out[i + 1] = out.get(i + 1, frozenset()) | rules
    return out


class FileContext:
    """One parsed source file, shared by every checker that runs on it."""

    def __init__(self, path: pathlib.Path, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.suppressions = _parse_suppressions(self.lines)

    def line(self, n: int) -> str:
        return self.lines[n - 1].strip() if 1 <= n <= len(self.lines) else ""

    def suppressed(self, f: Finding) -> bool:
        return f.rule in self.suppressions.get(f.line, frozenset())


class Checker(ast.NodeVisitor):
    """One rule. Subclasses set `rule`/`name`/`description`, scope
    themselves with `path_includes`/`path_excludes` (directory components
    of the absolute path), and call `report(node, msg)` from visit_*."""

    rule = PARSE_RULE
    name = "base"
    description = ""
    path_includes: tuple = ()
    path_excludes: tuple = ()

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        self.findings: list[Finding] = []
        self._scope: list[str] = []

    @classmethod
    def applies_to(cls, abs_path: pathlib.Path) -> bool:
        parts = set(abs_path.parts)
        if any(seg in parts for seg in cls.path_excludes):
            return False
        return (not cls.path_includes
                or any(seg in parts for seg in cls.path_includes))

    # qualname bookkeeping — checkers overriding these must call _push
    def visit_ClassDef(self, node):
        self._push(node)

    def visit_FunctionDef(self, node):
        self._push(node)

    def visit_AsyncFunctionDef(self, node):
        self._push(node)

    def _push(self, node):
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    def report(self, node: ast.AST, message: str):
        line = getattr(node, "lineno", 1)
        self.findings.append(Finding(
            rule=self.rule, path=self.ctx.relpath, line=line,
            col=getattr(node, "col_offset", 0) + 1, message=message,
            symbol=".".join(self._scope), snippet=self.ctx.line(line)))


@dataclasses.dataclass
class AnalysisResult:
    findings: list          # gating: new, unsuppressed, unbaselined
    suppressed: int
    baselined: int
    files: int

    @property
    def clean(self) -> bool:
        return not self.findings


def _expand(paths: Iterable) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(str(p))
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        else:
            out.append(p)
    return out


def _rel(p: pathlib.Path, cwd: pathlib.Path) -> str:
    try:
        return p.resolve().relative_to(cwd).as_posix()
    except ValueError:
        return p.resolve().as_posix()


def analyze_paths(paths: Iterable, *, rules: Optional[set] = None,
                  baseline_keys: frozenset = frozenset(),
                  checkers: Optional[Sequence] = None) -> AnalysisResult:
    """Run the (selected) checkers over every .py under `paths`."""
    if checkers is None:
        from repro.analysis.checkers import ALL_CHECKERS
        checkers = ALL_CHECKERS
    selected = [c for c in checkers if rules is None or c.rule in rules]
    cwd = pathlib.Path.cwd()
    findings: list[Finding] = []
    suppressed = baselined = nfiles = 0
    for fp in _expand(paths):
        nfiles += 1
        rel = _rel(fp, cwd)
        try:
            ctx = FileContext(fp, rel, fp.read_text())
        except SyntaxError as e:
            findings.append(Finding(
                rule=PARSE_RULE, path=rel, line=e.lineno or 1,
                col=e.offset or 1, message=f"syntax error: {e.msg}"))
            continue
        seen = set()                      # nested-with double reports
        for cls in selected:
            if not cls.applies_to(fp.resolve()):
                continue
            ck = cls(ctx)
            ck.visit(ctx.tree)
            for f in ck.findings:
                ident = (f.rule, f.line, f.col, f.message)
                if ident in seen:
                    continue
                seen.add(ident)
                if ctx.suppressed(f):
                    suppressed += 1
                elif f.key in baseline_keys:
                    baselined += 1
                else:
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return AnalysisResult(findings=findings, suppressed=suppressed,
                          baselined=baselined, files=nfiles)


# ------------------------------------------------------------------- baseline
def load_baseline(path) -> frozenset:
    doc = json.loads(pathlib.Path(str(path)).read_text())
    return frozenset(e["key"] for e in doc.get("findings", []))


def baseline_doc(findings: Sequence[Finding]) -> dict:
    return {"version": 1, "tool": "jbplint",
            "findings": [f.to_json() for f in findings]}


# ------------------------------------------------------------------ reporters
def render_text(res: AnalysisResult) -> str:
    lines = [f.render() for f in res.findings]
    lines.append(f"jbplint: {len(res.findings)} finding(s) in {res.files} "
                 f"file(s) ({res.suppressed} suppressed, "
                 f"{res.baselined} baselined)")
    return "\n".join(lines)


def render_json(res: AnalysisResult) -> dict:
    return {"tool": "jbplint", "clean": res.clean,
            "findings": [f.to_json() for f in res.findings],
            "suppressed": res.suppressed, "baselined": res.baselined,
            "files": res.files}
