"""The JBPxxx rules — each one an invariant this repo was burned by.

JBP001  bare `assert` as runtime validation (PR 6 retro-fixed these on the
        decode path: `python -O` strips them, so the check vanishes in
        optimized production runs)
JBP002  raw file I/O on the data planes instead of `InstrumentedFile`
        (PR 7 retro-fixed un-instrumented flush/close — every bypassed op
        is a Darshan/DXT blind spot that silently skews the paper's
        counter claims)
JBP003  Darshan counter names as free string literals: a typo silently
        mints a brand-new counter instead of failing; call sites must use
        the frozen `repro.core.darshan.CTR` registry
JBP004  blocking calls while holding a `with <lock>:` — one slow socket /
        queue / sleep serializes every contender (the jbpd serve plane is
        lock-heavy; PR 6's cache had to move fetches outside the lock)
JBP005  lambdas / nested functions handed to spawn-started workers — the
        spawn start method pickles the target by reference, so these fail
        at `Process.start()`, far from where they were written
JBP006  `time.time()` used to measure a DURATION on the data planes (a
        subtraction operand or a deadline comparison) — the wall clock
        steps under NTP/suspend, so durations must come from
        `time.perf_counter()`/`time.monotonic()`; wall clock is only for
        epoch stamps (PR 9 retro-fixed jbpd's uptime)

All rules are lexical/AST-level by design: no type inference, no data
flow. Heuristic receiver-name matching (lock-ish, queue-ish) is tuned to
this codebase's naming discipline and documented in the README.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.framework import Checker

# with-context names that mean mutual exclusion ... and the ones that mean
# coordination (Condition.wait releases the lock while waiting — flagging
# it would outlaw the reader-pool's notification protocol)
_LOCKISH = re.compile(r"lock", re.I)
_CONDISH = re.compile(r"cond|event|barrier", re.I)
# receivers that look like queues: `q`, `_q`, `task_q`, `result_q`, `jobs
# queue`, ... but not `self._lru` / `self._seq`
_QUEUEISH = re.compile(r"(^|[._])q\d*($|[._])|queue", re.I)


class BareAssertChecker(Checker):
    rule = "JBP001"
    name = "bare-assert"
    description = ("bare `assert` used for runtime validation — stripped "
                   "under `python -O`; raise ValueError/RuntimeError (or "
                   "CorruptPayloadError on decode paths) instead. "
                   "Test and kernel-reference code is exempt.")
    path_excludes = ("tests", "kernels", "benchmarks")

    def visit_Assert(self, node):
        self.report(node, "bare assert is stripped under python -O; raise "
                          "a real exception (ValueError / RuntimeError / "
                          "CorruptPayloadError) with a message instead")
        self.generic_visit(node)


class RawOpenChecker(Checker):
    rule = "JBP002"
    name = "raw-open"
    description = ("raw `open()` / `os.open` / pathlib read-write helpers "
                   "on the series data planes (core/, serve/, tools/) — "
                   "I/O that bypasses InstrumentedFile is invisible to "
                   "Darshan counters and DXT traces; use "
                   "repro.core.darshan.open_file")
    path_includes = ("core", "serve", "tools")
    path_excludes = ("tests", "benchmarks")

    _PATH_IO = ("read_text", "write_text", "read_bytes", "write_bytes")
    _MODULES = ("os", "io")

    def visit_Call(self, node):
        f = node.func
        msg = None
        if isinstance(f, ast.Name) and f.id == "open":
            msg = "raw open() bypasses InstrumentedFile"
        elif isinstance(f, ast.Attribute):
            if (f.attr == "open" and isinstance(f.value, ast.Name)
                    and f.value.id in self._MODULES):
                msg = f"raw {f.value.id}.open() bypasses InstrumentedFile"
            elif f.attr in self._PATH_IO:
                msg = f"Path.{f.attr}() bypasses InstrumentedFile"
        if msg:
            self.report(node, f"{msg} — this I/O is invisible to Darshan "
                              f"counters and DXT traces; use "
                              f"repro.core.darshan.open_file")
        self.generic_visit(node)


class CounterLiteralChecker(Checker):
    rule = "JBP003"
    name = "counter-literal"
    description = ("Darshan counter name passed to `record()` as a free "
                   "string literal — a typo silently mints a new counter; "
                   "use the frozen registry constants "
                   "(repro.core.darshan.CTR.<NAME>)")
    path_excludes = ("tests", "benchmarks")

    _COUNTERISH = re.compile(r"^(POSIX|F|TRANSPORT|SERVICE)_[A-Z0-9_]+$")

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "record":
            suspects = []
            # record(rank, path, counter, inc, tkey, ...) — counter and
            # tkey are the name-valued slots, positionally or by keyword
            if len(node.args) > 2:
                suspects.append(node.args[2])
            if len(node.args) > 4:
                suspects.append(node.args[4])
            suspects += [kw.value for kw in node.keywords
                         if kw.arg in ("counter", "tkey")]
            for arg in suspects:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and self._COUNTERISH.match(arg.value)):
                    self.report(arg, f"counter name {arg.value!r} as a "
                                     f"free literal; use repro.core."
                                     f"darshan.CTR.{arg.value} "
                                     f"(registry-validated, typo-proof)")
        self.generic_visit(node)


class LockHeldBlockingChecker(Checker):
    rule = "JBP004"
    name = "lock-held-blocking"
    description = ("blocking call (socket recv/accept, queue get/put or "
                   "join/wait without a timeout, time.sleep, file opens, "
                   "fsync, framed send/recv) inside a `with <lock>:` body "
                   "— every contender stalls behind it; narrow the "
                   "critical section or add a timeout. Condition/Event "
                   "contexts are exempt (wait() releases the lock).")
    path_excludes = ("tests", "benchmarks")

    _NAME_CALLS = {"open", "open_file", "sleep", "send_msg", "recv_msg",
                   "InstrumentedFile"}
    _ATTR_CALLS = {"recv", "recvfrom", "recv_into", "accept", "connect",
                   "sendall", "fsync", "sleep", "send_msg", "recv_msg"}

    def visit_With(self, node):
        lockish = [ast.unparse(it.context_expr) for it in node.items
                   if _LOCKISH.search(ast.unparse(it.context_expr))
                   and not _CONDISH.search(ast.unparse(it.context_expr))]
        if lockish:
            for stmt in node.body:
                self._scan(stmt, lockish[0])
        self.generic_visit(node)

    def _scan(self, node, lockname):
        # deferred-execution bodies run later, NOT under this lock
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            return
        if isinstance(node, ast.Call):
            self._check_call(node, lockname)
        for child in ast.iter_child_nodes(node):
            self._scan(child, lockname)

    def _check_call(self, node, lockname):
        f = node.func
        has_timeout = any(kw.arg == "timeout" for kw in node.keywords)
        if isinstance(f, ast.Name):
            if f.id in self._NAME_CALLS:
                self._flag(node, f.id, lockname)
            return
        if not isinstance(f, ast.Attribute):
            return
        recv = ast.unparse(f.value)
        what = f"{recv}.{f.attr}"
        if f.attr in self._ATTR_CALLS:
            self._flag(node, what, lockname)
        elif f.attr in ("wait", "join") and not node.args and not has_timeout:
            self._flag(node, what, lockname)
        elif (f.attr in ("get", "put") and not has_timeout
                and _QUEUEISH.search(recv)):
            self._flag(node, what, lockname)

    def _flag(self, node, what, lockname):
        self.report(node, f"blocking call {what}(...) while holding "
                          f"{lockname} — every contender stalls behind it; "
                          f"narrow the critical section or use a timeout")


class SpawnSafetyChecker(Checker):
    rule = "JBP005"
    name = "spawn-unsafe"
    description = ("lambda / nested function handed to a spawn-started "
                   "worker (`Process(target=...)`, `spawn_io_workers` "
                   "target, or shipped through a worker task queue) — the "
                   "spawn start method pickles the target by reference, "
                   "so these fail at Process.start(), far from the code "
                   "that wrote them")
    path_excludes = ("tests", "benchmarks")

    def visit_Module(self, node):
        self._nested_defs = set()
        for fn in ast.walk(node):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(fn):
                    if sub is not fn and isinstance(
                            sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        self._nested_defs.add(sub.name)
        self.generic_visit(node)

    def _unsafe(self, v):
        if isinstance(v, ast.Lambda):
            return "a lambda"
        if isinstance(v, ast.Name) and v.id in self._nested_defs:
            return f"nested function {v.id!r}"
        return None

    def visit_Call(self, node):
        fname = ast.unparse(node.func)
        if fname == "Process" or fname.endswith(".Process"):
            for kw in node.keywords:
                if kw.arg == "target":
                    bad = self._unsafe(kw.value)
                    if bad:
                        self.report(kw.value,
                                    f"{bad} as Process target does not "
                                    f"pickle under the spawn start method "
                                    f"the I/O planes require — use a "
                                    f"module-level function")
        if fname.endswith("spawn_io_workers") and len(node.args) > 1:
            bad = self._unsafe(node.args[1])
            if bad:
                self.report(node.args[1],
                            f"{bad} as spawn_io_workers target does not "
                            f"pickle under spawn — use a module-level "
                            f"function")
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr in ("put", "put_nowait")
                and _QUEUEISH.search(ast.unparse(f.value))):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Lambda):
                    self.report(sub, "lambda shipped through a worker "
                                     "queue — task messages must pickle "
                                     "under the spawn start method; ship "
                                     "data + a module-level handler "
                                     "instead")
                    break
        self.generic_visit(node)


class WallClockDurationChecker(Checker):
    rule = "JBP006"
    name = "wall-clock-duration"
    description = ("`time.time()` used for duration measurement on the "
                   "data planes — the wall clock steps (NTP slew, "
                   "suspend), so elapsed time computed from it is wrong "
                   "exactly when the machine is busiest; use "
                   "time.perf_counter() (or time.monotonic() for "
                   "deadlines). Bare `time.time()` epoch STAMPS are fine "
                   "— only subtraction operands and comparisons are "
                   "flagged.")
    path_includes = ("core", "serve", "tools")
    path_excludes = ("tests", "benchmarks")

    @staticmethod
    def _is_wall_clock(node) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "time"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "time")

    def _flag(self, node, how: str):
        self.report(node, f"time.time() {how} measures a duration on the "
                          f"wall clock, which steps under NTP/suspend — "
                          f"use time.perf_counter() (durations) or "
                          f"time.monotonic() (deadlines); wall clock is "
                          f"only valid as an epoch stamp")

    def visit_BinOp(self, node):
        if isinstance(node.op, ast.Sub):
            for side in (node.left, node.right):
                if self._is_wall_clock(side):
                    self._flag(side, "in a subtraction")
        self.generic_visit(node)

    def visit_Compare(self, node):
        for side in [node.left] + list(node.comparators):
            if self._is_wall_clock(side):
                self._flag(side, "in a comparison (deadline check)")
        self.generic_visit(node)


ALL_CHECKERS = (BareAssertChecker, RawOpenChecker, CounterLiteralChecker,
                LockHeldBlockingChecker, SpawnSafetyChecker,
                WallClockDurationChecker)
