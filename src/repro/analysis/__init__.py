"""jbplint — AST-based static analysis for the repo's I/O-plane invariants.

The paper's argument rests on I/O being observable and correct by
construction: Darshan counters that add up, instrumented file ops, and
crash-consistent multi-process commit protocols. Those invariants used to
live only in reviewers' heads (PR 6 retro-fixed `-O`-stripped asserts in
decode paths; PR 7 retro-fixed un-instrumented flush/close). Each checker
here turns one of them into a machine-checked rule that runs before the
code ever does — the same move Darshan makes for runtime I/O.

Layout:

    framework.py   Finding model, inline suppressions, baseline files,
                   the per-file AST driver and reporters
    checkers.py    the JBPxxx rules themselves
    repro.tools.jbplint   the CLI (exit codes 0/1/2, like jbpfsck)

Suppress a single finding with a trailing comment on the offending line
(or on a comment-only line directly above it):

    self._f = open(self.path, mode)  # jbplint: disable=JBP002 (reason)

Legacy findings can be parked in a committed baseline (`--write-baseline`
/ `--baseline`); new code must come in clean.
"""
from repro.analysis.checkers import ALL_CHECKERS
from repro.analysis.framework import (AnalysisResult, Checker, FileContext,
                                      Finding, analyze_paths, baseline_doc,
                                      load_baseline, render_json, render_text)

__all__ = [
    "ALL_CHECKERS", "AnalysisResult", "Checker", "FileContext", "Finding",
    "analyze_paths", "baseline_doc", "load_baseline", "render_json",
    "render_text",
]
