"""Oracles: the production chunked jnp SSD and the recurrent reference."""
from repro.models.ssm import ssd_chunked, ssd_recurrent_reference  # noqa: F401
