"""Pallas TPU kernel: Mamba2 SSD chunked scan (fused intra+inter chunk).

The chunked SSD algorithm (models/ssm.ssd_chunked) maps naturally onto the
MXU: per chunk, the intra-chunk decay-masked score matmul and the
state-to-output matmul are [Q,Q]x[Q,P] / [Q,N]x[N,P] dots; the inter-chunk
recurrence is a [P,N] state carried in VMEM scratch across the sequential
chunk grid dim. HBM traffic is O(S*(P+N)) per head — the decay matrix L
([Q,Q]) never leaves VMEM, which is the kernel's whole advantage over the
lowered-jnp version.

Grid: (B, H, nc) — nc innermost so state scratch persists per (b, h).
Block shapes: x [Q,P], dt [Q,1], B/C [Q,N]; Q=chunk (128), P=headdim,
N=d_state. A [1,1] scalar per head rides SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)              # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)            # [Q, 1]
    A = a_ref[0, 0]                                  # scalar (<0)
    Bm = b_ref[0].astype(jnp.float32)                # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                # [Q, N]
    D = d_ref[0, 0]                                  # scalar skip

    dA = dt * A                                      # [Q,1]
    cs = jnp.cumsum(dA, axis=0)                      # inclusive, [Q,1]
    total = cs[-1, 0]
    xdt = x * dt                                     # [Q,P]

    # intra-chunk: scores masked by decay
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(li >= si, jnp.exp(cs - cs[:, 0][None, :]), 0.0)
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    # carried-state contribution: C_l . state, decayed from chunk start
    out_decay = jnp.exp(cs)                          # [Q,1]
    y = y + out_decay * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [Q,N]x[P,N]^T -> [Q,P]

    # state update: state = state*exp(total) + sum_s decay_s * B_s (x) xdt_s
    decay_states = jnp.exp(total - cs)               # [Q,1]
    upd = jax.lax.dot_general(xdt * decay_states, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # [P,N]
    state_ref[...] = state_ref[...] * jnp.exp(total) + upd

    y_ref[0, 0] = (y + D * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_tpu(x, dt, A, B, C, D, *, chunk: int = DEFAULT_CHUNK,
                 interpret: bool = False):
    """x: [Bb,H,S,P] head-major; dt: [Bb,H,S]; A/D: [H]; B/C: [Bb,S,N].
    S % chunk == 0 (ops.py pads). Returns y [Bb,H,S,P]."""
    Bb, H, S, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    dt3 = dt[..., None]                              # [Bb,H,S,1]
    A2 = A.reshape(H, 1)
    D2 = D.reshape(H, 1)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt3, A2, B, C, D2)
