"""Public wrapper: [b,s,h,p] layout like models/ssm, padding, dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import DEFAULT_CHUNK, ssd_scan_tpu


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool | None = None):
    """Same contract as models.ssm.ssd_chunked (y only). x:[b,s,h,p],
    dt:[b,s,h], A/D:[h], B/C:[b,s,n]."""
    interpret = _auto_interpret() if interpret is None else interpret
    b, s, h, p = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xm = x.transpose(0, 2, 1, 3)                      # [b,h,s,p]
    dtm = dt.transpose(0, 2, 1)                       # [b,h,s]
    y = ssd_scan_tpu(xm, dtm, A, B, C, D, chunk=chunk, interpret=interpret)
    y = y.transpose(0, 2, 1, 3)
    return y[:, :s] if pad else y
