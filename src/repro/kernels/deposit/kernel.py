"""Pallas TPU kernel: PIC charge deposition (particle -> grid scatter).

TPU adaptation (DESIGN.md §2): GPUs do deposition with atomics; the TPU has
no scatter-atomics, so the scatter is restated as a ONE-HOT MATMUL that the
MXU executes natively:  rho[c] = sum_p onehot(cell_p == c) * w_p. The grid
is tiled (particle tiles x cell tiles); each (pt, ct) block builds the
[TILE_P, TILE_C] one-hot mask in VMEM and reduces over particles. CIC
weighting contributes to cells i0 and i0+1 with (1-frac, frac).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_P = 1024
TILE_C = 256


def _deposit_kernel(x_ref, w_ref, o_ref, *, dx: float, clip_max: int):
    pt = pl.program_id(0)
    ct = pl.program_id(1)
    x = x_ref[...]                                  # [TILE_P]
    w = w_ref[...]                                  # [TILE_P] (weight*alive)
    xi = x / dx
    i0 = jnp.floor(xi).astype(jnp.int32)
    frac = (xi - i0.astype(jnp.float32))
    cell_base = ct * TILE_C
    cells = cell_base + jax.lax.broadcasted_iota(jnp.int32, (TILE_P, TILE_C), 1)
    i0c = jnp.clip(i0, 0, clip_max)[:, None]
    i1c = jnp.clip(i0 + 1, 0, clip_max)[:, None]
    onehot0 = (cells == i0c).astype(jnp.float32)
    onehot1 = (cells == i1c).astype(jnp.float32)
    contrib = (onehot0 * (w * (1.0 - frac))[:, None] +
               onehot1 * (w * frac)[:, None])       # [TILE_P, TILE_C]
    partial = jnp.sum(contrib, axis=0)              # [TILE_C]

    @pl.when(pt == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial / dx


@functools.partial(jax.jit, static_argnames=("n_cells", "clip_max", "dx", "interpret"))
def deposit_tpu(x, w, *, n_cells: int, clip_max: int, dx: float,
                interpret: bool = False) -> jax.Array:
    """x: [N] positions, w: [N] effective weights (weight*alive) with
    N % TILE_P == 0 and n_cells % TILE_C == 0 (ops.py pads)."""
    n = x.shape[0]
    grid = (n // TILE_P, n_cells // TILE_C)
    return pl.pallas_call(
        functools.partial(_deposit_kernel, dx=dx, clip_max=clip_max),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_P,), lambda pt, ct: (pt,)),
                  pl.BlockSpec((TILE_P,), lambda pt, ct: (pt,))],
        out_specs=pl.BlockSpec((TILE_C,), lambda pt, ct: (ct,)),
        out_shape=jax.ShapeDtypeStruct((n_cells,), jnp.float32),
        interpret=interpret,
    )(x, w)
