"""Oracle: the production jnp CIC deposition from pic/grid.py."""
from repro.pic.grid import deposit_cic  # noqa: F401


def deposit_ref(x, w, alive, n_cells: int, dx: float):
    return deposit_cic(x, w, alive, n_cells, dx)
