"""Public wrapper: pad particles/cells to kernel tiles, dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.deposit.kernel import TILE_C, TILE_P, deposit_tpu


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def deposit(x, w, alive, *, n_cells: int, dx: float,
            interpret: bool | None = None) -> jax.Array:
    interpret = _auto_interpret() if interpret is None else interpret
    n = x.shape[0]
    pad_p = (-n) % TILE_P
    # park padded particles far outside the grid: clipped to the last cell
    # with zero weight, they contribute nothing.
    xp = jnp.pad(x, (0, pad_p))
    wp = jnp.pad(w * alive, (0, pad_p))
    pad_c = (-n_cells) % TILE_C
    rho = deposit_tpu(xp, wp, n_cells=n_cells + pad_c,
                      clip_max=n_cells - 1, dx=dx, interpret=interpret)
    return rho[:n_cells]
