"""Public wrapper: [B,S,H,D] layout in/out, seq padding, kernel dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_tpu


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, qc: int = 512,
                    kc: int = 512, interpret: bool | None = None):
    """q/k/v: [B,S,H,D] (H(q) == H(kv); GQA callers expand first)."""
    interpret = _auto_interpret() if interpret is None else interpret
    B, S, H, D = q.shape
    qc = min(qc, S)
    kc = min(kc, S)
    pad = (-S) % max(qc, kc)
    if pad:
        # pad kv with zeros; padded q rows produce garbage rows we slice off,
        # padded kv columns are masked by causality (they sit at the end).
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, padw) for t in (q, k, v))
    qm = q.transpose(0, 2, 1, 3)
    km = k.transpose(0, 2, 1, 3)
    vm = v.transpose(0, 2, 1, 3)
    o = flash_attention_tpu(qm, km, vm, causal=causal, qc=qc, kc=kc,
                            kv_len=S, interpret=interpret)
    o = o.transpose(0, 2, 1, 3)
    return o[:, :S] if pad else o
