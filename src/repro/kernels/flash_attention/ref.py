"""Oracles: the production chunked-jnp flash (models/attention) and the
O(S^2) reference."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import flash_attention_jnp, reference_attention  # noqa: F401


def flash_ref_headmajor(q, k, v, *, causal=True):
    """[B,H,S,D] head-major wrapper around the O(S^2) reference."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = reference_attention(qt, kt, vt, causal=causal)
    return o.transpose(0, 2, 1, 3)
