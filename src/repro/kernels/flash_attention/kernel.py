"""Pallas TPU kernel: fused flash attention forward (online softmax).

This is the VMEM-resident version of models/attention._flash_fwd_impl: the
[qc, kc] probability tile lives entirely in VMEM scratch between the two MXU
matmuls, so HBM traffic is O(S*D) instead of the jnp path's O(S^2) — the
dominant memory-roofline term the §Perf hillclimb removes.

Grid: (B, H, nq, nk) — nk innermost so the (m, l, acc) scratch accumulators
persist across the kv sweep for one q tile (TPU grids execute sequentially
over the trailing dim). Block shapes keep the MXU shapes aligned:
q [qc, D], k/v [kc, D] with qc=kc=512, D padded to >=128 by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_QC = 512
DEFAULT_KC = 512
NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      causal: bool, qc: int, kc: int, scale: float,
                      kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # [qc, D]
    k = k_ref[0, 0]                                   # [kc, D]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kp = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    if causal:
        qp = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        s = jnp.where(kp <= qp, s, NEG_INF)
    s = jnp.where(kp < kv_len, s, NEG_INF)            # padded kv columns

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                            # [qc, kc] — stays in VMEM
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr +
                    jax.lax.dot_general(p.astype(v.dtype), v,
                                        (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "qc", "kc", "kv_len",
                                    "interpret"))
def flash_attention_tpu(q, k, v, *, causal: bool = True, qc: int = DEFAULT_QC,
                        kc: int = DEFAULT_KC, kv_len: int | None = None,
                        interpret: bool = False):
    """q/k/v: [B, H, S, D] (head-major layout for clean blocking).
    S % qc == S % kc == 0 (ops.py pads). Returns [B, H, S, D]."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    qc = min(qc, Sq)
    kc = min(kc, Skv)
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_flash_fwd_kernel, causal=causal, qc=qc, kc=kc,
                               scale=scale, kv_len=kv_len or Skv)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qc, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kc, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, kc, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qc, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc, 1), jnp.float32),       # m
            pltpu.VMEM((qc, 1), jnp.float32),       # l
            pltpu.VMEM((qc, D), jnp.float32),       # acc
        ],
        interpret=interpret,
    )(q, k, v)
