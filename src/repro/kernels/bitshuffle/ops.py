"""Jit'd public wrapper: pads to kernel tiling, dispatches kernel vs oracle.

On this CPU container the kernel runs interpret=True (Python-level Pallas
execution) — the TPU path is identical code with interpret=False.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitshuffle.kernel import (TILE_N, byte_shuffle_block,
                                             byte_shuffle_tpu,
                                             byte_unshuffle_tpu)
from repro.kernels.bitshuffle.ref import byte_shuffle_ref


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def shuffle(data: jax.Array, *, itemsize: int,
            interpret: bool | None = None) -> jax.Array:
    """uint8 [n] -> shuffled uint8 [n]; n padded internally to tile size."""
    interpret = _auto_interpret() if interpret is None else interpret
    n = data.shape[0]
    tile_bytes = itemsize * TILE_N
    pad = (-n) % tile_bytes
    x = jnp.pad(data, (0, pad))
    # shuffle the padded [n_items, itemsize] matrix; slicing the first n
    # bytes of the inverse-unshuffled stream restores exactly data, but for
    # the compression pipeline we keep the padded frame (header records n).
    out = byte_shuffle_tpu(x, itemsize=itemsize, interpret=interpret)
    return out, n


def shuffle_block(data: jax.Array, *, itemsize: int,
                  interpret: bool | None = None) -> jax.Array:
    """Shuffle exactly one codec block on-device: uint8 [n] -> uint8 [n]
    with n % itemsize == 0 and NO padding — output is bit-identical to the
    host `compression.byte_shuffle` on the same bytes. One pallas grid
    point per call (the per-codec-block shape the write path uses)."""
    interpret = _auto_interpret() if interpret is None else interpret
    if data.shape[0] % itemsize:
        raise ValueError(
            f"shuffle_block needs len % itemsize == 0, got "
            f"{data.shape[0]} % {itemsize}")
    return byte_shuffle_block(data, itemsize=itemsize, interpret=interpret)


def unshuffle(data: jax.Array, n: int, *, itemsize: int,
              interpret: bool | None = None) -> jax.Array:
    interpret = _auto_interpret() if interpret is None else interpret
    out = byte_unshuffle_tpu(data, itemsize=itemsize, interpret=interpret)
    return out[:n]
