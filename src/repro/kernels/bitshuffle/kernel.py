"""Pallas TPU kernel: Blosc-style byte shuffle (compression preconditioner).

The shuffle transposes the [n_items, itemsize] byte matrix so that the k-th
byte of every item is contiguous — floats then compress 2-5x better (paper
§IV-D). On a TPU pod this runs ON-CHIP next to the checkpoint shards before
the DMA to host, so the host CPU only pays the cheap LZ stage.

TPU adaptation: bytes are processed as int32 lanes (the VPU has no efficient
sub-word shuffles across lanes); a [TILE_N, itemsize] uint8 block is widened
to int32 in VMEM, transposed, and narrowed on the way out. BlockSpec tiles
the item axis; itemsize (4/8) always fits a VMEM block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 1024


def _shuffle_kernel(x_ref, o_ref):
    # x_ref: [TILE_N, itemsize] uint8 ; o_ref: [itemsize, TILE_N] uint8
    blk = x_ref[...].astype(jnp.int32)       # widen: VPU-friendly lanes
    o_ref[...] = blk.T.astype(jnp.uint8)


def _unshuffle_kernel(x_ref, o_ref):
    blk = x_ref[...].astype(jnp.int32)
    o_ref[...] = blk.T.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("itemsize", "interpret"))
def byte_shuffle_tpu(data: jax.Array, *, itemsize: int,
                     interpret: bool = False) -> jax.Array:
    """data: uint8 [n_bytes] with n_bytes % (itemsize*TILE_N) == 0 (ops.py
    pads). Returns shuffled uint8 [n_bytes]."""
    n = data.shape[0] // itemsize
    x = data.reshape(n, itemsize)
    grid = (n // TILE_N,)
    out = pl.pallas_call(
        _shuffle_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N, itemsize), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((itemsize, TILE_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((itemsize, n), jnp.uint8),
        interpret=interpret,
    )(x)
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("itemsize", "interpret"))
def byte_shuffle_block(data: jax.Array, *, itemsize: int,
                       interpret: bool = False) -> jax.Array:
    """Whole-block shuffle: ONE grid point sized to the block, no padding
    (requires n_bytes % itemsize == 0). JBPC codec blocks are <= 1 MiB, so
    the int32-widened tile fits VMEM on current TPUs; a single grid point
    also keeps interpret-mode execution to one kernel dispatch per codec
    block instead of n/TILE_N — this is the shape the write-path
    `DeviceCodec` pipeline calls per compression block."""
    n = data.shape[0] // itemsize
    x = data.reshape(n, itemsize)
    out = pl.pallas_call(
        _shuffle_kernel,
        out_shape=jax.ShapeDtypeStruct((itemsize, n), jnp.uint8),
        interpret=interpret,
    )(x)
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("itemsize", "interpret"))
def byte_unshuffle_tpu(data: jax.Array, *, itemsize: int,
                       interpret: bool = False) -> jax.Array:
    n = data.shape[0] // itemsize
    x = data.reshape(itemsize, n)
    grid = (n // TILE_N,)
    out = pl.pallas_call(
        _unshuffle_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((itemsize, TILE_N), lambda i: (0, i))],
        out_specs=pl.BlockSpec((TILE_N, itemsize), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, itemsize), jnp.uint8),
        interpret=interpret,
    )(x)
    return out.reshape(-1)
