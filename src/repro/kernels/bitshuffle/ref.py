"""Pure-jnp oracle for the byte-shuffle kernel."""
from __future__ import annotations

import jax.numpy as jnp


def byte_shuffle_ref(data, *, itemsize: int):
    n = data.shape[0] // itemsize
    return data.reshape(n, itemsize).T.reshape(-1)


def byte_unshuffle_ref(data, *, itemsize: int):
    n = data.shape[0] // itemsize
    return data.reshape(itemsize, n).T.reshape(-1)
