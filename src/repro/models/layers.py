"""Shared neural-net building blocks (pure functional JAX).

All params are plain pytrees (nested dicts of jnp arrays). Compute is bf16
with f32 accumulation; master params keep their configured dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- init
def _dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in, d_out, bias=False, dtype=jnp.float32):
    p = {"w": _dense_init(key, (d_in, d_out), fan_in=d_in, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    """bf16-native projection: the dot's internal accumulation is f32 on the
    MXU, but inputs/outputs (and therefore fwd AND bwd cotangents — which
    carry the TP all-reduces) stay bf16. Emitting f32 here doubled every
    model-axis collective (EXPERIMENTS.md §Perf iterations 2-3)."""
    y = jnp.einsum("...i,io->...o", x.astype(COMPUTE_DTYPE),
                   p["w"].astype(COMPUTE_DTYPE))
    if "b" in p:
        y = y + p["b"].astype(COMPUTE_DTYPE)
    return y


linear_reduced = linear


# ----------------------------------------------------------------- rmsnorm
def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(COMPUTE_DTYPE)


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies for rotary embeddings; [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # [D/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # [...,S,1,D/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(COMPUTE_DTYPE)


# ------------------------------------------------------------------ swiglu
def init_swiglu(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(p, x):
    g = linear(p["gate"], x)
    u = linear(p["up"], x)
    return linear_reduced(
        p["down"], jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u)


# -------------------------------------------------------------- embeddings
def init_embedding(key, vocab, d_model, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)}


def embed(p, ids):
    return p["table"].astype(COMPUTE_DTYPE)[ids]


def unembed(p, x, *, transpose=True):
    """Project hidden states to logits. p is an embedding (tied) or linear."""
    t = p["table"].astype(COMPUTE_DTYPE)
    return jnp.einsum("...d,vd->...v", x, t, preferred_element_type=jnp.float32)
