"""Top-level LM: embeddings -> family stack -> final norm -> logits.

Pure-functional API used by train_step / serve_step / dryrun:
    init_params(cfg, key)                          -> params pytree
    forward(params, cfg, batch, ...)               -> (logits, aux)
    prefill(params, cfg, batch, ...)               -> (logits, cache)
    decode_step(params, cfg, token, cache, length) -> (logits, cache)
    loss_fn(params, cfg, batch, ...)               -> (loss, metrics)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.meshctx import shard_hint
from repro.models.layers import (COMPUTE_DTYPE, embed, init_embedding,
                                 init_rmsnorm, rms_norm, unembed)
from repro.models.transformer import STACKS

BATCH = ("pod", "data")

SDS = jax.ShapeDtypeStruct


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------- init
def init_params(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = _dtype(cfg)
    p = {
        "embed": init_embedding(k1, cfg.padded_vocab, cfg.d_model, dtype=dtype),
        "stack": STACKS[cfg.family].init(k2, cfg, dtype=dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_embedding(k3, cfg.padded_vocab, cfg.d_model, dtype=dtype)
    return p


def param_shapes(cfg):
    """Shape pytree of init_params without allocating (used for 480B archs)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def count_params_analytic(cfg, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
    if active_only and cfg.n_experts:
        expert_leaves = jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(
                lambda l: l, shapes["stack"]["layers"]["moe"]["experts"]))
        esz = sum(int(np.prod(l.shape)) for l in expert_leaves)
        total = total - esz + esz * cfg.top_k // cfg.n_experts
    return total


# ---------------------------------------------------------------- forward
def _embed_inputs(params, cfg, batch):
    if batch.get("embeds") is not None:
        x = batch["embeds"].astype(COMPUTE_DTYPE)
    else:
        x = embed(params["embed"], batch["tokens"])
    x = shard_hint(x, BATCH, None, None)   # pin batch sharding of the stream
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def forward(params, cfg, batch, *, remat=False, with_cache=False,
            q_chunk=1024, kv_chunk=1024, ssd_chunk=128):
    """batch: {tokens|embeds, positions?, vision_embeds?}. Causal full-seq pass."""
    x, positions = _embed_inputs(params, cfg, batch)
    stack = STACKS[cfg.family]
    kw: dict[str, Any] = dict(positions=positions, remat=remat,
                              with_cache=with_cache, q_chunk=q_chunk,
                              kv_chunk=kv_chunk, ssd_chunk=ssd_chunk)
    if cfg.family == "vlm":
        kw["vision_embeds"] = batch["vision_embeds"].astype(COMPUTE_DTYPE)
    x, aux, cache = stack.seq(params["stack"], x, cfg, **kw)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x)
    return (logits, aux, cache) if with_cache else (logits, aux)


def prefill(params, cfg, batch, **kw):
    logits, _, cache = forward(params, cfg, batch, with_cache=True, **kw)
    return logits, cache


def decode_step(params, cfg, token, cache, cache_len, *, embeds=None):
    """One-token decode. token:[B,1] int32 (or embeds:[B,1,d]); cache_len scalar."""
    if embeds is not None:
        x = embeds.astype(COMPUTE_DTYPE)
    else:
        x = embed(params["embed"], token)
    stack = STACKS[cfg.family]
    x, cache = stack.step(params["stack"], x, cache, cache_len, cfg)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x)
    return logits, cache


def make_decode_cache_spec(cfg, B, S):
    return STACKS[cfg.family].cache_spec(cfg, B, S)


def init_decode_cache(cfg, B, S):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  make_decode_cache_spec(cfg, B, S))


# ------------------------------------------------------------------- loss
def loss_fn(params, cfg, batch, *, remat=True, aux_weight=0.01,
            q_chunk=1024, kv_chunk=1024, ssd_chunk=128):
    """Next-token cross-entropy; batch needs `labels` [B,S] (-100 = ignore)."""
    logits, aux = forward(params, cfg, batch, remat=remat,
                          q_chunk=q_chunk, kv_chunk=kv_chunk, ssd_chunk=ssd_chunk)
    labels = batch["labels"]
    valid = (labels >= 0)
    safe = jnp.where(valid, labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via fused compare-select-reduce: with vocab sharded over
    # `model`, this reduces to a partial sum + tiny all-reduce — never a
    # gather/all-gather of the [B,S,V] logits.
    vocab_iota = jnp.arange(logits.shape[-1], dtype=safe.dtype)
    onehot = (safe[..., None] == vocab_iota).astype(logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = (logz - gold) * valid
    denom = jnp.maximum(valid.sum(), 1)
    ce = nll.sum() / denom
    total = ce + aux_weight * aux
    return total, {"loss": total, "ce": ce, "aux": aux,
                   "tokens": denom.astype(jnp.float32)}
