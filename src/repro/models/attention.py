"""GQA attention: chunked-flash (online softmax) for train/prefill, cached decode.

The chunked jnp implementation is the production path for the dry-run (it keeps
peak memory O(S·chunk) instead of O(S^2)) and doubles as the oracle for the
Pallas flash kernel (kernels/flash_attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.meshctx import axis_size, shard_hint
from repro.models.layers import (COMPUTE_DTYPE, apply_rope, init_linear,
                                 init_rmsnorm, linear, rms_norm)

NEG_INF = -1e30
BATCH = ("pod", "data")


def _attn_axes(cfg):
    """((q_heads, q_hd), (kv_heads, kv_hd)) hint axes — mirrors
    launch.sharding.attn_layouts against the ambient mesh."""
    tp = axis_size("model")
    if tp <= 1 or not cfg.n_heads:
        return (None, None), (None, None)
    hd_ok = cfg.resolved_head_dim % tp == 0
    if cfg.n_heads % tp == 0:
        q = ("model", None)
        kv = ("model", None) if cfg.n_kv_heads % tp == 0 else (None, None)
        return q, kv
    if hd_ok:
        return (None, "model"), (None, "model")
    return (None, None), (None, None)


def _head_proj_init(key, d_model, n_heads, head_dim, bias, dtype):
    """Weights kept 3-D [d_model, H, head_dim] so head/head_dim partition specs
    apply directly (no reshape through a fused dim that breaks sharding)."""
    w = (jax.random.normal(key, (d_model, n_heads, head_dim), jnp.float32)
         / (d_model ** 0.5)).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((n_heads, head_dim), dtype)
    return p


def init_attention(key, cfg, *, cross=False, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _head_proj_init(k1, cfg.d_model, cfg.n_heads, hd, cfg.qkv_bias, dtype),
        "wk": _head_proj_init(k2, cfg.d_model, cfg.n_kv_heads, hd, cfg.qkv_bias, dtype),
        "wv": _head_proj_init(k3, cfg.d_model, cfg.n_kv_heads, hd, cfg.qkv_bias, dtype),
        "wo": {"w": (jax.random.normal(k4, (cfg.n_heads, hd, cfg.d_model), jnp.float32)
                     / ((cfg.n_heads * hd) ** 0.5)).astype(dtype)},
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _head_proj(p, x):
    y = jnp.einsum("bsd,dhk->bshk", x.astype(COMPUTE_DTYPE),
                   p["w"].astype(COMPUTE_DTYPE))
    if "b" in p:
        y = y + p["b"].astype(COMPUTE_DTYPE)[None, None]
    return y


def _out_proj(p, o):
    """o: [B,S,H,hd] -> [B,S,d]. bf16 out: its TP all-reduce runs at half
    width (§Perf iteration 2); the MXU still accumulates f32 in-dot."""
    return jnp.einsum("bshk,hkd->bsd", o, p["w"].astype(COMPUTE_DTYPE))


def _project_qkv(p, x, kv_x, cfg, positions, kv_positions, *, rope):
    q = _head_proj(p["wq"], x)
    k = _head_proj(p["wk"], kv_x)
    v = _head_proj(p["wv"], kv_x)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    # pin batch/head layout so SPMD propagation never falls back to
    # replicating the batch dim inside the attention loops
    (qh, qd), (kh, kd) = _attn_axes(cfg)
    q = shard_hint(q, BATCH, None, qh, qd)
    k = shard_hint(k, BATCH, None, kh, kd)
    v = shard_hint(v, BATCH, None, kh, kd)
    return q, k, v


def _causal_bias(qi, ki, q_chunk, kv_chunk):
    qp = qi * q_chunk + jnp.arange(q_chunk)
    kp = ki * kv_chunk + jnp.arange(kv_chunk)
    return jnp.where(kp[None, :] <= qp[:, None], 0.0, NEG_INF)   # [qc,kc]


def _flash_chunks(x, n, c):
    # [B,S,H,D] -> [n,B,c,H,D]
    B, S, H, D = x.shape
    return jnp.moveaxis(x.reshape(B, n, c, H, D), 1, 0)


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, h_ax):
    """Returns (out [B,Sq,H,D], lse [B,H,Sq])."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / (D ** 0.5)
    hint = shard_hint
    qr = hint(_flash_chunks(q, nq, q_chunk), None, BATCH, None, h_ax, None)
    kr = hint(_flash_chunks(k, nk, kv_chunk), None, BATCH, None, h_ax, None)
    vr = hint(_flash_chunks(v, nk, kv_chunk), None, BATCH, None, h_ax, None)

    def q_step(_, xs):
        qi, qc = xs                                        # [B,qc,H,D]

        def kv_step(carry, ys):
            m, l, acc = carry
            ki, kc, vc = ys
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = hint(s, BATCH, h_ax, None, None)
            if causal:
                s = s + _causal_bias(qi, ki, q_chunk, kv_chunk)[None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(COMPUTE_DTYPE), vc,
                preferred_element_type=jnp.float32)
            acc = hint(acc, BATCH, h_ax, None, None)
            return (m_new, l, acc), None

        m0 = hint(jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
                  BATCH, h_ax, None)
        l0 = hint(jnp.zeros((B, H, q_chunk), jnp.float32), BATCH, h_ax, None)
        a0 = hint(jnp.zeros((B, H, q_chunk, D), jnp.float32),
                  BATCH, h_ax, None, None)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kr, vr))
        out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(COMPUTE_DTYPE)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))           # [B,H,qc]
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # outs: [nq,B,H,qc,D] -> [B,Sq,H,D];  lses: [nq,B,H,qc] -> [B,H,Sq]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, D)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, do, causal, q_chunk, kv_chunk, h_ax):
    """Memory-efficient flash backward: recomputes p per tile (never saves the
    O(S^2) probabilities — the jnp analogue of the fused-kernel backward)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / (D ** 0.5)
    hint = shard_hint

    qr = hint(_flash_chunks(q, nq, q_chunk), None, BATCH, None, h_ax, None)
    kr = hint(_flash_chunks(k, nk, kv_chunk), None, BATCH, None, h_ax, None)
    vr = hint(_flash_chunks(v, nk, kv_chunk), None, BATCH, None, h_ax, None)
    dor = hint(_flash_chunks(do, nq, q_chunk), None, BATCH, None, h_ax, None)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    delta = delta.transpose(0, 2, 1)                         # [B,H,Sq]
    deltar = jnp.moveaxis(delta.reshape(B, H, nq, q_chunk), 2, 0)
    lser = jnp.moveaxis(lse.reshape(B, H, nq, q_chunk), 2, 0)

    dk0 = hint(jnp.zeros((B, Skv, H, D), jnp.float32), BATCH, None, h_ax, None)
    dv0 = hint(jnp.zeros((B, Skv, H, D), jnp.float32), BATCH, None, h_ax, None)

    def i_step(carry, xs):
        dkf, dvf = carry
        qi, qc, doi, Li, di = xs                             # Li/di: [B,H,qc]

        def j_step(c2, ys):
            dqi, dkf, dvf = c2
            ki, kc, vc = ys
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                s = s + _causal_bias(qi, ki, q_chunk, kv_chunk)[None, None]
            p = jnp.exp(s - Li[..., None])                   # [B,H,qc,kc]
            p = hint(p, BATCH, h_ax, None, None)
            pb = p.astype(COMPUTE_DTYPE)
            dv_blk = jnp.einsum("bhqk,bqhd->bkhd", pb, doi,
                                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doi, vc,
                            preferred_element_type=jnp.float32)
            ds = (p * (dp - di[..., None]) * scale).astype(COMPUTE_DTYPE)
            dqi = dqi + jnp.einsum("bhqk,bkhd->bqhd", ds, kc,
                                   preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bhqk,bqhd->bkhd", ds, qc,
                                preferred_element_type=jnp.float32)
            start = ki * kv_chunk
            old_k = jax.lax.dynamic_slice_in_dim(dkf, start, kv_chunk, axis=1)
            dkf = jax.lax.dynamic_update_slice_in_dim(dkf, old_k + dk_blk,
                                                      start, axis=1)
            old_v = jax.lax.dynamic_slice_in_dim(dvf, start, kv_chunk, axis=1)
            dvf = jax.lax.dynamic_update_slice_in_dim(dvf, old_v + dv_blk,
                                                      start, axis=1)
            return (dqi, dkf, dvf), None

        dq0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        (dqi, dkf, dvf), _ = jax.lax.scan(j_step, (dq0, dkf, dvf),
                                          (jnp.arange(nk), kr, vr))
        return (dkf, dvf), dqi

    (dk, dv), dqs = jax.lax.scan(i_step, (dk0, dv0),
                                 (jnp.arange(nq), qr, dor, lser, deltar))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, H, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, q_chunk, kv_chunk, h_ax):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, h_ax)
    return out


def _flash_fwd_rule(q, k, v, causal, q_chunk, kv_chunk, h_ax):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, h_ax)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, q_chunk, kv_chunk, h_ax, res, do):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, do, causal, q_chunk, kv_chunk, h_ax)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention_jnp(q, k, v, *, causal=True, q_chunk=1024, kv_chunk=1024,
                        hint_axes=(None, None)):
    """Memory-efficient attention with a flash-style custom VJP.
    q/k/v: [B,S,H,D] with H(q) == H(kv) — GQA callers expand KV first
    (attention_block). O(S * D) residuals; probabilities are recomputed
    tile-by-tile in the backward pass, exactly like the fused TPU kernel."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if k.shape[2] != H:
        raise ValueError(
            f"flash core is ungrouped; expand KV heads first "
            f"(q {q.shape} has {H} heads, kv {k.shape} has {k.shape[2]})")
    q_chunk = _fit_chunk(Sq, q_chunk)
    kv_chunk = _fit_chunk(Skv, kv_chunk)
    h_ax = hint_axes[0]
    return _flash(q, k, v, causal, q_chunk, kv_chunk, h_ax)


def _fit_chunk(S: int, c: int) -> int:
    """Largest divisor of S that is <= c (handles Skv like 1600)."""
    c = min(c, S)
    while S % c:
        c -= 1
    return c


def attention_block(p, x, *, cfg, positions, kv_x=None, kv_positions=None,
                    causal=True, rope=True, q_chunk=1024, kv_chunk=1024):
    """Full-sequence attention (train / prefill). Returns (y, (k, v)).

    KV heads are expanded (and q-heads zero-padded) to a multiple of the
    `model` axis before the flash loop, so the attention probability tiles —
    the largest activations in the program — are ALWAYS sharded over `model`
    regardless of GQA ratios (llama 64/8, arctic 56/8, smollm 15/5, ...).
    The returned cache k/v stay in their compact [B,S,Hkv,hd] form.
    """
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, x, kv_x, cfg, positions, kv_positions, rope=rope)
    B, Sq = q.shape[0], q.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    tp = axis_size("model")
    Hp = -(-H // tp) * tp if tp > 1 else H
    G = H // Hkv
    qp = q
    if Hp != H:
        qp = jnp.concatenate(
            [q, jnp.zeros((B, Sq, Hp - H, hd), q.dtype)], axis=2)
    kv_map = jnp.minimum(jnp.arange(Hp) // G, Hkv - 1)
    k_exp = jnp.take(k, kv_map, axis=2)
    v_exp = jnp.take(v, kv_map, axis=2)
    # flash tiles shard over padded q-heads; with KV kept head-replicated
    # (GQA kv < tp) the expansion is a LOCAL slice — no resharding a2a.
    qp = shard_hint(qp, BATCH, None, "model", None)
    k_exp = shard_hint(k_exp, BATCH, None, "model", None)
    v_exp = shard_hint(v_exp, BATCH, None, "model", None)
    o = flash_attention_jnp(qp, k_exp, v_exp, causal=causal, q_chunk=q_chunk,
                            kv_chunk=kv_chunk, hint_axes=("model", None))
    if Hp != H:
        o = o[:, :, :H]
    y = _out_proj(p["wo"], o)
    return y, (k, v)


def decode_attention(p, x, cache_k, cache_v, cache_len, *, cfg, rope=True,
                     update_cache=True):
    """One-token decode. x:[B,1,d]; cache_k/v:[B,Smax,Hkv,D]; cache_len scalar.

    Returns (y, new_cache_k, new_cache_v).
    """
    B, Smax = cache_k.shape[0], cache_k.shape[1]
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, cfg, positions, positions, rope=rope)
    if update_cache:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), cache_len, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), cache_len, axis=1)
    Hkv, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, cache_k.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    valid = jnp.arange(Smax)[None, None, None, None, :] <= cache_len
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w, cache_v.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, cfg.n_heads, hd)
    y = _out_proj(p["wo"], o)
    return y, cache_k, cache_v


def decode_cross_attention(p, x, cross_k, cross_v, n_cross, *, cfg):
    """Decode-time cross attention over a fixed (precomputed) KV set."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.zeros((B, 1), jnp.int32)
    q, _, _ = _project_qkv(p, x, x, cfg, pos, pos, rope=False)
    Hkv, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, cross_k.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    w = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w, cross_v.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, cfg.n_heads, hd)
    return _out_proj(p["wo"], o)


def reference_attention(q, k, v, *, causal=True):
    """O(S^2) oracle for tests."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)
