"""Per-family layer stacks, composed as lax.scan over stacked params.

Every family exposes three functions:
  init_stack(key, cfg)             -> stacked param pytree
  stack_seq(p, x, cfg, ...)        -> (x, aux_loss, cache)      # train / prefill
  stack_step(p, x, cache, len, ..) -> (x, new_cache)            # one-token decode
plus `cache_spec(cfg, B, S)` giving the decode-cache ShapeDtypeStructs.

Scanning over stacked params keeps the HLO O(1) in depth — a 100-layer,
512-device SPMD program lowers to a handful of while-loops. Heterogeneous
stacks (zamba2, llama-vision) scan over their repeating pattern unit.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import (attention_block, decode_attention,
                                    decode_cross_attention, init_attention)
from repro.models.layers import (COMPUTE_DTYPE, init_rmsnorm, init_swiglu,
                                 rms_norm, swiglu)
from repro.models.moe import init_moe, moe_ffn

SDS = jax.ShapeDtypeStruct


# =============================================================== dense block
def init_dense_block(key, cfg, *, d_ff=None, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype=dtype),
        "ffn_norm": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_swiglu(k2, cfg.d_model, d_ff or cfg.d_ff, dtype=dtype),
    }


def dense_block_seq(p, x, cfg, positions, q_chunk, kv_chunk):
    h, kv = attention_block(p["attn"], rms_norm(p["attn_norm"], x, cfg.norm_eps),
                            cfg=cfg, positions=positions,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + h
    x = x + swiglu(p["ffn"], rms_norm(p["ffn_norm"], x, cfg.norm_eps))
    return x, kv


def dense_block_step(p, x, ck, cv, cache_len, cfg):
    h, ck, cv = decode_attention(p["attn"], rms_norm(p["attn_norm"], x, cfg.norm_eps),
                                 ck, cv, cache_len, cfg=cfg)
    x = x + h
    x = x + swiglu(p["ffn"], rms_norm(p["ffn_norm"], x, cfg.norm_eps))
    return x, ck, cv


# ================================================================= moe block
def init_moe_block(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype=dtype),
        "ffn_norm": init_rmsnorm(cfg.d_model, dtype),
        "moe": init_moe(k2, cfg, dtype=dtype),
    }


def moe_block_seq(p, x, cfg, positions, q_chunk, kv_chunk):
    h, kv = attention_block(p["attn"], rms_norm(p["attn_norm"], x, cfg.norm_eps),
                            cfg=cfg, positions=positions,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + h
    y, aux = moe_ffn(p["moe"], rms_norm(p["ffn_norm"], x, cfg.norm_eps), cfg)
    x = x + y
    return x, kv, aux


def moe_block_step(p, x, ck, cv, cache_len, cfg):
    h, ck, cv = decode_attention(p["attn"], rms_norm(p["attn_norm"], x, cfg.norm_eps),
                                 ck, cv, cache_len, cfg=cfg)
    x = x + h
    y, _ = moe_ffn(p["moe"], rms_norm(p["ffn_norm"], x, cfg.norm_eps), cfg,
                   return_aux=False)
    x = x + y
    return x, ck, cv


# ================================================================ ssm block
def init_ssm_block(key, cfg, dtype=jnp.float32):
    return {"norm": init_rmsnorm(cfg.d_model, dtype),
            "mamba": ssm.init_mamba2(key, cfg, dtype=dtype)}


def ssm_block_seq(p, x, cfg, ssd_chunk=128):
    y, _ = ssm.mamba2_seq(p["mamba"], rms_norm(p["norm"], x, cfg.norm_eps),
                          cfg=cfg, chunk=ssd_chunk)
    return x + y


def ssm_block_seq_with_state(p, x, cfg, ssd_chunk=128):
    y, (st, tails) = ssm.mamba2_seq(p["mamba"], rms_norm(p["norm"], x, cfg.norm_eps),
                                    cfg=cfg, chunk=ssd_chunk)
    return x + y, st, tails


def ssm_block_step(p, x, st, tails, cfg):
    y, (st, tails) = ssm.mamba2_step(p["mamba"], rms_norm(p["norm"], x, cfg.norm_eps),
                                     st, tails, cfg=cfg)
    return x + y, st, tails


# ---------------------------------------------------------------------------
def _stacked_init(init_fn, key, n, *args, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args, **kw))(keys)


def _maybe_remat(fn, remat):
    return jax.checkpoint(fn) if remat else fn


# ===========================================================================
# Family: dense / audio  (uniform stack of dense blocks)
# ===========================================================================
class DenseStack:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        return {"layers": _stacked_init(init_dense_block, key, cfg.n_layers,
                                        cfg, dtype=dtype)}

    @staticmethod
    def seq(p, x, cfg, *, positions, remat=False, with_cache=False,
            q_chunk=1024, kv_chunk=1024, **_):
        def body(carry, layer_p):
            y, kv = dense_block_seq(layer_p, carry, cfg, positions, q_chunk, kv_chunk)
            return y, kv if with_cache else None

        x, kvs = jax.lax.scan(_maybe_remat(body, remat), x, p["layers"])
        cache = None
        if with_cache:
            cache = {"k": kvs[0].astype(COMPUTE_DTYPE), "v": kvs[1].astype(COMPUTE_DTYPE)}
        return x, jnp.array(0.0, jnp.float32), cache

    @staticmethod
    def step(p, x, cache, cache_len, cfg, **_):
        def body(carry, xs):
            layer_p, ck, cv = xs
            y, ck, cv = dense_block_step(layer_p, carry, ck, cv, cache_len, cfg)
            return y, (ck, cv)

        x, (cks, cvs) = jax.lax.scan(body, x, (p["layers"], cache["k"], cache["v"]))
        return x, {"k": cks, "v": cvs}

    @staticmethod
    def cache_spec(cfg, B, S):
        hd = cfg.resolved_head_dim
        return {"k": SDS((cfg.n_layers, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                "v": SDS((cfg.n_layers, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE)}


# ===========================================================================
# Family: moe  (optional unstacked dense first layer — deepseek-moe)
# ===========================================================================
class MoeStack:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        k1, k2 = jax.random.split(key)
        n_moe = cfg.n_layers - cfg.first_dense_layers
        p = {"layers": _stacked_init(init_moe_block, k1, n_moe, cfg, dtype=dtype)}
        if cfg.first_dense_layers:
            p["first"] = _stacked_init(init_dense_block, k2, cfg.first_dense_layers,
                                       cfg, d_ff=cfg.dense_d_ff, dtype=dtype)
        return p

    @staticmethod
    def seq(p, x, cfg, *, positions, remat=False, with_cache=False,
            q_chunk=1024, kv_chunk=1024, **_):
        first_cache = None
        if "first" in p:
            def fbody(carry, layer_p):
                y, kv = dense_block_seq(layer_p, carry, cfg, positions, q_chunk, kv_chunk)
                return y, kv if with_cache else None
            x, fkvs = jax.lax.scan(_maybe_remat(fbody, remat), x, p["first"])
            if with_cache:
                first_cache = {"k": fkvs[0].astype(COMPUTE_DTYPE),
                               "v": fkvs[1].astype(COMPUTE_DTYPE)}

        def body(carry, layer_p):
            x, aux = carry
            y, kv, a = moe_block_seq(layer_p, x, cfg, positions, q_chunk, kv_chunk)
            return (y, aux + a), kv if with_cache else None

        (x, aux), kvs = jax.lax.scan(_maybe_remat(body, remat),
                                     (x, jnp.array(0.0, jnp.float32)), p["layers"])
        cache = None
        if with_cache:
            cache = {"k": kvs[0].astype(COMPUTE_DTYPE), "v": kvs[1].astype(COMPUTE_DTYPE)}
            if first_cache is not None:
                cache = {"moe": cache, "first": first_cache}
            else:
                cache = {"moe": cache}
        return x, aux, cache

    @staticmethod
    def step(p, x, cache, cache_len, cfg, **_):
        new_first = None
        if "first" in p:
            def fbody(carry, xs):
                layer_p, ck, cv = xs
                y, ck, cv = dense_block_step(layer_p, carry, ck, cv, cache_len, cfg)
                return y, (ck, cv)
            x, (fk, fv) = jax.lax.scan(fbody, x, (p["first"], cache["first"]["k"],
                                                  cache["first"]["v"]))
            new_first = {"k": fk, "v": fv}

        def body(carry, xs):
            layer_p, ck, cv = xs
            y, ck, cv = moe_block_step(layer_p, carry, ck, cv, cache_len, cfg)
            return y, (ck, cv)

        x, (cks, cvs) = jax.lax.scan(body, x, (p["layers"], cache["moe"]["k"],
                                               cache["moe"]["v"]))
        out = {"moe": {"k": cks, "v": cvs}}
        if new_first is not None:
            out["first"] = new_first
        return x, out

    @staticmethod
    def cache_spec(cfg, B, S):
        hd = cfg.resolved_head_dim
        n_moe = cfg.n_layers - cfg.first_dense_layers
        spec = {"moe": {"k": SDS((n_moe, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                        "v": SDS((n_moe, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE)}}
        if cfg.first_dense_layers:
            spec["first"] = {
                "k": SDS((cfg.first_dense_layers, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                "v": SDS((cfg.first_dense_layers, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE)}
        return spec


# ===========================================================================
# Family: ssm  (mamba2, attention-free)
# ===========================================================================
class SsmStack:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        return {"layers": _stacked_init(init_ssm_block, key, cfg.n_layers,
                                        cfg, dtype=dtype)}

    @staticmethod
    def seq(p, x, cfg, *, remat=False, with_cache=False, ssd_chunk=128, **_):
        def body(carry, layer_p):
            if with_cache:
                y, st, tails = ssm_block_seq_with_state(layer_p, carry, cfg, ssd_chunk)
                return y, (st, tails)
            return ssm_block_seq(layer_p, carry, cfg, ssd_chunk), None

        x, caches = jax.lax.scan(_maybe_remat(body, remat), x, p["layers"])
        cache = None
        if with_cache:
            cache = {"ssm": caches[0], "conv": caches[1]}
        return x, jnp.array(0.0, jnp.float32), cache

    @staticmethod
    def step(p, x, cache, cache_len, cfg, **_):
        def body(carry, xs):
            layer_p, st, tails = xs
            y, st, tails = ssm_block_step(layer_p, carry, st, tails, cfg)
            return y, (st, tails)

        x, (sts, tails) = jax.lax.scan(body, x, (p["layers"], cache["ssm"], cache["conv"]))
        return x, {"ssm": sts, "conv": tails}

    @staticmethod
    def cache_spec(cfg, B, S):
        H, P, N = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        L, K = cfg.n_layers, cfg.ssm_conv
        return {"ssm": SDS((L, B, H, P, N), COMPUTE_DTYPE),
                "conv": (SDS((L, B, K - 1, cfg.d_inner), COMPUTE_DTYPE),
                         SDS((L, B, K - 1, N), COMPUTE_DTYPE),
                         SDS((L, B, K - 1, N), COMPUTE_DTYPE))}


# ===========================================================================
# Family: hybrid (zamba2) — mamba2 backbone + ONE shared attn/FFN block
# applied after every `shared_attn_interval` layers.
# ===========================================================================
class HybridStack:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        k1, k2, k3 = jax.random.split(key, 3)
        I = cfg.shared_attn_interval
        U = cfg.n_layers // I
        keys = jax.random.split(k1, U)
        units = jax.vmap(
            lambda k: _stacked_init(init_ssm_block, k, I, cfg, dtype=dtype))(keys)
        return {"units": units,                       # [U, I, ...]
                "shared": init_dense_block(k2, cfg, dtype=dtype)}

    @staticmethod
    def seq(p, x, cfg, *, positions, remat=False, with_cache=False,
            q_chunk=1024, kv_chunk=1024, ssd_chunk=128, **_):
        shared = p["shared"]

        def unit(carry, unit_p):
            x = carry

            def inner(c, lp):
                if with_cache:
                    y, st, tail = ssm_block_seq_with_state(lp, c, cfg, ssd_chunk)
                    return y, (st, tail)
                return ssm_block_seq(lp, c, cfg, ssd_chunk), None

            # nested remat: unit backward holds ONE mamba layer at a time
            x, inner_caches = jax.lax.scan(_maybe_remat(inner, remat), x, unit_p)
            x, kv = dense_block_seq(shared, x, cfg, positions, q_chunk, kv_chunk)
            out = (inner_caches, kv) if with_cache else None
            return x, out

        x, outs = jax.lax.scan(_maybe_remat(unit, remat), x, p["units"])
        cache = None
        if with_cache:
            (inner_caches, kvs) = outs
            cache = {"ssm": inner_caches[0], "conv": inner_caches[1],
                     "k": kvs[0].astype(COMPUTE_DTYPE), "v": kvs[1].astype(COMPUTE_DTYPE)}
        return x, jnp.array(0.0, jnp.float32), cache

    @staticmethod
    def step(p, x, cache, cache_len, cfg, **_):
        shared = p["shared"]

        def unit(carry, xs):
            unit_p, sts, tails, ck, cv = xs
            x = carry

            def inner(c, ys):
                lp, st, tl = ys
                y, st, tl = ssm_block_step(lp, c, st, tl, cfg)
                return y, (st, tl)

            x, (sts, tails) = jax.lax.scan(inner, x, (unit_p, sts, tails))
            x, ck, cv = dense_block_step(shared, x, ck, cv, cache_len, cfg)
            return x, (sts, tails, ck, cv)

        x, (sts, tails, cks, cvs) = jax.lax.scan(
            unit, x, (p["units"], cache["ssm"], cache["conv"], cache["k"], cache["v"]))
        return x, {"ssm": sts, "conv": tails, "k": cks, "v": cvs}

    @staticmethod
    def cache_spec(cfg, B, S):
        I = cfg.shared_attn_interval
        U = cfg.n_layers // I
        H, P, N = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        K = cfg.ssm_conv
        hd = cfg.resolved_head_dim
        return {"ssm": SDS((U, I, B, H, P, N), COMPUTE_DTYPE),
                "conv": (SDS((U, I, B, K - 1, cfg.d_inner), COMPUTE_DTYPE),
                         SDS((U, I, B, K - 1, N), COMPUTE_DTYPE),
                         SDS((U, I, B, K - 1, N), COMPUTE_DTYPE)),
                "k": SDS((U, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                "v": SDS((U, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE)}


# ===========================================================================
# Family: vlm (llama-3.2-vision) — units of (interval-1) self layers + 1
# cross-attention layer over precomputed vision-patch embeddings.
# ===========================================================================
def init_cross_block(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "cross_norm": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": init_attention(k1, cfg, cross=True, dtype=dtype),
        "attn_gate": jnp.zeros((1,), dtype),
        "ffn_norm": init_rmsnorm(cfg.d_model, dtype),
        "ffn": init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
        "ffn_gate": jnp.zeros((1,), dtype),
    }


def cross_block_seq(p, x, vision, cfg, positions):
    h, kv = attention_block(p["cross_attn"], rms_norm(p["cross_norm"], x, cfg.norm_eps),
                            cfg=cfg, positions=positions, kv_x=vision,
                            kv_positions=jnp.zeros(vision.shape[:2], jnp.int32),
                            causal=False, rope=False,
                            q_chunk=1024, kv_chunk=min(1024, vision.shape[1]))
    x = x + jnp.tanh(p["attn_gate"].astype(jnp.float32)).astype(COMPUTE_DTYPE) * h
    f = swiglu(p["ffn"], rms_norm(p["ffn_norm"], x, cfg.norm_eps))
    x = x + jnp.tanh(p["ffn_gate"].astype(jnp.float32)).astype(COMPUTE_DTYPE) * f
    return x, kv


def cross_block_step(p, x, cross_k, cross_v, cfg):
    h = decode_cross_attention(p["cross_attn"],
                               rms_norm(p["cross_norm"], x, cfg.norm_eps),
                               cross_k, cross_v, cross_k.shape[1], cfg=cfg)
    x = x + jnp.tanh(p["attn_gate"].astype(jnp.float32)).astype(COMPUTE_DTYPE) * h
    f = swiglu(p["ffn"], rms_norm(p["ffn_norm"], x, cfg.norm_eps))
    x = x + jnp.tanh(p["ffn_gate"].astype(jnp.float32)).astype(COMPUTE_DTYPE) * f
    return x


class VlmStack:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        I = cfg.cross_attn_interval
        U = cfg.n_layers // I
        k1, k2 = jax.random.split(key)
        keys = jax.random.split(k1, U)
        self_units = jax.vmap(
            lambda k: _stacked_init(init_dense_block, k, I - 1, cfg, dtype=dtype))(keys)
        cross = _stacked_init(init_cross_block, k2, U, cfg, dtype=dtype)
        return {"self_units": self_units, "cross": cross}     # [U, I-1, ...], [U, ...]

    @staticmethod
    def seq(p, x, cfg, *, positions, vision_embeds, remat=False, with_cache=False,
            q_chunk=1024, kv_chunk=1024, **_):
        def unit(carry, xs):
            unit_p, cross_p = xs
            x = carry

            def inner(c, lp):
                y, kv = dense_block_seq(lp, c, cfg, positions, q_chunk, kv_chunk)
                return y, kv if with_cache else None

            # nested remat: unit backward holds ONE layer's internals
            x, kvs = jax.lax.scan(_maybe_remat(inner, remat), x, unit_p)
            x, ckv = cross_block_seq(cross_p, x, vision_embeds, cfg, positions)
            out = (kvs, ckv) if with_cache else None
            return x, out

        x, outs = jax.lax.scan(_maybe_remat(unit, remat), x,
                               (p["self_units"], p["cross"]))
        cache = None
        if with_cache:
            kvs, ckvs = outs
            cache = {"k": kvs[0].astype(COMPUTE_DTYPE), "v": kvs[1].astype(COMPUTE_DTYPE),
                     "cross_k": ckvs[0].astype(COMPUTE_DTYPE),
                     "cross_v": ckvs[1].astype(COMPUTE_DTYPE)}
        return x, jnp.array(0.0, jnp.float32), cache

    @staticmethod
    def step(p, x, cache, cache_len, cfg, **_):
        def unit(carry, xs):
            unit_p, cross_p, cks, cvs, crk, crv = xs
            x = carry

            def inner(c, ys):
                lp, ck, cv = ys
                y, ck, cv = dense_block_step(lp, c, ck, cv, cache_len, cfg)
                return y, (ck, cv)

            x, (cks, cvs) = jax.lax.scan(inner, x, (unit_p, cks, cvs))
            x = cross_block_step(cross_p, x, crk, crv, cfg)
            return x, (cks, cvs)

        x, (cks, cvs) = jax.lax.scan(
            unit, x, (p["self_units"], p["cross"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        return x, {"k": cks, "v": cvs,
                   "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}

    @staticmethod
    def cache_spec(cfg, B, S):
        I = cfg.cross_attn_interval
        U = cfg.n_layers // I
        hd = cfg.resolved_head_dim
        Tv = cfg.n_vision_tokens
        return {"k": SDS((U, I - 1, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                "v": SDS((U, I - 1, B, S, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                "cross_k": SDS((U, B, Tv, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
                "cross_v": SDS((U, B, Tv, cfg.n_kv_heads, hd), COMPUTE_DTYPE)}


STACKS: dict[str, Any] = {
    "dense": DenseStack,
    "audio": DenseStack,
    "moe": MoeStack,
    "ssm": SsmStack,
    "hybrid": HybridStack,
    "vlm": VlmStack,
}
