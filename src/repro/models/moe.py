"""Mixture-of-Experts FFN: top-k routing with GROUP-LOCAL sort-based
capacity dispatch.

Dispatch is per-group (group = one sequence): tokens are argsorted by expert
id WITHIN their group, bucketed into a [B, E, C, d] buffer, and the buffer is
resharded batch->expert (one all-to-all under SPMD — the canonical MoE
dispatch collective) before the batched expert matmuls, which then run fully
aligned with the expert-sharded weights.

The earlier global-sort formulation sorted/gathered across the whole token
set, which the SPMD partitioner could only realize by replicating [T, d]
activations on every device — the arctic-480b baseline was collective-bound
at 605 s/step because of it (EXPERIMENTS.md §Perf hillclimb A).

Supports: shared experts (deepseek-moe), dense residual path (arctic),
load-balancing aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.meshctx import shard_hint
from repro.models.layers import COMPUTE_DTYPE, _dense_init, init_swiglu, swiglu

BATCH = ("pod", "data")
FSDP_AX = "data"


def init_moe(key, cfg, dtype=jnp.float32):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    p = {
        "router": _dense_init(k1, (d, E), fan_in=d, dtype=jnp.float32),
        "experts": {
            "gate": _dense_init(k2, (E, d, f), fan_in=d, dtype=dtype),
            "up": _dense_init(k3, (E, d, f), fan_in=d, dtype=dtype),
            "down": _dense_init(k4, (E, f, d), fan_in=f, dtype=dtype),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(k5, d, cfg.n_shared_experts * f, dtype=dtype)
    if cfg.dense_residual:
        p["dense"] = init_swiglu(k6, d, cfg.dense_d_ff, dtype=dtype)
    return p


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)                          # round up to 8


def moe_ffn(p, x, cfg, *, return_aux=True):
    """x: [B,S,d] -> (y, aux_loss). Groups = batch rows."""
    Bb, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)
    xf = x.reshape(Bb, S, d)

    logits = jnp.einsum("bsd,de->bse", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # [B,S,E]
    top_w, top_e = jax.lax.top_k(probs, k)                  # [B,S,k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- group-local dispatch (no cross-group communication) ----------------
    e_flat = top_e.reshape(Bb, S * k)                       # [B,S*k]
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=-1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    seg_pos = jnp.arange(S * k)[None] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)
    valid = seg_pos < C
    slot = jnp.where(valid, sorted_e * C + seg_pos, E * C)  # overflow row

    tok_of_assign = order // k                              # [B,S*k]
    gathered = jnp.take_along_axis(
        xf.astype(COMPUTE_DTYPE), tok_of_assign[..., None], axis=1)
    gathered = jnp.where(valid[..., None], gathered, 0)

    def scatter_row(slots, vals):
        return jnp.zeros((E * C + 1, d), COMPUTE_DTYPE).at[slots].set(vals)

    buf = jax.vmap(scatter_row)(slot, gathered)[:, :-1]     # [B,E*C,d]
    buf = buf.reshape(Bb, E, C, d)
    # batch-sharded -> expert-sharded: THE MoE all-to-all
    buf = shard_hint(buf, BATCH, "model", None, None)
    # merge (B,C) so the expert matmuls are plain 3-D batched dots; tokens
    # replicate over `data` inside the expert block — the expert weights
    # are Megatron col/row-parallel over `data` (no ZeRO re-gathers), and
    # the row-parallel all-reduce below carries the partial sums back
    buf = buf.transpose(1, 0, 2, 3).reshape(E, Bb * C, d)
    buf = shard_hint(buf, "model", None, None)

    # ---- expert computation (aligned with E-sharded weights) ----------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["gate"].astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["up"].astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(COMPUTE_DTYPE)
    h = shard_hint(h, "model", None, FSDP_AX)               # col-parallel out
    out = jnp.einsum("ecf,efd->ecd", h,
                     p["experts"]["down"].astype(COMPUTE_DTYPE))
    out = out.reshape(E, Bb, C, d).transpose(1, 0, 2, 3)    # [B,E,C,d]
    out = shard_hint(out, BATCH, None, None, None)          # combine a2a back

    # ---- combine -------------------------------------------------------------
    out_flat = jnp.concatenate(
        [out.reshape(Bb, E * C, d),
         jnp.zeros((Bb, 1, d), COMPUTE_DTYPE)], axis=1)     # [B,E*C+1,d]
    y_sorted = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    inv = jnp.argsort(order, axis=-1)
    y_assign = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    y_assign = y_assign.reshape(Bb, S, k, d)
    y = jnp.einsum("bskd,bsk->bsd", y_assign.astype(jnp.float32),
                   top_w.astype(jnp.float32))

    y = y.astype(COMPUTE_DTYPE)
    y = shard_hint(y, BATCH, None, None)
    if "shared" in p:
        y = y + swiglu(p["shared"], x)
    if "dense" in p:
        y = y + swiglu(p["dense"], x)

    aux = jnp.array(0.0, jnp.float32)
    if return_aux:
        # Switch-style load-balance loss: E * sum_e f_e * P_e
        assign_onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # [B,S,k,E]
        f_e = assign_onehot.sum((0, 1, 2)) / (Bb * S * k)
        P_e = probs.mean((0, 1))
        aux = E * jnp.sum(f_e * P_e)
    return y, aux
