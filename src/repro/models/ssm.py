"""Mamba2 (SSD — state-space duality) block, chunked matmul form.

The chunked algorithm (Mamba2 paper §6) is MXU-friendly: intra-chunk work is
batched matmuls, inter-chunk work is an O(S/Q) recurrence, fused here into a
single lax.scan so peak memory is O(chunk^2), independent of S (needed for
the 32k prefill and 500k long-context shapes).

Projections and conv are stored SPLIT (z / x / B / C / dt) rather than fused:
each piece then has a clean partition spec — x and dt shard over SSM heads
(`model` axis), B/C are group-shared (ngroups=1) and stay replicated.

This jnp implementation is the production path for dry-runs and the oracle
for the Pallas `ssd_scan` kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.meshctx import axis_size, shard_hint
from repro.models.layers import (COMPUTE_DTYPE, init_linear, init_rmsnorm,
                                 linear, linear_reduced, rms_norm)

BATCH = ("pod", "data")


def _ssm_head_axis(n_heads: int):
    tp = axis_size("model")
    return "model" if (tp > 1 and n_heads % tp == 0) else None


# ----------------------------------------------------------------- init
def init_mamba2(key, cfg, dtype=jnp.float32):
    d_in = cfg.d_inner
    H = cfg.n_ssm_heads
    N = cfg.ssm_state
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "wz": init_linear(ks[0], cfg.d_model, d_in, dtype=dtype),
        "wx": init_linear(ks[1], cfg.d_model, d_in, dtype=dtype),
        "wB": init_linear(ks[2], cfg.d_model, N, dtype=dtype),
        "wC": init_linear(ks[3], cfg.d_model, N, dtype=dtype),
        "wdt": init_linear(ks[4], cfg.d_model, H, dtype=dtype),
        "conv_x": {"w": (jax.random.normal(ks[5], (K, d_in), jnp.float32) / K).astype(dtype),
                   "b": jnp.zeros((d_in,), dtype)},
        "conv_B": {"w": (jax.random.normal(ks[6], (K, N), jnp.float32) / K).astype(dtype),
                   "b": jnp.zeros((N,), dtype)},
        "conv_C": {"w": (jax.random.normal(ks[7], (K, N), jnp.float32) / K).astype(dtype),
                   "b": jnp.zeros((N,), dtype)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.geomspace(1e-3, 1e-1, H))).astype(dtype),
        "norm": init_rmsnorm(d_in, dtype),
        "out_proj": init_linear(ks[4], d_in, cfg.d_model, dtype=dtype),
    }


# ------------------------------------------------------------ SSD core
def ssd_chunked(x, dt, A, B, C, D, *, chunk=128, initial_state=None):
    """Chunked SSD fused scan. x:[b,s,h,p] dt:[b,s,h] (>=0) A:[h] (<0)
    B/C:[b,s,n] D:[h].  Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(
            f"sequence length {s} is not divisible by chunk {chunk} — "
            f"the chunked SSD scan needs whole chunks (pad the sequence "
            f"or pick a chunk that divides it)")
    nc = s // chunk

    # [nc, b, chunk, ...] so lax.scan walks chunks.
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, h, p), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0).astype(jnp.float32)
    Bc = jnp.moveaxis(B.reshape(b, nc, chunk, n), 1, 0).astype(jnp.float32)
    Cc = jnp.moveaxis(C.reshape(b, nc, chunk, n), 1, 0).astype(jnp.float32)

    h_ax = _ssm_head_axis(h)
    xc = shard_hint(xc, None, BATCH, None, h_ax, None)
    dtc = shard_hint(dtc, None, BATCH, None, h_ax)

    tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]   # [l,s]
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    h0 = shard_hint(h0, BATCH, h_ax, None, None)

    def step(state, ys):
        xq, dtq, Bq, Cq = ys              # [b,q,h,p] [b,q,h] [b,q,n] [b,q,n]
        dA = dtq * A                       # [b,q,h] <= 0
        cs = jnp.cumsum(dA, axis=1)        # inclusive
        total = cs[:, -1]                  # [b,h]
        xdt = xq * dtq[..., None]          # [b,q,h,p]

        # intra-chunk: masked decay matmul
        scores = jnp.einsum("bln,bsn->bls", Cq, Bq)                  # [b,l,s]
        diff = cs[:, :, None, :] - cs[:, None, :, :]                 # [b,l,s,h]
        L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        y = jnp.einsum("bls,blsh,bshp->blhp", scores, L, xdt)

        # contribution of the carried state
        out_decay = jnp.exp(cs)                                      # [b,q,h]
        y = y + jnp.einsum("bln,bhpn,blh->blhp", Cq, state, out_decay)

        # state update
        decay_states = jnp.exp(total[:, None] - cs)                  # [b,q,h]
        upd = jnp.einsum("bsh,bshp,bsn->bhpn", decay_states, xdt, Bq)
        state = state * jnp.exp(total)[:, :, None, None] + upd
        state = shard_hint(state, BATCH, h_ax, None, None)

        y = y + D[None, None, :, None] * xq
        return state, y.astype(COMPUTE_DTYPE)

    final, ys = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, final


def ssd_recurrent_reference(x, dt, A, B, C, D, *, initial_state=None):
    """Step-by-step oracle (tests only)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(hidden, ys):
        xt, dtt, Bt, Ct = ys
        decay = jnp.exp(dtt * A)                            # [b,h]
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
        hidden = hidden * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", hidden, Ct) + D[None, :, None] * xt
        return hidden, yt

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(COMPUTE_DTYPE), final


# ----------------------------------------------------------- full block
def _causal_conv(x, conv, *, tail=None):
    """Depthwise causal conv + silu. x:[b,s,c]; conv.w:[k,c]. tail:[b,k-1,c]."""
    w = conv["w"].astype(COMPUTE_DTYPE)
    bvec = conv["b"].astype(COMPUTE_DTYPE)
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    padded = jnp.concatenate([tail, x], axis=1)
    out = sum(padded[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_tail = padded[:, -(k - 1):] if k > 1 else tail
    out = jax.nn.silu((out + bvec[None, None]).astype(jnp.float32))
    return out.astype(COMPUTE_DTYPE), new_tail


def _project(p, u, cfg):
    z = linear(p["wz"], u)
    x = linear(p["wx"], u)
    B = linear(p["wB"], u)
    C = linear(p["wC"], u)
    dt_raw = linear(p["wdt"], u)
    return z, x, B, C, dt_raw


def mamba2_seq(p, u, *, cfg, initial_state=None, conv_tails=None, chunk=128):
    """Full-sequence Mamba2 block. u:[b,s,d_model] ->
    (y, (ssm_state, (tail_x, tail_B, tail_C)))."""
    b, s, _ = u.shape
    H, P = cfg.n_ssm_heads, cfg.ssm_headdim
    z, x, B, C, dt_raw = _project(p, u, cfg)
    tx, tB, tC = conv_tails if conv_tails is not None else (None, None, None)
    x, tx = _causal_conv(x, p["conv_x"], tail=tx)
    B, tB = _causal_conv(B, p["conv_B"], tail=tB)
    C, tC = _causal_conv(C, p["conv_C"], tail=tC)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final = ssd_chunked(x.reshape(b, s, H, P), dt, A, B, C,
                           p["D"].astype(jnp.float32), chunk=chunk,
                           initial_state=initial_state)
    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE),
                 cfg.norm_eps)
    return linear_reduced(p["out_proj"], y), (final.astype(COMPUTE_DTYPE),
                                              (tx, tB, tC))


def mamba2_step(p, u, ssm_state, conv_tails, *, cfg):
    """One-token decode. u:[b,1,d_model]."""
    b = u.shape[0]
    H, P = cfg.n_ssm_heads, cfg.ssm_headdim
    z, x, B, C, dt_raw = _project(p, u, cfg)
    tx, tB, tC = conv_tails
    x, tx = _causal_conv(x, p["conv_x"], tail=tx)
    B, tB = _causal_conv(B, p["conv_B"], tail=tB)
    C, tC = _causal_conv(C, p["conv_C"], tail=tC)
    x = x[:, 0].reshape(b, H, P).astype(jnp.float32)
    B = B[:, 0].astype(jnp.float32)
    C = C[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                 # [b,H]
    upd = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], B)
    new_state = ssm_state.astype(jnp.float32) * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C) + p["D"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(b, 1, cfg.d_inner).astype(COMPUTE_DTYPE)
    y = rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE),
                 cfg.norm_eps)
    return linear_reduced(p["out_proj"], y), (new_state.astype(COMPUTE_DTYPE),
                                              (tx, tB, tC))
