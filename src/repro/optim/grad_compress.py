"""Int8 error-feedback gradient compression for cross-pod all-reduces.

At 1000+ node scale the pod axis rides DCN, not ICI; compressing the pod
all-reduce 4x (f32 -> int8 with per-tensor scale and an error-feedback
residual carried in the train state) cuts the dominant cross-pod traffic.
The compression is simulated faithfully under SPMD: quantize -> psum over
'pod' -> dequantize, with the quantization residual added back next step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x):
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residuals):
    """Returns (quantized_grads_as_f32, new_residuals).

    The returned grads have passed through int8; residuals accumulate the
    per-leaf quantization error (error feedback keeps the optimizer unbiased
    over time).
    """
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = quantize(g)
        deq = dequantize(q, scale)
        return deq, g - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def init_residuals(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
