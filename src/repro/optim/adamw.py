"""AdamW with global-norm clipping, fully sharded (ZeRO: moments inherit the
param partition specs), plus warmup-cosine schedule."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(hp: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(hp.warmup_steps, 1), 1.0)
    t = jnp.clip((step - hp.warmup_steps) /
                 jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = hp.min_lr_ratio + (1 - hp.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return hp.lr * warm * cos


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, opt_state, step, hp: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(hp, step)
    b1, b2 = hp.b1, hp.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + hp.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + hp.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
