"""Three-term roofline from a compiled dry-run artifact.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment's constants).

    compute term    = per-device HLO FLOPs / peak FLOP/s
    memory term     = per-device HLO bytes / HBM bandwidth
    collective term = per-device collective traffic / link bandwidth

(The prescribed global formulation `X_total / (chips * rate)` is identical:
post-SPMD modules are per-partition programs, so per-device = total / chips.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.roofline.hlo_analysis import analyze

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
HBM_PER_CHIP = 16 * 1024**3  # v5e HBM


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_by_kind: dict
    collective_op_counts: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float                 # 6*N(*active)*D, global
    useful_flops_ratio: float          # model_flops / (flops_per_device*chips)
    mfu_bound: float                   # model_flops/(chips*peak)/max(term)
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    fits_hbm: Optional[bool] = None
    xla_flops_per_device: float = 0.0  # XLA's own (trip-unaware) number

    def to_dict(self):
        return dataclasses.asdict(self)


def tokens_for_shape(kind: str, seq: int, batch: int) -> int:
    if kind == "train":
        return seq * batch
    if kind == "prefill":
        return seq * batch
    return batch                                   # decode: 1 new token/seq


def model_flops(cfg, kind: str, seq: int, batch: int) -> float:
    n = cfg.n_active_params() if cfg.n_experts else cfg.n_params()
    d = tokens_for_shape(kind, seq, batch)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * d


def build_report(*, arch, shape, mesh_name, n_devices, hlo_text, cfg, kind,
                 seq, batch, mem_stats=None, xla_cost=None) -> RooflineReport:
    a = analyze(hlo_text, n_devices)
    compute_s = a["flops_per_device"] / PEAK_FLOPS
    memory_s = a["hbm_bytes_per_device"] / HBM_BW
    collective_s = a["collective_traffic_per_device"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, kind, seq, batch)
    total_flops = a["flops_per_device"] * n_devices
    ratio = mf / total_flops if total_flops else 0.0
    step_time = max(terms.values()) or 1.0
    mfu_bound = (mf / (n_devices * PEAK_FLOPS)) / step_time
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=a["flops_per_device"],
        hbm_bytes_per_device=a["hbm_bytes_per_device"],
        collective_bytes_per_device=a["collective_traffic_per_device"],
        collective_by_kind=a["collective_traffic_by_kind"],
        collective_op_counts=a["collective_op_counts"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, useful_flops_ratio=ratio,
        mfu_bound=mfu_bound)
    if mem_stats is not None:
        rep.arg_bytes_per_device = float(mem_stats.argument_size_in_bytes)
        rep.temp_bytes_per_device = float(mem_stats.temp_size_in_bytes)
        rep.fits_hbm = (rep.arg_bytes_per_device + rep.temp_bytes_per_device
                        <= HBM_PER_CHIP)
    if xla_cost:
        rep.xla_flops_per_device = float(xla_cost.get("flops", 0.0))
    return rep
