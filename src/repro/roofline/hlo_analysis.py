"""Trip-count-aware cost analysis over post-optimization HLO text.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` surfaces) counts a
while-loop body ONCE — a scan-over-layers model would be undercounted by the
layer count. This analyzer parses `compiled.as_text()`, resolves each while
loop's trip count from its condition computation, and recursively accumulates

  * flops        — dots at 2*M*N*K (trip-multiplied), elementwise at |out|
  * hbm bytes    — operands+outputs of top-level ops (fusion-internal traffic
                   is free, matching XLA's model)
  * collectives  — per-op (kind, bytes, group_size, trips) with a ring-model
                   traffic estimate

All numbers are PER DEVICE (post-SPMD modules are per-partition programs).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "sine", "cosine",
    "compare", "select", "clamp", "and", "or", "xor", "not", "atan2",
    "remainder", "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "convert", "erf", "is-finite", "expm1", "log1p",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string; tuples summed."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> float:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0.0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return float(n)


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    raw: str


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    out_bytes: float
    group_size: int
    trips: float

    @property
    def traffic_bytes(self) -> float:
        """Ring-model per-device traffic."""
        g = max(self.group_size, 1)
        f = (g - 1) / g
        if self.kind == "all-reduce":
            return 2 * self.out_bytes * f * self.trips
        if self.kind == "all-gather":
            return self.out_bytes * f * self.trips
        if self.kind == "reduce-scatter":
            return self.out_bytes * g * f * self.trips      # out = in / g
        return self.out_bytes * self.trips                  # a2a / permute


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},\s]*?))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")


def parse_module(hlo_text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    entry_name = None
    cur: Optional[list[Instr]] = None
    for line in hlo_text.splitlines():
        if not line.strip():
            continue
        hdr = _COMP_HDR_RE.match(line.strip()) if line.rstrip().endswith("{") else None
        if hdr and ("->" in line):
            name = hdr.group(1)
            cur = []
            comps[name] = cur
            if line.strip().startswith("ENTRY"):
                entry_name = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operands: up to the closing paren at depth 0
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[:end]
        operands = _OPERAND_RE.findall(operand_str)
        cur.append(Instr(name=name, type_str=type_str.strip(), op=op,
                         operands=operands, raw=line.strip()))
    comps["__entry__"] = comps.get(entry_name, [])
    comps["__entry_name__"] = entry_name  # type: ignore
    return comps


def _group_size(raw: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(raw)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(raw)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(comps, cond_name: str) -> float:
    """Max integer constant in the while condition (scan bound)."""
    best = 1
    for ins in comps.get(cond_name, []):
        for m in _CONST_RE.finditer(ins.raw):
            best = max(best, int(m.group(1)))
    return float(best)


def _called(ins: Instr) -> list[str]:
    out = []
    for m in _CALLED_RE.finditer(ins.raw):
        if m.group(1) is not None:
            out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
        else:
            out.append(m.group(2))
    return out


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: list = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    [CollectiveRecord(c.kind, c.out_bytes, c.group_size,
                                      c.trips * k) for c in self.collectives])

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        self.collectives.extend(o.collectives)
        return self


class HloAnalyzer:
    def __init__(self, hlo_text: str, n_partitions: int):
        self.comps = parse_module(hlo_text)
        self.n_partitions = n_partitions
        self._memo: dict[tuple[str, bool], Cost] = {}
        self._symtab: dict[str, dict[str, str]] = {}

    def _types(self, comp: str) -> dict[str, str]:
        if comp not in self._symtab:
            self._symtab[comp] = {i.name: i.type_str for i in self.comps.get(comp, [])}
        return self._symtab[comp]

    def _dot_flops(self, ins: Instr, comp: str) -> float:
        out_elems = shape_elems(ins.type_str)
        lhs_t = self._types(comp).get(ins.operands[0] if ins.operands else "", "")
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
        contract = 1
        if m and lhs_t:
            dims = shape_dims(lhs_t)
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
        return 2.0 * out_elems * contract

    def cost(self, comp: str = "__entry__", in_fusion: bool = False) -> Cost:
        key = (comp, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total  # guard cycles
        for ins in self.comps.get(comp, []):
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done") or op in ("parameter", "constant", "tuple",
                                              "get-tuple-element", "bitcast",
                                              "after-all", "iota", "partition-id",
                                              "replica-id"):
                if op in ("iota",):
                    if not in_fusion:
                        total.bytes += shape_bytes(ins.type_str)
                continue
            if base in COLLECTIVE_OPS:
                g = _group_size(ins.raw, self.n_partitions)
                total.collectives.append(CollectiveRecord(
                    base, self._collective_bytes(ins, comp), g, 1.0))
                continue
            if op == "while":
                body, cond = None, None
                bm = re.search(r"body=%?([\w.\-]+)", ins.raw)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                if bm:
                    trips = _trip_count(self.comps, cm.group(1)) if cm else 1.0
                    total += self.cost(bm.group(1)).scaled(trips)
                continue
            if op == "conditional":
                branches = _called(ins)
                if branches:
                    costs = [self.cost(b) for b in branches]
                    best = max(costs, key=lambda c: c.flops + c.bytes)
                    total += best
                continue
            if op == "fusion":
                for c in _called(ins):
                    inner = self.cost(c, in_fusion=True)
                    total.flops += inner.flops
                    total.collectives.extend(inner.collectives)
                if not in_fusion:
                    total.bytes += self._fusion_bytes(ins, comp)
                continue
            if op == "call" or (op == "custom-call" and "called_computations" in ins.raw):
                for c in _called(ins):
                    total += self.cost(c, in_fusion=in_fusion)
                continue
            # --- plain instruction ------------------------------------------
            if op in ("dot", "convolution"):
                total.flops += self._dot_flops(ins, comp)
            elif op in ELEMENTWISE:
                total.flops += shape_elems(ins.type_str)
            elif op in ("reduce", "reduce-window"):
                types = self._types(comp)
                total.flops += sum(shape_elems(types.get(o, ""))
                                   for o in ins.operands[:1]) or shape_elems(ins.type_str)
            if not in_fusion:
                total.bytes += self._instr_bytes(ins, comp)
        self._memo[key] = total
        return total

    def _collective_bytes(self, ins: Instr, comp: str) -> float:
        """TPU-effective bytes for a collective.

        XLA's CPU float support upcasts bf16 dots to f32, so SPMD places
        partial-dot all-reduces on f32 tensors that are bf16 at the jax
        level (their only consumers immediately convert back to bf16). A
        TPU build reduces in bf16 — count that width when every consumer
        converts the value straight to bf16."""
        out = shape_bytes(ins.type_str)
        if "f32[" not in ins.type_str:
            return out
        if self._feeds_bf16_convert(ins.name, comp, depth=0):
            return out / 2.0
        # Structural rule: rank>=3 f32 all-reduces are activation/cotangent
        # reductions — bf16 at the jax level (the CPU backend's dot upcast
        # propagates f32 through the whole residual stream, which a TPU
        # build never does). Parameter-gradient reductions are
        # reduce-scatter/all-gather kinds and stay full width. Applied
        # per-component for tuple (fused) all-reduces.
        if ins.op.startswith("all-reduce"):
            total = 0.0
            for dt, dims in _SHAPE_RE.findall(ins.type_str):
                if dt not in DTYPE_BYTES:
                    continue
                nd = [int(d) for d in dims.split(",") if d]
                b = float(np.prod(nd)) * DTYPE_BYTES[dt] if nd else DTYPE_BYTES[dt]
                if dt == "f32" and len(nd) >= 3:
                    b /= 2.0
                total += b
            return total
        return out

    def _feeds_bf16_convert(self, name: str, comp: str, depth: int) -> bool:
        if depth > 2:
            return False
        for c in self.comps.get(comp, []):
            if name not in c.operands:
                continue
            if c.op == "convert" and c.type_str.startswith("bf16"):
                return True
            if c.op == "get-tuple-element":      # fused tuple all-reduce
                if self._feeds_bf16_convert(c.name, comp, depth + 1):
                    return True
            if c.op == "fusion" and _called(c):
                idx = c.operands.index(name)
                body = self.comps.get(_called(c)[0], [])
                pname = None
                for i2 in body:
                    m = re.search(r"parameter\((\d+)\)", i2.raw)
                    if i2.op == "parameter" and m and int(m.group(1)) == idx:
                        pname = i2.name
                        break
                if pname and any(i2.op == "convert" and pname in i2.operands
                                 and i2.type_str.startswith("bf16")
                                 for i2 in body):
                    return True
        return False

    def _fusion_bytes(self, ins: Instr, comp: str) -> float:
        """Fusion traffic = output + operands, except operands that are only
        dynamic-sliced inside (scan reading one layer of a stacked tensor)
        pay slice-sized traffic, not the full stack."""
        types = self._types(comp)
        called = _called(ins)
        body = self.comps.get(called[0], []) if called else []
        total = shape_bytes(ins.type_str)
        # in-place DUS fusions write only the update, not the whole buffer
        for i2 in body:
            if "ROOT" in i2.raw and i2.op == "dynamic-update-slice":
                if len(i2.operands) > 1:
                    body_types = {b.name: b.type_str for b in body}
                    total = shape_bytes(body_types.get(i2.operands[1], ""))
                break
        param_idx: dict[str, int] = {}
        for i2 in body:
            if i2.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", i2.raw)
                if m:
                    param_idx[i2.name] = int(m.group(1))
        users: dict[str, list[Instr]] = {}
        for i2 in body:
            for o in i2.operands:
                users.setdefault(o, []).append(i2)

        def effective_users(name, depth=0):
            out = []
            for u in users.get(name, []):
                if u.op in ("bitcast", "reshape") and depth < 4:
                    out.extend(effective_users(u.name, depth + 1))
                else:
                    out.append((name, u))
            return out

        for pname, idx in param_idx.items():
            operand_name = ins.operands[idx] if idx < len(ins.operands) else None
            full = shape_bytes(types.get(operand_name, "")) if operand_name else 0.0
            us = effective_users(pname)
            windowed = us and all(
                (u.op == "dynamic-slice" and u.operands and u.operands[0] == src)
                or (u.op == "dynamic-update-slice" and u.operands
                    and u.operands[0] == src)
                for src, u in us)
            if windowed:
                sub = 0.0
                for src, u in us:
                    if u.op == "dynamic-slice":
                        sub += shape_bytes(u.type_str)
                    else:  # DUS: traffic = the update written in place
                        upd = (shape_bytes(self._types(called[0]).get(u.operands[1], ""))
                               if len(u.operands) > 1 else 0.0)
                        sub += upd
                total += sub
            else:
                total += full
        return total

    def _instr_bytes(self, ins: Instr, comp: str) -> float:
        """HBM-traffic estimate per op, approximating TPU fusion behaviour:
        elementwise chains are assumed fused (output write only); data-moving
        and compute ops pay operands+output; windowed slices pay slice-sized
        traffic, never the full sliced-into buffer."""
        op = ins.op
        out = shape_bytes(ins.type_str)
        types = self._types(comp)
        if op in ("dynamic-slice", "gather"):
            return 2 * out
        if op == "dynamic-update-slice":
            upd = shape_bytes(types.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0.0
            return 2 * upd
        if op == "scatter":
            upd = shape_bytes(types.get(ins.operands[-1], "")) if ins.operands else 0.0
            return 2 * upd + out
        if op in ("dot", "convolution", "reduce", "reduce-window", "concatenate",
                  "copy", "sort", "pad", "cholesky", "triangular-solve", "select-and-scatter"):
            return out + sum(shape_bytes(types.get(o, "")) for o in ins.operands)
        if op in ("reshape", "bitcast", "transpose", "broadcast"):
            return out if op == "transpose" else 0.0
        # elementwise & everything else: assume fused into neighbours; the
        # produced buffer is written once
        return out


def analyze(hlo_text: str, n_partitions: int) -> dict:
    """Per-device flops / hbm bytes / collective traffic from HLO text."""
    an = HloAnalyzer(hlo_text, n_partitions)
    c = an.cost()
    by_kind: dict[str, float] = {}
    n_ops: dict[str, float] = {}
    for rec in c.collectives:
        by_kind[rec.kind] = by_kind.get(rec.kind, 0.0) + rec.traffic_bytes
        n_ops[rec.kind] = n_ops.get(rec.kind, 0.0) + rec.trips
    return {
        "flops_per_device": c.flops,
        "hbm_bytes_per_device": c.bytes,
        "collective_traffic_per_device": sum(by_kind.values()),
        "collective_traffic_by_kind": by_kind,
        "collective_op_counts": n_ops,
    }
