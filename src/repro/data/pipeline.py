"""Deterministic synthetic token pipeline with double-buffered host->device
prefetch.

Sequences are generated from a seeded Markov-ish integer process (cheap, but
non-uniform so the LM loss actually decreases), keyed by (seed, step, shard)
— every data-parallel shard reads only its slice, any step is reproducible
after restart (the data pipeline is stateless given the step counter, which
lives in the checkpointed TrainState).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class SyntheticTokens:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, n_shards: int = 1, shard_id: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        if global_batch % n_shards:
            raise ValueError(
                f"global_batch={global_batch} is not divisible by "
                f"n_shards={n_shards} — every data shard needs an equal "
                f"local batch")
        self.local_batch = global_batch // n_shards
        self.seed = seed
        self.n_shards = n_shards
        self.shard_id = shard_id

    def batch_at(self, step: int) -> dict:
        """Materialize the local batch for `step` (stateless/replayable)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_id]))
        b, s = self.local_batch, self.seq
        # structured stream: per-sequence offset + small vocabulary walk
        base = rng.integers(0, self.vocab, size=(b, 1), dtype=np.int64)
        steps = rng.integers(-3, 4, size=(b, s), dtype=np.int64)
        toks = np.abs(base + np.cumsum(steps, axis=1)) % self.vocab
        tokens = toks.astype(np.int32)
        labels = np.concatenate([tokens[:, 1:],
                                 np.full((b, 1), -100, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering (overlap host batch gen + step)."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 depth: int = 2, device_put=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._put = device_put or (lambda x: x)

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = source.batch_at(step)
                try:
                    self._q.put(self._put(batch), timeout=1.0)
                except queue.Full:
                    continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self) -> dict:
        return self._q.get()

    def stop(self):
        self._stop.set()
