"""Async double-buffered write pipeline: sync-equivalence, back-pressure,
drain ordering, blocking seals, crash consistency, and the shared
property-based box-selection round-trip over both writer classes."""
import time

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.async_engine import AsyncBpWriter
from repro.core.bp_engine import (IDX_RECORD, IDX_SIZE, BpReader, BpWriter,
                                  EngineConfig)


def _write_series(cls, path, *, n_ranks=8, aggregators=3, codec="none",
                  steps=3, fsync_policy="close", **kw):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=3,
                       fsync_policy=fsync_policy)
    w = cls(path, n_ranks, cfg, **kw)
    rng = np.random.default_rng(7)
    truth = {}
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(n_ranks * 16, 4)).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        w.end_step()
    w.close()
    return truth


def _idx_records(path, *, zero_time=True):
    raw = (path / "md.idx").read_bytes()
    out = []
    for i in range(0, len(raw) - IDX_SIZE + 1, IDX_SIZE):
        rec = list(IDX_RECORD.unpack_from(raw, i))
        if zero_time:
            rec[5] = 0                      # wall-clock t_ns differs by run
        out.append(tuple(rec))
    return out


# ------------------------------------------------------------ sync parity
@pytest.mark.parametrize("codec", ["none", "blosc"])
def test_async_output_byte_identical_to_sync(tmpdir_path, codec):
    truth = _write_series(BpWriter, tmpdir_path / "sync.bp4", codec=codec)
    _write_series(AsyncBpWriter, tmpdir_path / "async.bp4", codec=codec,
                  queue_depth=2)
    for name in ["data.0", "data.1", "data.2", "md.0"]:
        a = (tmpdir_path / "sync.bp4" / name).read_bytes()
        b = (tmpdir_path / "async.bp4" / name).read_bytes()
        assert a == b, f"{name} differs between sync and async writes"
    assert _idx_records(tmpdir_path / "sync.bp4") == \
        _idx_records(tmpdir_path / "async.bp4")
    r = BpReader(tmpdir_path / "async.bp4")
    assert r.valid_steps() == [0, 1, 2]
    for s, g in truth.items():
        np.testing.assert_array_equal(r.read_var(s, "var/x"), g)


def test_producer_buffer_reuse_is_safe(tmpdir_path):
    """The async snapshot is a deep copy: mutating the put() buffer after
    end_step must not corrupt the written step."""
    w = AsyncBpWriter(tmpdir_path / "s.bp4", 1, EngineConfig())
    buf = np.arange(8, dtype=np.float32)
    w.begin_step(0)
    w.put("v", buf, global_shape=(8,), offset=(0,), rank=0)
    w.end_step()
    buf[:] = -1.0                           # producer reuses its buffer
    w.close()
    np.testing.assert_array_equal(
        BpReader(tmpdir_path / "s.bp4").read_var(0, "v"),
        np.arange(8, dtype=np.float32))


# ----------------------------------------------------------- back-pressure
class _SlowWriter(AsyncBpWriter):
    DELAY = 0.05

    def _write_step(self, snap):
        time.sleep(self.DELAY)
        return super()._write_step(snap)


def test_backpressure_bounds_in_flight_steps(tmpdir_path):
    w = _SlowWriter(tmpdir_path / "s.bp4", 1, EngineConfig(), queue_depth=1)
    waits = []
    for s in range(4):
        w.begin_step(s)
        w.put("v", np.full(4, s, np.float32), global_shape=(4,),
              offset=(0,), rank=0)
        prof = w.end_step()
        waits.append(prof["queue_wait_s"])
        assert prof["backlog"] <= 1         # never > queue_depth in flight
    w.close()
    # first submit lands in an empty queue; later ones must wait for the
    # slow writer to free a slot — that wait IS the back-pressure
    assert waits[0] < _SlowWriter.DELAY / 2
    assert max(waits[1:]) > _SlowWriter.DELAY / 2
    assert BpReader(tmpdir_path / "s.bp4").valid_steps() == [0, 1, 2, 3]


# ---------------------------------------------------------- drain ordering
def test_drain_seals_all_steps_in_submission_order(tmpdir_path):
    w = _SlowWriter(tmpdir_path / "s.bp4", 1, EngineConfig(), queue_depth=2)
    for s in range(5):
        w.begin_step(s)
        w.put("v", np.full(4, s, np.float32), global_shape=(4,),
              offset=(0,), rank=0)
        w.end_step()
    w.drain()                               # barrier: everything sealed now
    steps_on_disk = [rec[0] for rec in _idx_records(tmpdir_path / "s.bp4")]
    assert steps_on_disk == [0, 1, 2, 3, 4], "md.idx must grow in step order"
    w.close()


def test_fsync_step_policy_forces_blocking_seal(tmpdir_path):
    w = AsyncBpWriter(tmpdir_path / "s.bp4", 1,
                      EngineConfig(fsync_policy="step"))
    w.begin_step(0)
    w.put("v", np.arange(4, dtype=np.float32), global_shape=(4,), offset=(0,),
          rank=0)
    prof = w.end_step()                     # must return the SEALED profile
    assert "queued" not in prof and prof["write_s"] > 0
    # the idx record is already durable before close()
    assert [r[0] for r in _idx_records(tmpdir_path / "s.bp4")] == [0]
    w.close()


def test_check_error_raises_fresh_chained_exceptions(tmpdir_path):
    """Every surfacing of a background failure must be a FRESH exception
    chained to the original via __cause__ — re-raising one stored object
    would accrete a traceback frame per call site and misreport where the
    failure was raised."""
    w = AsyncBpWriter(tmpdir_path / "s.bp4", 1,
                      EngineConfig(codec="no-such-codec"))
    w.begin_step(0)
    w.put("v", np.arange(4, dtype=np.float32), global_shape=(4,),
          offset=(0,), rank=0)
    w.end_step()
    with pytest.raises(ValueError) as e1:
        w.drain()
    with pytest.raises(ValueError) as e2:
        w.drain()
    assert e1.value is not e2.value, "same exception object re-raised"
    original = w._writer_error
    assert e1.value.__cause__ is original and e2.value.__cause__ is original
    assert str(e1.value) == str(original)
    # the original's traceback must not have grown from the re-raises
    depth = 0
    tb = original.__traceback__
    while tb is not None:
        depth += 1
        tb = tb.tb_next
    with pytest.raises(ValueError):
        w.drain()
    tb, grown = original.__traceback__, 0
    while tb is not None:
        grown += 1
        tb = tb.tb_next
    assert grown == depth, "original traceback accreted frames"
    with pytest.raises(ValueError):
        w.close()


def test_writer_error_propagates_to_producer(tmpdir_path):
    w = AsyncBpWriter(tmpdir_path / "s.bp4", 4,
                      EngineConfig(codec="no-such-codec"))
    w.begin_step(0)
    w.put("v", np.arange(4, dtype=np.float32), global_shape=(4,), offset=(0,),
          rank=0)
    w.end_step()
    with pytest.raises(ValueError, match="unknown codec"):
        w.drain()
    # close() must still fully shut down (thread, file handles) and raise
    # the error exactly once; after that it is a no-op
    with pytest.raises(ValueError, match="unknown codec"):
        w.close()
    w.close()
    assert not w._writer_thread.is_alive()


# -------------------------------------------------------- crash consistency
def test_truncated_idx_recovers_last_sealed_step(tmpdir_path):
    """Crash mid-seal: md.idx ends in a torn record -> the reader must come
    back with exactly the fully sealed prefix."""
    truth = _write_series(AsyncBpWriter, tmpdir_path / "s.bp4", steps=3)
    idxp = tmpdir_path / "s.bp4" / "md.idx"
    raw = idxp.read_bytes()
    assert len(raw) == 3 * IDX_SIZE
    idxp.write_bytes(raw[:2 * IDX_SIZE + IDX_SIZE // 2])   # tear record 2
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0, 1]
    np.testing.assert_array_equal(r.read_var(1, "var/x"), truth[1])


def test_overlap_stats_in_profiling(tmpdir_path):
    import json
    _write_series(AsyncBpWriter, tmpdir_path / "s.bp4", steps=2)
    doc = json.loads((tmpdir_path / "s.bp4" / "profiling.json").read_text())
    assert doc["async"]["queue_depth"] >= 1
    assert 0.0 <= doc["async"]["overlap_fraction"] <= 1.0
    assert all("backlog" in s and "queue_delay_s" in s for s in doc["steps"])


# ---------------------------------------- property: box-selection round-trip
@pytest.mark.parametrize("writer_cls", [BpWriter, AsyncBpWriter])
@settings(max_examples=15, deadline=None)
@given(n_chunks=st.integers(1, 7), rows=st.integers(8, 80),
       cols=st.integers(1, 6), box_seed=st.integers(0, 10_000),
       codec=st.sampled_from(["none", "blosc"]))
def test_property_box_selection_roundtrip(writer_cls, n_chunks, rows, cols,
                                          box_seed, codec):
    """Random row-chunk layouts written by either engine, arbitrary box
    reads, checked against the dense reference array. (Uses its own tempdir
    rather than a function-scoped fixture: hypothesis' health check forbids
    fixtures inside @given.)"""
    import pathlib
    import shutil
    import tempfile
    rng = np.random.default_rng(box_seed)
    dense = rng.normal(size=(rows, cols)).astype(np.float32)
    bounds = np.unique(np.concatenate(
        [[0, rows], rng.integers(0, rows + 1, n_chunks - 1)])).astype(int)
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="repro-propbox-"))
    path = tmp / "p.bp4"
    w = writer_cls(path, max(len(bounds) - 1, 1),
                   EngineConfig(aggregators=2, codec=codec, workers=2))
    w.begin_step(0)
    for r, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        w.put("v", dense[lo:hi], global_shape=dense.shape, offset=(int(lo), 0),
              rank=r)
    w.end_step()
    w.close()

    try:
        reader = BpReader(path)
        np.testing.assert_array_equal(reader.read_var(0, "v"), dense)
        for _ in range(4):
            r0 = int(rng.integers(0, rows))
            r1 = int(rng.integers(r0 + 1, rows + 1))
            c0 = int(rng.integers(0, cols))
            c1 = int(rng.integers(c0 + 1, cols + 1))
            sel = reader.read_var(0, "v", offset=(r0, c0),
                                  extent=(r1 - r0, c1 - c0))
            np.testing.assert_array_equal(sel, dense[r0:r1, c0:c1])
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_sst_tee_close_cleans_up_on_write_error(tmpdir_path):
    """A failing tee must not leak the writer thread or file handles when
    the stream closes; the error surfaces from close() exactly once."""
    from repro.core.sst_engine import SstStream
    tee = AsyncBpWriter(tmpdir_path / "tee.bp4", 1,
                        EngineConfig(codec="no-such-codec"))
    stream = SstStream(queue_depth=2, tee=tee)
    stream.begin_step(0)
    stream.put("n", np.ones(4, np.float32), global_shape=(4,), offset=(0,))
    stream.end_step()
    with pytest.raises(ValueError, match="unknown codec"):
        stream.close()
    assert not tee._writer_thread.is_alive()


def test_failed_checkpoint_save_does_not_leak_writer(tmpdir_path):
    """save_checkpoint with a broken engine must raise AND fully tear down
    the async writer (thread + handles) — a long-running manager retrying
    saves on persistent I/O errors must not accumulate leaked threads."""
    import threading

    from repro.ckpt.checkpoint import save_checkpoint
    before = threading.active_count()
    for _ in range(3):
        with pytest.raises(ValueError, match="unknown codec"):
            save_checkpoint(tmpdir_path, {"w": np.arange(64.0)}, 1,
                            engine_config=EngineConfig(codec="no-such-codec"),
                            async_io=True)
    assert threading.active_count() <= before + 4   # WriterPool workers only
    assert not any(t.name == "jbp-async-seal"
                   for t in threading.enumerate() if t.is_alive())


class _FailAtStep(AsyncBpWriter):
    """Fails exactly one step's write — later steps must be dropped."""
    FAIL_STEP = 1

    def _write_step(self, snap):
        if snap.step == self.FAIL_STEP:
            raise OSError("injected ENOSPC")
        return super()._write_step(snap)


def test_no_sealed_steps_after_a_failed_step(tmpdir_path):
    """Durability must match sync semantics: a sync writer raises at step N
    and never writes N+1 — async must not seal a gapped series either."""
    w = _FailAtStep(tmpdir_path / "s.bp4", 1, EngineConfig(), queue_depth=2)
    for s in range(4):
        w.begin_step(s)
        w.put("v", np.full(4, s, np.float32), global_shape=(4,),
              offset=(0,), rank=0)
        try:
            w.end_step()
        except OSError:
            break                       # producer may learn of it early
    with pytest.raises(OSError, match="injected ENOSPC"):
        w.close()
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0], \
        "steps after the failure must be dropped, not sealed over a gap"


def test_tee_error_does_not_wedge_the_stream(tmpdir_path):
    """A broken tee surfaces its error to the producer, but the streaming
    consumer keeps receiving steps and the stream remains usable."""
    from repro.core.sst_engine import SstStream, attach_consumer
    tee = AsyncBpWriter(tmpdir_path / "tee.bp4", 1,
                        EngineConfig(codec="no-such-codec"))
    stream = SstStream(queue_depth=4, tee=tee)
    seen = {}
    t = attach_consumer(stream, lambda s, data: seen.update({s: data}))
    stream.begin_step(0)
    stream.put("n", np.zeros(2, np.float32), global_shape=(2,), offset=(0,))
    stream.end_step()                   # enqueues; failure is asynchronous
    with pytest.raises(ValueError, match="unknown codec"):
        tee.drain()                     # make the background failure visible
    stream.begin_step(1)                # must NOT die on a stale _step
    stream.put("n", np.ones(2, np.float32), global_shape=(2,), offset=(0,))
    with pytest.raises(ValueError, match="unknown codec"):
        stream.end_step()               # producer learns persistence broke
    stream.begin_step(2)                # ...but the stream is NOT wedged
    stream.put("n", np.full(2, 2, np.float32), global_shape=(2,),
               offset=(0,))
    with pytest.raises(ValueError, match="unknown codec"):
        stream.end_step()
    stream._tee = None                  # persistence is dead; stream is not
    stream.close()
    t.join(timeout=5)
    assert sorted(seen) == [0, 1, 2], "consumer must see every step"
    with pytest.raises(ValueError, match="unknown codec"):
        tee.close()                     # cleanup completes, raises once
