"""Mamba2 SSD: chunked == recurrent; seq == step-by-step decode."""
import jax
import jax.numpy as jnp
import pytest
from _propcheck import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models.ssm import (init_mamba2, mamba2_seq, mamba2_step,
                              ssd_chunked, ssd_recurrent_reference)


def _inputs(key, b, s, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    D = jnp.ones((h,))
    return x, dt, A, B, C, D


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_chunked_equals_recurrent(chunk):
    x, dt, A, B, C, D = _inputs(jax.random.PRNGKey(0), 2, 128, 4, 16, 8)
    y1, f1 = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    y2, f2 = ssd_recurrent_reference(x, dt, A, B, C, D)
    assert jnp.max(jnp.abs(y1.astype(jnp.float32) -
                           y2.astype(jnp.float32))) < 3e-2
    assert jnp.max(jnp.abs(f1 - f2)) < 1e-3


def test_initial_state_continuation():
    """SSD over [0:64]+[64:128] with carried state == SSD over [0:128]."""
    x, dt, A, B, C, D = _inputs(jax.random.PRNGKey(1), 1, 128, 2, 8, 4)
    y_full, f_full = ssd_chunked(x, dt, A, B, C, D, chunk=32)
    y1, f1 = ssd_chunked(x[:, :64], dt[:, :64], A, B[:, :64], C[:, :64], D,
                         chunk=32)
    y2, f2 = ssd_chunked(x[:, 64:], dt[:, 64:], A, B[:, 64:], C[:, 64:], D,
                         chunk=32, initial_state=f1)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    assert jnp.max(jnp.abs(y_cat.astype(jnp.float32) -
                           y_full.astype(jnp.float32))) < 3e-2
    assert jnp.max(jnp.abs(f2 - f_full)) < 1e-3


def test_block_seq_matches_step_decode():
    cfg = ModelConfig(name="m", family="ssm", n_layers=1, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=64,
                      ssm_state=8, ssm_headdim=16)
    key = jax.random.PRNGKey(2)
    p = init_mamba2(key, cfg)
    B, S = 2, 24
    u = jax.random.normal(key, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    y_seq, (state, tails) = mamba2_seq(p, u, cfg=cfg, chunk=8)
    K = cfg.ssm_conv
    st = jnp.zeros((B, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                   jnp.float32)
    tls = (jnp.zeros((B, K - 1, cfg.d_inner), jnp.bfloat16),
           jnp.zeros((B, K - 1, cfg.ssm_state), jnp.bfloat16),
           jnp.zeros((B, K - 1, cfg.ssm_state), jnp.bfloat16))
    outs = []
    for t in range(S):
        yt, (st, tls) = mamba2_step(p, u[:, t:t + 1], st, tls, cfg=cfg)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, axis=1)
    err = jnp.max(jnp.abs((y_seq - y_dec).astype(jnp.float32)))
    assert err < 5e-2, err
    assert jnp.max(jnp.abs(st.astype(jnp.float32) -
                           state.astype(jnp.float32))) < 2e-2


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([32, 64, 96]), chunk=st.sampled_from([16, 32]),
       seed=st.integers(0, 1000))
def test_property_chunk_invariance(s, chunk, seed):
    """y must not depend on the chunk size."""
    x, dt, A, B, C, D = _inputs(jax.random.PRNGKey(seed), 1, s, 2, 8, 4)
    y1, _ = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    y2, _ = ssd_chunked(x, dt, A, B, C, D, chunk=s)
    assert jnp.max(jnp.abs(y1.astype(jnp.float32) -
                           y2.astype(jnp.float32))) < 3e-2
