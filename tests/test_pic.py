"""PIC-MC physics invariants (paper §II use case §III-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pic.fields import solve_poisson, thomas_solve
from repro.pic.grid import deposit_cic, gather_field, smooth_121
from repro.pic.simulation import (PicConfig, diagnostics, init_sim,
                                  pic_run_chunk, pic_step)


def test_thomas_vs_dense():
    rng = np.random.default_rng(0)
    n = 64
    a = rng.normal(size=n).astype(np.float32) * 0.1
    b = (2.0 + rng.uniform(0, 1, n)).astype(np.float32)
    c = rng.normal(size=n).astype(np.float32) * 0.1
    d = rng.normal(size=n).astype(np.float32)
    M = np.diag(b) + np.diag(a[1:], -1) + np.diag(c[:-1], 1)
    x_ref = np.linalg.solve(M, d)
    x = thomas_solve(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c),
                     jnp.asarray(d))
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=2e-4, atol=2e-4)


def test_poisson_sine_analytic():
    """-phi'' = sin(k x) -> phi = sin(k x)/k^2, with 2nd-order convergence."""
    errs = {}
    for n in (128, 512):
        L = 1.0
        dx = L / n
        xs = (np.arange(n) + 1.0) * dx      # interior solve convention
        kw = 2 * np.pi / L
        rho = np.sin(kw * xs).astype(np.float32)
        phi, E = solve_poisson(jnp.asarray(rho), dx)
        phi_ref = np.sin(kw * xs) / kw**2
        errs[n] = (np.max(np.abs(np.asarray(phi) - phi_ref)) /
                   np.max(np.abs(phi_ref)))
    assert errs[512] < 5e-2
    assert errs[512] < errs[128]            # converges with resolution


def test_deposit_gather_adjointness():
    """sum_p gather(F, x_p) w_p == sum_c F_c deposit(x, w)_c * dx."""
    rng = np.random.default_rng(1)
    n, n_cells, dx = 1000, 64, 1.0 / 64
    x = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32))
    alive = jnp.ones((n,), jnp.float32)
    F = jnp.asarray(rng.normal(size=n_cells).astype(np.float32))
    lhs = float(jnp.sum(gather_field(F, x, dx) * w))
    rho = deposit_cic(x, w, alive, n_cells, dx)
    rhs = float(jnp.sum(F * rho) * dx)
    assert abs(lhs - rhs) / abs(lhs) < 1e-3


def test_smoothing_preserves_total():
    rho = jnp.asarray(np.random.default_rng(2).uniform(0, 1, 128)
                      .astype(np.float32))
    sm = smooth_121(rho)
    # interior-conserving up to boundary treatment
    assert abs(float(jnp.sum(sm) - jnp.sum(rho))) / float(jnp.sum(rho)) < 0.02


def test_ionization_decay_matches_ode():
    cfg = PicConfig(n_cells=256, capacity=1 << 14, n_electrons=4096,
                    n_ions=4096, n_neutrals=4096, rate_R=0.02, dt=1e-2)
    state = init_sim(cfg, jax.random.PRNGKey(0))
    d0 = diagnostics(state, cfg)
    state = pic_run_chunk(state, cfg, 200)
    d1 = diagnostics(state, cfg)
    ne, nn = d0["count/e"], d0["count/D"]
    for _ in range(200):
        dn = nn * (ne * cfg.dx) * cfg.rate_R * cfg.dt
        nn -= dn
        ne += dn
    assert abs(nn - d1["count/D"]) / nn < 0.08
    # conservation
    assert abs((d1["count/D"] + d1["count/D_plus"]) -
               (d0["count/D"] + d0["count/D_plus"])) < 1e-3
    assert abs((d1["count/e"] - d1["count/D_plus"]) -
               (d0["count/e"] - d0["count/D_plus"])) < 1e-3


def test_absorbing_walls_lose_particles():
    cfg = PicConfig(n_cells=128, capacity=1 << 12, n_electrons=2048,
                    n_ions=2048, n_neutrals=8, boundary="absorbing",
                    field_solve=True, smoothing=True, dt=1e-3, rate_R=0.0)
    state = pic_run_chunk(init_sim(cfg, jax.random.PRNGKey(1)), cfg, 100)
    d = diagnostics(state, cfg)
    assert d["wall_flux/e"] > 0
    assert d["count/e"] < 2048
    assert np.isfinite(d["wall_flux/e"])


def test_energy_sane_in_field_run():
    """Electrostatic run stays numerically stable (no NaN/explosion)."""
    cfg = PicConfig(n_cells=128, capacity=1 << 12, n_electrons=2048,
                    n_ions=2048, n_neutrals=8, field_solve=True,
                    smoothing=True, dt=5e-4, rate_R=0.0)
    state = pic_run_chunk(init_sim(cfg, jax.random.PRNGKey(2)), cfg, 200)
    v = np.asarray(state.electrons.v)
    assert np.isfinite(v).all()
    assert np.abs(v).max() < 1e3
