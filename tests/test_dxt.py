"""DXT per-operation tracing: ring buffers, cross-process merge, exports,
the jbpdxt CLI, and the jbpd live `watch` metrics stream."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import MONITOR, open_file
from repro.core.dxt import (DxtTracer, SPAN_OPS, TRACER, load_trace,
                            to_chrome, to_dxt_text)
from repro.core.parallel_engine import ParallelBpWriter
from repro.serve.jbpd import JbpDaemon, SeriesClient, SeriesServer
from repro.tools.jbpdxt import bandwidth_bins, main as jbpdxt_main, summarize


# ------------------------------------------------------------------ unit: ring
def test_disabled_tracer_records_nothing():
    tr = DxtTracer()
    tr.record(0, "x", "write", 0, 10, 0.0, 1.0)
    with tr.span("commit", path="y") as sp:
        sp.length = 5
    assert tr.stats()["events"] == 0
    assert tr.events() == []


def test_ring_buffer_drops_oldest_and_counts():
    tr = DxtTracer(capacity=4)
    tr.enable()
    for i in range(10):
        tr.record(0, "f", "write", i * 8, 8, float(i), float(i) + 0.5)
    snap = tr.snapshot()
    assert len(snap["events"]) == 4
    assert snap["dropped"] == 6
    # the SURVIVORS are the newest events
    assert [e[5] for e in snap["events"]] == [6.0, 7.0, 8.0, 9.0]
    assert tr.stats()["dropped"] == 6


def test_snapshot_reset_clears_buffers():
    tr = DxtTracer()
    tr.enable()
    tr.record(0, "f", "write", 0, 8, 1.0, 2.0)
    s1 = tr.snapshot(reset=True)
    assert len(s1["events"]) == 1
    assert tr.snapshot()["events"] == []


def test_ingest_rebases_onto_wall_clock():
    """Two processes with different perf_counter origins must land on one
    wall-clock axis: event at the SAME wall instant -> same merged t0."""
    host = DxtTracer()
    host.enable()
    # a "remote" snapshot whose perf_counter origin is wildly different:
    # its epoch says perf=1000.0 corresponds to wall=W
    wall = host.epoch[0] - host.epoch[1]  # host shift
    snap = {"src": "worker", "epoch": [123456.0, 1000.0], "dropped": 2,
            "events": [[1, "data.1", "write", 0, 64, 1001.0, 1001.5]]}
    host.ingest(snap)
    evs = host.events()
    assert len(evs) == 1
    src, rank, path, op, off, ln, t0, t1 = evs[0]
    assert src == "worker" and rank == 1
    # rebased: wall = perf + (epoch_wall - epoch_perf)
    assert t0 == pytest.approx(123456.0 + 1.0)
    assert t1 - t0 == pytest.approx(0.5)
    assert host.dropped() == 2
    assert wall != 123456.0 - 1000.0  # the test is meaningful


def test_span_sets_length_inside_block():
    tr = DxtTracer()
    tr.enable()
    with tr.span("transport", path="ring", rank=3) as sp:
        sp.length = 4096
    (rank, path, op, off, ln, t0, t1), = tr.snapshot()["events"]
    assert (rank, path, op, ln) == (3, "ring", "transport", 4096)
    assert t1 >= t0


def test_threaded_records_land_in_per_thread_buffers():
    tr = DxtTracer()
    tr.enable()

    def work(k):
        for i in range(100):
            tr.record(k, f"f{k}", "write", i, 1, float(i), float(i))

    ts = [threading.Thread(target=work, args=(k,)) for k in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert tr.stats()["events"] == 400
    assert tr.stats()["dropped"] == 0


# ----------------------------------------------------- instrumented file trace
def test_instrumented_file_traces_every_op_with_offsets(tmpdir_path):
    TRACER.enable()
    p = tmpdir_path / "x.bin"
    f = open_file(p, "wb", rank=2)
    f.write(b"a" * 100)
    f.write(b"b" * 50)
    f.fsync()
    f.close()
    r = open_file(p, "rb", rank=2)
    r.seek(100)
    assert r.read(50) == b"b" * 50
    r.close()
    evs = [e for e in TRACER.events() if e[2] == str(p)]
    ops = [(e[3], e[4], e[5]) for e in evs]     # (op, offset, length)
    assert ("open", 0, 0) in ops
    assert ("write", 0, 100) in ops
    assert ("write", 100, 50) in ops            # position tracked
    assert ("fsync", 150, 0) in ops
    assert ("close", 150, 0) in ops
    assert ("seek", 100, 0) in ops
    assert ("read", 100, 50) in ops             # offset from the seek
    assert all(e[1] == 2 for e in evs)          # rank attribution


# ------------------------------------------------- the W=2 acceptance scenario
@pytest.mark.slow
def test_parallel_w2_async_commit_merged_trace(tmpdir_path):
    """The ISSUE acceptance: W=2 ParallelBpWriter(async_commit=True) with
    tracing on -> ONE merged trace, worker+coordinator events monotonic on
    one clock, span coverage >= {compress, transport, seal, commit}, and
    per-subfile trace byte totals == the Darshan counters exactly."""
    TRACER.enable()
    p = tmpdir_path / "series"
    with ParallelBpWriter(p, n_ranks=4, n_writers=2,
                          async_commit=True) as w:
        for s in range(3):
            w.begin_step(s)
            for r in range(4):
                w.put("T", np.full((16, 8), r, np.float64),
                      global_shape=(64, 8), offset=(r * 16, 0), rank=r)
            w.end_step()
        w.drain()

    evs = TRACER.events()
    srcs = {e[0] for e in evs}
    assert len(srcs) >= 3                   # coordinator + both workers
    assert {"compress", "transport", "seal", "commit"} <= {e[3] for e in evs}
    # one clock: merged timeline is sorted and every event is well-formed
    t0s = [e[6] for e in evs]
    assert t0s == sorted(t0s)
    assert all(e[7] >= e[6] for e in evs)
    # worker events (foreign src) INTERLEAVE with coordinator events in
    # wall time — the rebase put them on one axis, not before/after
    order = [e[0] for e in evs]
    first_foreign = next(i for i, s in enumerate(order) if s != TRACER.src)
    assert any(s == TRACER.src for s in order[first_foreign:])

    # per-subfile byte parity with the darshan counters
    files = MONITOR.report()["files"]
    for sub in ("data.0", "data.1"):
        fpath = str(p / sub)
        trace_bytes = sum(e[5] for e in evs
                          if e[3] == "write" and e[2] == fpath)
        assert trace_bytes == files[fpath]["POSIX_BYTES_WRITTEN"]
        assert trace_bytes > 0

    # the dxt.json sidecar landed next to profiling.json and round-trips
    doc = load_trace(p)
    assert len(doc["events"]) == len(evs)

    # reader still sees a valid series
    with BpReader(p) as r:
        assert r.read_var(2, "T").shape == (64, 8)


# ---------------------------------------------------------------- the exports
def _synthetic_events():
    return [
        ("pid1", 0, "data.0", "write", 0, 4096, 10.0, 10.5),
        ("pid1", 0, "series", "commit", 0, 128, 10.6, 10.7),
        ("pid2", 1, "data.1", "write", 0, 8192, 10.1, 10.4),
        ("pid2", 1, "ost3/data.1.0", "write", 0, 256, 10.2, 10.3),
    ]


def test_chrome_export_structure():
    ch = to_chrome(_synthetic_events(), dropped=7)
    assert ch["otherData"]["dropped"] == 7
    evs = ch["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    ms = [e for e in evs if e["ph"] == "M"]
    assert len(xs) == 4 and len(ms) == 2        # 2 distinct pids
    for e in xs:
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["dur"] > 0
        assert set(("path", "offset", "length")) <= set(e["args"])
    cats = {e["name"]: e["cat"] for e in xs}
    assert cats["write"] == "posix" and cats["commit"] == "span"
    # pid/tid mapping: same src -> same pid; rank -> tid
    by_src = {}
    for e in ms:
        by_src[e["args"]["name"]] = e["pid"]
    assert by_src["pid1"] != by_src["pid2"]


def test_dxt_text_format():
    txt = to_dxt_text(_synthetic_events(), dropped=1)
    assert "# DXT, file_name: data.0" in txt
    assert "X_POSIX" in txt and "X_SPAN" in txt
    assert "dropped: 1" in txt
    # one X_POSIX line per posix op, fields tab-separated
    posix = [l for l in txt.splitlines() if l.startswith(" X_POSIX")]
    assert len(posix) == 3
    parts = posix[0].split("\t")
    assert len(parts) == 8                      # module..end
    int(parts[1]); int(parts[3]); int(parts[4]); int(parts[5])
    float(parts[6]); float(parts[7])


def test_summarize_and_bandwidth_bins():
    summ = summarize(_synthetic_events(), dropped=3)
    assert summ["dropped"] == 3
    assert summ["ops"]["write"]["count"] == 3
    assert summ["files"]["data.0"]["bytes_written"] == 4096
    assert summ["files"]["ost3/data.1.0"]["ost"] == 3
    assert "series" not in summ["files"]        # spans are not file records
    bins = bandwidth_bins(_synthetic_events(), 10)
    assert sum(b for _, b in bins) == 4096 + 8192 + 256


# ------------------------------------------------------------------ jbpdxt CLI
def test_jbpdxt_cli_on_traced_series(tmpdir_path, capsys):
    TRACER.enable()
    p = tmpdir_path / "series"
    with_profiling = EngineConfig(profiling=True)
    w = BpWriter(p, n_ranks=2, cfg=with_profiling)
    for s in range(2):
        w.begin_step(s)
        for r in range(2):
            w.put("rho", np.ones((32,)) * r, global_shape=(64,),
                  offset=(r * 32,), rank=r)
        w.end_step()
    w.close()
    assert (p / "dxt.json").exists()

    chrome = tmpdir_path / "trace.json"
    dxt_txt = tmpdir_path / "trace.txt"
    rc = jbpdxt_main([str(p), "--chrome", str(chrome), "--dxt", str(dxt_txt),
                      "--bins", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "timeline summary" in out
    assert "straggler" in out
    assert "bandwidth over time" in out
    ch = json.loads(chrome.read_text())
    assert any(e["ph"] == "X" for e in ch["traceEvents"])
    assert "X_POSIX" in dxt_txt.read_text()

    # --json agrees with the darshan counter for the subfile
    rc = jbpdxt_main([str(p), "--json"])
    assert rc == 0
    summ = json.loads(capsys.readouterr().out)
    files = MONITOR.report()["files"]
    sub = str(p / "data.0")
    assert summ["files"][sub]["bytes_written"] == \
        files[sub]["POSIX_BYTES_WRITTEN"]


def test_jbpdxt_cli_no_trace_is_usage_error(tmpdir_path, capsys):
    assert jbpdxt_main([str(tmpdir_path)]) == 2
    assert "no trace found" in capsys.readouterr().err


# ------------------------------------------------------------- jbpd watch op
def _write_series(p, steps=2):
    w = BpWriter(p, n_ranks=2)
    for s in range(steps):
        w.begin_step(s)
        for r in range(2):
            w.put("T", np.full((128,), r, np.float64), global_shape=(256,),
                  offset=(r * 128,), rank=r)
        w.end_step()
    w.close()
    return p


def test_watch_streams_deltas_that_sum_to_stats(tmpdir_path):
    series = _write_series(tmpdir_path / "s")
    sock = str(tmpdir_path / "jbpd.sock")
    server = SeriesServer([str(series)])
    with JbpDaemon(server, socket_path=sock).start():
        stop = threading.Event()

        def traffic():
            c = SeriesClient(sock, series=str(series))
            while not stop.is_set():
                c.read_var(1, "T")
                time.sleep(0.02)
            c.close()

        t = threading.Thread(target=traffic)
        t.start()
        try:
            wc = SeriesClient(sock, shm=False)
            seen = []
            res = wc.watch(interval_s=0.1, count=3, on_frame=seen.append)
            assert len(res["frames"]) >= 2          # >= 2 delta frames
            assert seen == res["frames"]            # live callback fired
            # begin + sum(deltas) == end == the final frame's absolutes
            acc = dict(res["begin"])
            for fr in res["frames"]:
                for k, v in fr["delta"].items():
                    acc[k] = acc.get(k, 0.0) + v
            assert acc == res["end"]
            assert res["end"] == res["frames"][-1]["counters"]
            # traffic actually moved the counters
            total_delta = sum(sum(fr["delta"].values())
                              for fr in res["frames"])
            assert total_delta > 0
        finally:
            stop.set()
            t.join()
        # --stats sees the SAME counter families (superset in time)
        st = wc.stats()
        assert set(st["counters"]) == set(res["end"])
        for k in res["end"]:
            assert st["counters"][k] >= res["end"][k] - 1e-9
        assert st["uptime_s"] > 0
        assert "dxt" in st and set(st["dxt"]) == {"enabled", "events",
                                                  "dropped", "capacity"}
        wc.close()


def test_watch_frames_carry_cache_and_dxt_stats(tmpdir_path):
    series = _write_series(tmpdir_path / "s")
    sock = str(tmpdir_path / "jbpd.sock")
    with JbpDaemon(SeriesServer([str(series)]), socket_path=sock).start():
        wc = SeriesClient(sock, shm=False)
        res = wc.watch(interval_s=0.05, count=2)
        for fr in res["frames"]:
            assert "cache" in fr and "entries" in fr["cache"]
            assert "dxt" in fr and "enabled" in fr["dxt"]
            assert fr["t"] > 0
        wc.close()


# ------------------------------------------------ heatmap epoch rebase (fix)
def test_heatmap_merge_rebases_different_start_times():
    """Regression: two monitors started at different times used to be
    superimposed at bin 0; merge() must rebase via the shipped epoch."""
    from repro.core.darshan import DarshanMonitor
    m1 = DarshanMonitor()
    m2 = DarshanMonitor()
    # m2 started 0.35s after m1 (deterministic: pin the epochs)
    m2._t0_epoch = m1._t0_epoch + 0.35
    m2.record(0, "f", "POSIX_WRITES", 1.0, "F_WRITE_TIME", 0.0, nbytes=512)
    snap = m2.snapshot()
    assert any(b == 0 for _r, b, _v in snap["heatmap"])  # at ITS bin 0
    m1.merge(snap)
    hm = m1.heatmap()
    # 0.35s / 0.1s bins -> bin 3 on m1's axis, NOT bin 0
    assert hm == {"rank0@0.3s": 512}


def test_heatmap_merge_legacy_snapshot_keeps_raw_bins():
    from repro.core.darshan import DarshanMonitor
    m = DarshanMonitor()
    m.merge({"per_rank": {}, "per_file": {}, "size_hist": {},
             "heatmap": [[1, 2, 64.0]]})        # pre-epoch snapshot shape
    assert m.heatmap() == {"rank1@0.2s": 64.0}
