"""openPMD data-model semantics over the JBP engine."""
import numpy as np
import pytest

from repro.core import EngineConfig, Series


def test_standard_attributes(tmpdir_path):
    s = Series(tmpdir_path / "a.bp4", "w")
    assert s.attributes["openPMD"] == "1.1.0"
    assert s.attributes["basePath"] == "/data/%T/"
    assert s.attributes["iterationEncoding"] == "groupBased"
    s.close()


def test_mesh_and_particles_roundtrip(tmpdir_path):
    s = Series(tmpdir_path / "a.bp4", "w", n_ranks=4,
               engine_config=EngineConfig(aggregators=2, codec="blosc"))
    rng = np.random.default_rng(0)
    dens = rng.normal(size=(64,)).astype(np.float32)
    it = s.iterations[10]
    it.time = 1.5
    rc = it.meshes["density"][""]
    rc.reset_dataset(np.float32, (64,))
    for r in range(4):
        rc.store_chunk(dens[r * 16:(r + 1) * 16], offset=(r * 16,), rank=r)
    pos = rng.normal(size=(100,))
    px = it.particles["electrons"]["position"]["x"]
    px.reset_dataset(np.float64, (100,))
    px.store_chunk(pos, offset=(0,), rank=0)
    it.close()
    s.close()

    r = Series(tmpdir_path / "a.bp4", "r")
    assert r.read_iterations() == [10]
    reader = r._reader()
    np.testing.assert_array_equal(
        reader.read_var(10, "/data/10/meshes/density"), dens)
    np.testing.assert_array_equal(
        reader.read_var(10, "/data/10/particles/electrons/position/x"), pos)
    assert reader.attributes(10)["/data/10/time"] == 1.5


def test_multiple_iterations_one_series(tmpdir_path):
    """Group-based iteration encoding with steps: one dir, many steps."""
    s = Series(tmpdir_path / "a.bp4", "w")
    for i in (0, 5, 9):
        rc = s.iterations[i].meshes["n"][""]
        rc.reset_dataset(np.float32, (8,))
        rc.store_chunk(np.full(8, float(i), np.float32), offset=(0,))
        s.flush()
    s.close()
    r = Series(tmpdir_path / "a.bp4", "r")
    assert r.read_iterations() == [0, 5, 9]
    got = r._reader().read_var(9, "/data/9/meshes/n")
    np.testing.assert_array_equal(got, np.full(8, 9.0, np.float32))


def test_flush_is_single_action(tmpdir_path):
    """Nothing hits the engine before flush(); everything after."""
    s = Series(tmpdir_path / "a.bp4", "w")
    rc = s.iterations[0].meshes["x"][""]
    rc.reset_dataset(np.float32, (4,))
    rc.store_chunk(np.ones(4, np.float32), offset=(0,))
    assert not (tmpdir_path / "a.bp4" / "md.idx").exists() or \
        (tmpdir_path / "a.bp4" / "md.idx").stat().st_size == 0
    s.flush()
    assert (tmpdir_path / "a.bp4" / "md.idx").stat().st_size > 0
    s.close()


def test_async_series_matches_sync(tmpdir_path):
    """async_io=True: flush() snapshots + enqueues; drain() is the
    durability barrier; on-disk data equals the sync series'."""
    def fill(s):
        for i in (0, 3, 7):
            rc = s.iterations[i].meshes["n"][""]
            rc.reset_dataset(np.float32, (16,))
            rc.store_chunk(np.arange(16, dtype=np.float32) + i, offset=(0,))
            s.flush()

    sync = Series(tmpdir_path / "sync.bp4", "w")
    fill(sync)
    sync.close()
    a = Series(tmpdir_path / "async.bp4", "w", async_io=True, queue_depth=2)
    fill(a)
    a.drain()                    # every flushed iteration sealed on disk
    r = Series(tmpdir_path / "async.bp4", "r")
    assert r.read_iterations() == [0, 3, 7]
    a.close()
    assert (tmpdir_path / "sync.bp4" / "md.0").read_bytes() == \
        (tmpdir_path / "async.bp4" / "md.0").read_bytes()
    assert (tmpdir_path / "sync.bp4" / "data.0").read_bytes() == \
        (tmpdir_path / "async.bp4" / "data.0").read_bytes()
    got = r._reader().read_var(7, "/data/7/meshes/n")
    np.testing.assert_array_equal(got, np.arange(16, dtype=np.float32) + 7)


def test_async_series_close_cleans_up_after_write_error(tmpdir_path):
    """A failed background write must not leave Series.close() unable to
    release the writer thread and metadata handles."""
    import pytest
    from repro.core.bp_engine import EngineConfig
    s = Series(tmpdir_path / "bad.bp4", "w", async_io=True,
               engine_config=EngineConfig(codec="no-such-codec"))
    rc = s.iterations[0].meshes["x"][""]
    rc.reset_dataset(np.float32, (4,))
    rc.store_chunk(np.ones(4, np.float32), offset=(0,))
    s.flush()
    with pytest.raises(ValueError, match="unknown codec"):
        s.close()
    assert s._writer is None            # engine released despite the error
    s.close()                           # second close is a clean no-op
    # a closed series must NEVER construct a fresh writer on the same path
    # (reopening md.0/md.idx "wb" would truncate sealed iterations)
    rc2 = s.iterations[1].meshes["x"][""]
    rc2.reset_dataset(np.float32, (4,))
    rc2.store_chunk(np.ones(4, np.float32), offset=(0,))
    with pytest.raises(RuntimeError, match="closed"):
        s.flush()
