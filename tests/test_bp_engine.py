"""JBP engine: roundtrips, aggregation invariants, crash consistency."""
import json

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.aggregation import aggregator_of
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig, IDX_SIZE
from repro.core.striping import StripeConfig


def _write_series(path, n_ranks=8, aggregators=3, codec="none", steps=2,
                  stripe=None):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=3,
                       stripe=stripe, n_osts=4)
    w = BpWriter(path, n_ranks, cfg)
    rng = np.random.default_rng(0)
    truth = {}
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(n_ranks * 16, 4)).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        w.end_step()
    w.close()
    return truth


@pytest.mark.parametrize("codec", ["none", "blosc", "bzip2"])
@pytest.mark.parametrize("aggregators", [1, 3, 8])
def test_roundtrip(tmpdir_path, codec, aggregators):
    truth = _write_series(tmpdir_path / "s.bp4", codec=codec,
                          aggregators=aggregators)
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0, 1]
    for s, g in truth.items():
        np.testing.assert_array_equal(r.read_var(s, "var/x"), g)


def test_striped_roundtrip(tmpdir_path):
    truth = _write_series(tmpdir_path / "s.bp4", aggregators=2,
                          stripe=StripeConfig(stripe_count=2, stripe_size=256))
    r = BpReader(tmpdir_path / "s.bp4")
    np.testing.assert_array_equal(r.read_var(1, "var/x"), truth[1])


def test_subfile_count_equals_aggregators(tmpdir_path):
    """N ranks -> M files: the paper's Table II invariant."""
    _write_series(tmpdir_path / "s.bp4", n_ranks=16, aggregators=5)
    datafiles = list((tmpdir_path / "s.bp4").glob("data.*"))
    assert len(datafiles) == 5


def test_box_selection(tmpdir_path):
    truth = _write_series(tmpdir_path / "s.bp4")
    r = BpReader(tmpdir_path / "s.bp4")
    sel = r.read_var(0, "var/x", offset=(21, 1), extent=(40, 2))
    np.testing.assert_array_equal(sel, truth[0][21:61, 1:3])


def test_read_var_empty_intersection(tmpdir_path):
    """A selection that intersects no chunk returns zeros of the selection
    shape and performs ZERO payload I/O (the chunks_in_box plan is empty)."""
    from repro.core.darshan import MONITOR
    _write_series(tmpdir_path / "s.bp4", n_ranks=8)   # global (128, 4)
    MONITOR.reset()
    r = BpReader(tmpdir_path / "s.bp4")
    sel = r.read_var(0, "var/x", offset=(128, 0), extent=(10, 4))
    np.testing.assert_array_equal(sel, np.zeros((10, 4), np.float32))
    assert r.chunks_in_box(0, "var/x", (128, 0), (10, 4)) == []
    files = MONITOR.report()["files"]
    assert sum(c.get("POSIX_READS", 0) for p, c in files.items()
               if "data." in p) == 0


def test_read_var_box_spanning_subfiles(tmpdir_path):
    """A box crossing aggregator boundaries assembles from multiple
    subfiles (8 ranks over 4 aggregators -> 2 ranks per subfile)."""
    from repro.core.darshan import MONITOR
    truth = _write_series(tmpdir_path / "s.bp4", n_ranks=8, aggregators=4)
    MONITOR.reset()
    r = BpReader(tmpdir_path / "s.bp4")
    # rows 24..104 span rank chunks 1..6 -> aggregators 0..3
    sel = r.read_var(1, "var/x", offset=(24, 0), extent=(80, 4))
    np.testing.assert_array_equal(sel, truth[1][24:104])
    touched = {p for p, c in MONITOR.report()["files"].items()
               if "data." in p and c.get("POSIX_READS", 0) > 0}
    assert len(touched) == 4


@pytest.mark.parametrize("codec", ["blosc", "bzip2", "zlib"])
def test_read_var_box_of_compressed_chunks(tmpdir_path, codec):
    """Box selections decompress only intersecting chunks, losslessly."""
    truth = _write_series(tmpdir_path / "s.bp4", codec=codec, n_ranks=8)
    r = BpReader(tmpdir_path / "s.bp4")
    sel = r.read_var(0, "var/x", offset=(19, 2), extent=(42, 2))
    np.testing.assert_array_equal(sel, truth[0][19:61, 2:4])
    # chunk stats survive the codec: metadata min/max == data min/max
    lo, hi = r.var_minmax(0, "var/x")
    assert lo == float(truth[0].min()) and hi == float(truth[0].max())


def test_put_rejects_out_of_range_rank(tmpdir_path):
    """put(rank=n_ranks) used to die deep in SubfileSet with an opaque
    IndexError; it must be a clear ValueError at the put() boundary."""
    w = BpWriter(tmpdir_path / "s.bp4", 4, EngineConfig(aggregators=2))
    w.begin_step(0)
    with pytest.raises(ValueError, match=r"rank=4.*n_ranks=4"):
        w.put("v", np.zeros(4, np.float32), global_shape=(4,), offset=(0,),
              rank=4)
    with pytest.raises(ValueError, match="rank=-1"):
        w.put("v", np.zeros(4, np.float32), global_shape=(4,), offset=(0,),
              rank=-1)
    w.put("v", np.zeros(4, np.float32), global_shape=(4,), offset=(0,),
          rank=3)
    w.end_step()
    w.close()
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0]


def test_reader_caches_subfile_handles(tmpdir_path):
    """A multi-chunk read_var must open data.<agg> once, not once per
    chunk (8 chunks in one aggregator -> 1 open)."""
    from repro.core.darshan import MONITOR
    _write_series(tmpdir_path / "s.bp4", n_ranks=8, aggregators=1)
    MONITOR.reset()
    r = BpReader(tmpdir_path / "s.bp4")
    r.read_var(0, "var/x")
    r.read_var(1, "var/x")
    files = MONITOR.report()["files"]
    opens = sum(c.get("POSIX_OPENS", 0) for p, c in files.items()
                if p.endswith("data.0"))
    reads = sum(c.get("POSIX_READS", 0) for p, c in files.items()
                if p.endswith("data.0"))
    assert opens == 1, f"data.0 reopened per chunk ({opens} opens)"
    assert reads == 16                     # payload reads still per chunk
    r.close()


def test_reader_striped_getstripe_roundtrip(tmpdir_path):
    """The striped read path constructs a REAL read-mode StripedFile:
    getstripe() works on it (the __new__ hack used to leave the object
    half-built and AttributeError out)."""
    from repro.core.striping import StripedFile
    truth = _write_series(tmpdir_path / "s.bp4", aggregators=2,
                          stripe=StripeConfig(stripe_count=2, stripe_size=256))
    r = BpReader(tmpdir_path / "s.bp4")
    np.testing.assert_array_equal(r.read_var(0, "var/x"), truth[0])
    sf = r._data_file(0)
    assert isinstance(sf, StripedFile)
    info = sf.getstripe()
    assert info["lmm_stripe_count"] == 2 and info["logical_size"] > 0
    r.close()


def test_torn_step_is_dropped(tmpdir_path):
    """Crash consistency: corrupt md.0 bytes -> that step invalid, rest ok."""
    _write_series(tmpdir_path / "s.bp4", steps=3)
    md = (tmpdir_path / "s.bp4" / "md.0")
    raw = bytearray(md.read_bytes())
    # find step-1 record region via the index and flip a byte
    idx = (tmpdir_path / "s.bp4" / "md.idx").read_bytes()
    import struct
    _, off, ln, _, _, _, _, _ = struct.unpack_from("<QQQIIQQQ", idx, IDX_SIZE)
    raw[off + 5] ^= 0xFF
    md.write_bytes(bytes(raw))
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0, 2]


def test_truncated_index_ignores_tail(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", steps=2)
    idxp = tmpdir_path / "s.bp4" / "md.idx"
    idxp.write_bytes(idxp.read_bytes()[:IDX_SIZE + 7])   # torn final record
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0]


def test_profiling_json(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", codec="blosc")
    prof = json.loads((tmpdir_path / "s.bp4" / "profiling.json").read_text())
    assert prof["engine"] == "JBP(BP4)"
    assert len(prof["steps"]) == 2
    assert prof["steps"][0]["bytes_raw"] > 0


@settings(max_examples=60, deadline=None)
@given(n_ranks=st.integers(1, 4096), m=st.integers(1, 512))
def test_property_aggregator_assignment(n_ranks, m):
    """Contiguous, monotone, surjective onto min(m, n_ranks) aggregators."""
    assign = [aggregator_of(r, n_ranks, m) for r in range(n_ranks)]
    assert assign == sorted(assign)
    assert set(assign) == set(range(min(m, n_ranks)))


# ---------------------------------------------------- API-misuse hard errors
# These held with `assert` before, i.e. not at all under `python -O`. A
# writer driven out of protocol must fail loudly in every interpreter mode.

def test_begin_step_while_step_open_raises(tmpdir_path):
    w = BpWriter(tmpdir_path / "s.bp4", 1, EngineConfig())
    w.begin_step(0)
    with pytest.raises(RuntimeError, match="still open"):
        w.begin_step(1)
    w.end_step()
    w.close()


def test_put_outside_step_raises(tmpdir_path):
    w = BpWriter(tmpdir_path / "s.bp4", 1, EngineConfig())
    with pytest.raises(RuntimeError, match="outside begin"):
        w.put("v", np.zeros(4, np.float32), global_shape=(4,),
              offset=(0,), rank=0)
    w.close()


def test_end_step_outside_step_raises(tmpdir_path):
    w = BpWriter(tmpdir_path / "s.bp4", 1, EngineConfig())
    with pytest.raises(RuntimeError, match="outside begin_step"):
        w.end_step()
    w.close()


def test_put_conflicting_global_shape_raises(tmpdir_path):
    w = BpWriter(tmpdir_path / "s.bp4", 2, EngineConfig())
    w.begin_step(0)
    w.put("v", np.zeros((4, 4), np.float32), global_shape=(8, 4),
          offset=(0, 0), rank=0)
    with pytest.raises(ValueError, match="conflicts with"):
        w.put("v", np.zeros((4, 4), np.float32), global_shape=(8, 5),
              offset=(4, 0), rank=1)
    w.end_step()
    w.close()
