import pathlib
import shutil
import tempfile

import pytest

from repro.core.darshan import MONITOR


@pytest.fixture()
def tmpdir_path():
    p = pathlib.Path(tempfile.mkdtemp(prefix="repro-test-"))
    yield p
    shutil.rmtree(p, ignore_errors=True)


@pytest.fixture(autouse=True)
def fresh_monitor():
    MONITOR.reset()
    yield
