import pathlib
import shutil
import tempfile

import pytest

from repro.core.darshan import MONITOR
from repro.core.dxt import TRACER
from repro.core.metrics import METRICS


@pytest.fixture()
def tmpdir_path():
    p = pathlib.Path(tempfile.mkdtemp(prefix="repro-test-"))
    yield p
    shutil.rmtree(p, ignore_errors=True)


@pytest.fixture(autouse=True)
def fresh_monitor():
    MONITOR.reset()
    METRICS.reset()
    yield
    # a test that enabled tracing must not leak it into the next test:
    # TRACER and METRICS are process-global exactly like MONITOR
    if TRACER.enabled:
        TRACER.disable()
        TRACER.reset()
    if METRICS.enabled:
        METRICS.disable()
    METRICS.reset()
