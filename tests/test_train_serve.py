"""End-to-end training (loss decreases, crash-resume determinism) + serving."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_for_smoke
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def _mk(tmp, steps=12, ckpt_every=4):
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    tcfg = TrainerConfig(steps=steps, log_every=100, ckpt_every=ckpt_every,
                         seq_len=64, global_batch=4)
    hp = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    return cfg, Trainer(cfg, tcfg, hp, tmp)


def test_loss_decreases(tmpdir_path):
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    tcfg = TrainerConfig(steps=30, log_every=1, ckpt_every=1000,
                         seq_len=64, global_batch=8)
    hp = AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=30)
    tr = Trainer(cfg, tcfg, hp, tmpdir_path / "c")
    out = tr.run()
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.05, losses[:3] + losses[-3:]


def test_crash_resume_bitexact(tmpdir_path):
    """Interrupted-then-resumed run ends at the same state as a straight
    run (deterministic data keyed by step; state checkpoint is exact)."""
    cfg, tr_straight = _mk(tmpdir_path / "a")
    out_straight = tr_straight.run()

    cfg, tr1 = _mk(tmpdir_path / "b")
    with pytest.raises(RuntimeError):
        tr1.run(crash_at=8)
    _, tr2 = _mk(tmpdir_path / "b")
    out_resumed = tr2.run()

    for a, b in zip(jax.tree_util.tree_leaves(out_straight["state"]["params"]),
                    jax.tree_util.tree_leaves(out_resumed["state"]["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_grad_compression_trains(tmpdir_path):
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    tcfg = TrainerConfig(steps=10, log_every=1, ckpt_every=1000, seq_len=32,
                         global_batch=4, grad_compression=True)
    hp = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    tr = Trainer(cfg, tcfg, hp, tmpdir_path / "c")
    out = tr.run()
    assert "residuals" in out["state"]
    losses = [h["loss"] for h in out["history"]]
    assert np.isfinite(losses).all()


def test_serve_greedy_matches_teacher_forcing(tmpdir_path):
    """Greedy decode tokens == argmax of full-forward logits, step by step."""
    import jax.numpy as jnp
    from repro.models import model as M
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_seq=64,
                                               max_new_tokens=6))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 10)).astype(np.int32)
    gen = eng.generate(prompts, new_tokens=6)

    # teacher-forced reference: repeatedly run the full forward
    seq = jnp.asarray(prompts)
    for t in range(6):
        logits, _ = M.forward(params, cfg, {"tokens": seq}, q_chunk=16,
                              kv_chunk=16)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(nxt)[:, 0], gen[:, t])
        seq = jnp.concatenate([seq, nxt], axis=1)


def test_data_pipeline_determinism():
    from repro.data.pipeline import SyntheticTokens
    d1 = SyntheticTokens(1000, 32, 8, seed=3)
    d2 = SyntheticTokens(1000, 32, 8, seed=3)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the global batch deterministically
    sh0 = SyntheticTokens(1000, 32, 8, seed=3, n_shards=2, shard_id=0)
    sh1 = SyntheticTokens(1000, 32, 8, seed=3, n_shards=2, shard_id=1)
    assert sh0.batch_at(5)["tokens"].shape == (4, 32)
    assert not np.array_equal(sh0.batch_at(5)["tokens"],
                              sh1.batch_at(5)["tokens"])
