"""Zero-copy shm chunk transport + async∘parallel composition.

Covers the ShmRing allocator (wrap-around, FIFO free-list, pow2 slots,
oversize spill), transport parity (shm and pickle transports must produce
bit-identical series), crash consistency (a worker SIGKILLed while ring
slots are in flight drops the step exactly like a torn shard and leaks
nothing in /dev/shm), the hardened close path (a dead worker must not
turn the context manager into a hang), and the composed
`Series(parallel_io=W, async_commit=True)` mode."""
import os
import pathlib
import signal
import tempfile

import numpy as np
import pytest

from _propcheck import given, settings, strategies as st
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.parallel_engine import ParallelBpWriter, WriterPlane
from repro.core.shm_transport import MIN_SLOT, ShmRing


def _ring_exists(name: str) -> bool:
    return pathlib.Path(f"/dev/shm/{name}").exists()


def _write_series(cls, path, *, n_ranks=8, codec="none", steps=3, **kw):
    cfg = EngineConfig(aggregators=4, codec=codec, workers=3)
    w = cls(path, n_ranks, cfg, **kw)
    rng = np.random.default_rng(11)
    truth = {}
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(n_ranks * 16, 4)).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        w.end_step()
    if hasattr(w, "drain"):
        w.drain()
    w.close()
    return truth


# ------------------------------------------------------------------ ShmRing
def test_ring_pow2_slots_and_oversize_spill():
    r = ShmRing(1 << 16)
    assert r.slot_len(1) == MIN_SLOT
    assert r.slot_len(MIN_SLOT + 1) == 2 * MIN_SLOT
    # oversized payload: the transport must DEGRADE (None -> pickle), not
    # block or raise
    assert r.write_array(np.zeros(r.capacity + 1, np.uint8)) is None
    r.close()
    r.unlink()


def test_ring_wraparound_preserves_contents():
    """Allocation wraps past the end of the segment (pad + restart at 0)
    and both sides of the wrap read back intact through an attached view."""
    r = ShmRing(1 << 16)
    att = ShmRing(name=r.name, create=False)
    first = [r.write_array(np.full(1000, i, np.float32)) for i in range(8)]
    tailh = r.write_array(np.arange(8192, dtype=np.float32))  # fills the end
    for h in first:
        r.free(h.offset)
    wrapped = r.write_array(np.full(1500, 9, np.float32))     # lands at 0
    assert wrapped is not None and wrapped.offset == 0
    np.testing.assert_array_equal(att.view(tailh),
                                  np.arange(8192, dtype=np.float32))
    assert (att.view(wrapped) == 9).all()
    r.free(tailh.offset)
    r.free(wrapped.offset)
    assert r.free_bytes() == r.capacity
    att.close()
    r.close()
    r.unlink()


def test_ring_free_is_fifo_only():
    r = ShmRing(1 << 16)
    a = r.write_array(np.zeros(100, np.float32))
    b = r.write_array(np.zeros(100, np.float32))
    with pytest.raises(ValueError, match="out-of-order free"):
        r.free(b.offset)
    r.free(a.offset)
    r.free(b.offset)
    r.close()
    r.unlink()


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(min_value=1, max_value=24 * 1024),
                      min_size=1, max_size=40),
       capacity_kib=st.sampled_from([16, 64, 256]))
def test_ring_alloc_free_property(sizes, capacity_kib):
    """Crash-consistency invariant of the allocator itself: under any
    alloc/free interleaving (free oldest whenever the ring refuses), every
    live slot's contents stay intact until ITS free, and draining the
    FIFO returns the ring to empty."""
    ring = ShmRing(capacity_kib * 1024)
    try:
        live: list = []                       # (header, expected fill value)
        for i, nbytes in enumerate(sizes):
            arr = np.full(max(nbytes // 4, 1), i, np.int32)
            hdr = ring.write_array(arr)
            while hdr is None and live:
                h, v = live.pop(0)            # ring full: retire the oldest
                assert (ring.view(h) == v).all(), "slot corrupted while live"
                ring.free(h.offset)
                hdr = ring.write_array(arr)
            if hdr is None:                   # oversized for this capacity
                continue
            live.append((hdr, i))
        for h, v in live:
            assert (ring.view(h) == v).all()
            ring.free(h.offset)
        assert ring.free_bytes() == ring.capacity
    finally:
        ring.close()
        ring.unlink()


# ------------------------------------------------------------------- parity
def test_shm_and_pickle_transports_bit_identical_w4(tmpdir_path):
    """The transport moves bytes, it must not change them: shm- and
    pickle-transport series at W=4 are bit-identical to each other AND to
    the single-process sync writer (zero reader-side format changes)."""
    truth = _write_series(BpWriter, tmpdir_path / "sync.bp4", codec="blosc")
    _write_series(ParallelBpWriter, tmpdir_path / "shm.bp4", codec="blosc",
                  n_writers=4, transport="shm")
    _write_series(ParallelBpWriter, tmpdir_path / "pkl.bp4", codec="blosc",
                  n_writers=4, transport="pickle")
    for name in ["data.0", "data.1", "data.2", "data.3", "md.0"]:
        ref = (tmpdir_path / "sync.bp4" / name).read_bytes()
        assert (tmpdir_path / "shm.bp4" / name).read_bytes() == ref, name
        assert (tmpdir_path / "pkl.bp4" / name).read_bytes() == ref, name
    r = BpReader(tmpdir_path / "shm.bp4")
    np.testing.assert_array_equal(r.read_var(2, "var/x"), truth[2])
    r.close()


def test_tiny_ring_spills_to_pickle_fallback_with_parity(tmpdir_path):
    """A ring too small for the step's chunks must degrade per-chunk to the
    pickle path — same bytes on disk, fallback visible in profiling."""
    _write_series(ParallelBpWriter, tmpdir_path / "ref.bp4", n_writers=2)
    cfg = EngineConfig(aggregators=2, codec="none", workers=3,
                       profiling=True)
    w = ParallelBpWriter(tmpdir_path / "tiny.bp4", 8, cfg, n_writers=2,
                         transport="shm", ring_bytes=2 * MIN_SLOT)
    rng = np.random.default_rng(11)
    prof = None
    for s in range(3):
        w.begin_step(s)
        g = rng.normal(size=(8 * 16, 4)).astype(np.float32)
        for r in range(8):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        prof = w.end_step()
    w.close()
    assert prof["transport_pickle_bytes"] > 0, "nothing spilled"
    for name in ["data.0", "data.1", "md.0"]:
        assert (tmpdir_path / "tiny.bp4" / name).read_bytes() == \
            (tmpdir_path / "ref.bp4" / name).read_bytes(), name


# -------------------------------------------------------- crash consistency
def test_worker_sigkill_with_slot_in_flight_drops_step(tmpdir_path):
    """SIGKILL a writer process while its ring slots are in flight: the
    step must abort uncommitted (exactly a torn shard), the context
    manager must still exit, the rings must be unlinked, and a fresh
    writer must succeed immediately afterwards."""
    cfg = EngineConfig(aggregators=2, codec="none", workers=3)
    with ParallelBpWriter(tmpdir_path / "p.bp4", 4, cfg, n_writers=2,
                          transport="shm", ack_timeout=60.0) as w:
        ring_names = [r.name for r in w._rings]
        w.begin_step(0)
        w.put("v", np.arange(8, dtype=np.float32), global_shape=(8,),
              offset=(0,), rank=0)
        w.end_step()                         # step 0 commits cleanly
        os.kill(w._workers[1][0].pid, signal.SIGKILL)
        w.begin_step(1)
        for r in range(4):                   # rank 2/3 route to dead worker 1
            w.put("v", np.full(8, r, np.float32), global_shape=(32,),
                  offset=(8 * r,), rank=r)
        with pytest.raises(RuntimeError, match="died before acking"):
            w.end_step()
    # context manager exited: workers reaped, rings unlinked
    assert all(not p.is_alive() for p, _ in w._workers)
    assert not any(_ring_exists(n) for n in ring_names), "ring leaked"
    # the killed step is invisible; the committed prefix survives
    r = BpReader(tmpdir_path / "p.bp4")
    assert r.valid_steps() == [0]
    np.testing.assert_array_equal(r.read_var(0, "v"),
                                  np.arange(8, dtype=np.float32))
    r.close()
    # the plane is rebuildable at once: the next step (new writer) succeeds
    _write_series(ParallelBpWriter, tmpdir_path / "next.bp4", n_ranks=4,
                  steps=1, n_writers=2, transport="shm")
    assert BpReader(tmpdir_path / "next.bp4").valid_steps() == [0]


def test_worker_killed_mid_step_close_does_not_hang(tmpdir_path):
    """The satellite regression: coordinator exception with a dead worker
    and undrained queues must not hang close() — stale acks are drained,
    task queues closed, stragglers terminated (bounded join)."""
    cfg = EngineConfig(aggregators=2, codec="none", workers=3)
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 4, cfg, n_writers=2,
                         transport="pickle", ack_timeout=60.0)
    w.begin_step(0)
    for r in range(4):
        w.put("v", np.full(1024, r, np.float32), global_shape=(4096,),
              offset=(1024 * r,), rank=r)
    os.kill(w._workers[0][0].pid, signal.SIGKILL)
    with pytest.raises(RuntimeError, match="died before acking"):
        w.end_step()
    w.close()                                # must return, not hang
    w.close()                                # idempotent
    assert all(not p.is_alive() for p, _ in w._workers)


def test_async_commit_worker_failure_surfaces_on_drain(tmpdir_path):
    """A background two-phase commit that fails (worker error) latches the
    error and surfaces it at the next producer call; later queued steps
    are dropped, not committed (no gapped series)."""
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 2,
                         EngineConfig(codec="no-such-codec"), n_writers=2,
                         async_commit=True)
    w.begin_step(0)
    w.put("v", np.arange(4, dtype=np.float32), global_shape=(4,),
          offset=(0,), rank=0)
    w.end_step()
    with pytest.raises(RuntimeError, match="unknown codec"):
        w.drain()
    with pytest.raises(RuntimeError, match="unknown codec"):
        w.close()
    w.close()                                # no-op afterwards
    assert BpReader(tmpdir_path / "p.bp4").valid_steps() == []


# ------------------------------------------------------------- composition
def test_series_async_commit_roundtrip_and_barrier(tmpdir_path):
    from repro.core.openpmd import Series
    s = Series(tmpdir_path / "d.bp4", "w", n_ranks=4,
               engine_config=EngineConfig(aggregators=2), parallel_io=2,
               async_commit=True)
    arr = np.linspace(0, 1, 64, dtype=np.float32)
    for it_idx in range(3):
        it = s.iterations[it_idx]
        rc = it.meshes["density"][""]
        rc.reset_dataset(arr.dtype, arr.shape)
        for r in range(4):
            rc.store_chunk(arr[r * 16:(r + 1) * 16] + it_idx,
                           offset=(r * 16,), rank=r)
        it.close()                           # flush: snapshot + enqueue only
    s.drain()                                # durability barrier
    r = BpReader(tmpdir_path / "d.bp4")
    assert r.valid_steps() == [0, 1, 2]
    s.close()
    r = BpReader(tmpdir_path / "d.bp4")
    for it_idx in range(3):
        np.testing.assert_array_equal(
            r.read_var(it_idx, f"/data/{it_idx}/meshes/density"),
            arr + it_idx)
    r.close()


def test_async_commit_output_byte_identical_to_sync_plane(tmpdir_path):
    """The composed mode is a LATENCY change, not a format change: same
    data.* and md.0 as the synchronous parallel plane and the sync
    writer."""
    _write_series(BpWriter, tmpdir_path / "sync.bp4")
    _write_series(ParallelBpWriter, tmpdir_path / "par.bp4", n_writers=4)
    _write_series(ParallelBpWriter, tmpdir_path / "ac.bp4", n_writers=4,
                  async_commit=True)
    for name in ["data.0", "data.1", "data.2", "data.3", "md.0"]:
        ref = (tmpdir_path / "sync.bp4" / name).read_bytes()
        assert (tmpdir_path / "par.bp4" / name).read_bytes() == ref, name
        assert (tmpdir_path / "ac.bp4" / name).read_bytes() == ref, name


def test_async_commit_fsync_step_forces_blocking_seal(tmpdir_path):
    """fsync_policy='step' + async_commit: end_step returns only after the
    commit record is durable — a reader opened mid-series sees every
    returned step (the checkpoint crash-consistency contract)."""
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 4,
                         EngineConfig(fsync_policy="step"), n_writers=2,
                         async_commit=True)
    for s in range(2):
        w.begin_step(s)
        w.put("v", np.full(8, s, np.float32), global_shape=(8,),
              offset=(0,), rank=0)
        prof = w.end_step()
        assert "queued" not in prof          # real profile: the seal is done
        assert BpReader(tmpdir_path / "p.bp4").valid_steps() == \
            list(range(s + 1))
    w.close()


def test_async_commit_profiling_has_overlap_block(tmpdir_path):
    import json
    cfg = EngineConfig(aggregators=2, codec="none", workers=3,
                       profiling=True)
    w = ParallelBpWriter(tmpdir_path / "q.bp4", 4, cfg, n_writers=2,
                         async_commit=True)
    w.begin_step(0)
    w.put("v", np.arange(8, dtype=np.float32), global_shape=(8,),
          offset=(0,), rank=0)
    prof = w.end_step()
    assert prof.get("queued") is True        # producer saw only the enqueue
    w.close()
    doc = json.loads((tmpdir_path / "q.bp4" / "profiling.json").read_text())
    assert doc["transport"] == "shm"
    assert doc["async"]["queue_depth"] >= 1
    assert doc["steps"][0]["transport_shm_bytes"] > 0


# -------------------------------------------------------- plane ring reuse
def test_writer_plane_rings_persist_across_series_and_unlink(tmpdir_path):
    """The plane owns the rings: same shm segments across series (no remap
    per save), unlinked exactly once at shutdown."""
    with WriterPlane(2) as plane:
        names = [r.name for r in plane.rings]
        assert len(names) == 2 and all(_ring_exists(n) for n in names)
        for i in range(2):
            _write_series(ParallelBpWriter, tmpdir_path / f"s{i}.bp4",
                          n_ranks=4, steps=2, n_writers=2, plane=plane)
            assert [r.name for r in plane.rings] == names
            assert all(_ring_exists(n) for n in names)
    assert not any(_ring_exists(n) for n in names), "plane leaked rings"
    for i in range(2):
        assert BpReader(tmpdir_path / f"s{i}.bp4").valid_steps() == [0, 1]


def test_checkpoint_manager_survives_killed_plane_worker(tmpdir_path):
    """Kill a plane worker between saves: the manager detects the dead
    plane, shuts it down (unlinking its rings — no shm leak) and respawns
    a fresh one, so the next save just succeeds."""
    from repro.ckpt.manager import CheckpointManager

    state = {"w": np.arange(256, dtype=np.float32).reshape(16, 16)}
    with CheckpointManager(tmpdir_path, every=1, parallel_io=2,
                           async_write=False, n_io_ranks=4) as m:
        assert m.save(state, 1)
        m.wait()
        plane = m._plane
        old_names = [r.name for r in plane.rings]
        os.kill(plane.workers[0][0].pid, signal.SIGKILL)
        plane.workers[0][0].join(timeout=10.0)   # death is observable
        assert m.save(state, 2)              # dead plane respawned lazily
        m.wait()
        assert m._plane is not plane
        assert [r.name for r in m._plane.rings] != old_names
        assert not any(_ring_exists(n) for n in old_names), \
            "dead plane leaked its rings"
    from repro.ckpt.checkpoint import restore_checkpoint
    restored, step = restore_checkpoint(tmpdir_path, dict(state))
    assert step == 2
    np.testing.assert_array_equal(restored["w"], state["w"])
