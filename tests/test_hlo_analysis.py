"""Roofline HLO analyzer: trip-count awareness + collective accounting."""
import subprocess
import sys
import textwrap

import pytest

from repro.roofline.hlo_analysis import (CollectiveRecord, analyze,
                                         shape_bytes, shape_dims, shape_elems)


def test_shape_parsing():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], s32[4])") == 24
    assert shape_bytes("pred[]") == 1
    assert shape_elems("f32[3,5]{1,0}") == 15
    assert shape_dims("bf16[2,3,4]") == [2, 3, 4]


def test_collective_traffic_model():
    ar = CollectiveRecord("all-reduce", 100.0, 4, 2.0)
    assert ar.traffic_bytes == pytest.approx(2 * 100 * 0.75 * 2)
    ag = CollectiveRecord("all-gather", 100.0, 4, 1.0)
    assert ag.traffic_bytes == pytest.approx(75.0)
    rs = CollectiveRecord("reduce-scatter", 25.0, 4, 1.0)
    assert rs.traffic_bytes == pytest.approx(25 * 4 * 0.75)


@pytest.mark.slow
def test_trip_count_awareness_subprocess():
    """flops(scan of 10 matmuls) ~ 10x flops(single matmul)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, json
        from repro.roofline.hlo_analysis import analyze

        def one(w, x):
            return jnp.sum(x @ w[0])

        def scan10(w, x):
            def body(c, wi):
                return c @ wi, None
            y, _ = jax.lax.scan(body, x, w)
            return jnp.sum(y)

        W = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
        X = jax.ShapeDtypeStruct((32, 128), jnp.float32)
        f1 = analyze(jax.jit(one).lower(W, X).compile().as_text(), 1)
        f10 = analyze(jax.jit(scan10).lower(W, X).compile().as_text(), 1)
        ratio = f10["flops_per_device"] / f1["flops_per_device"]
        print("RATIO", ratio)
        assert 8.0 < ratio < 12.5, ratio
        print("TRIPS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env())
    assert "TRIPS_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


@pytest.mark.slow
def test_collectives_detected_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_analysis import analyze

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 2), ("data", "model"))
        W = jax.ShapeDtypeStruct((256, 512), jnp.float32)
        X = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        f = lambda w, x: jnp.sum((x @ w)**2)
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", "model")),
                                     NamedSharding(mesh, P("data", None)))
                    ).lower(W, X).compile()
        a = analyze(c.as_text(), 4)
        assert a["collective_traffic_per_device"] > 0
        kinds = set(a["collective_traffic_by_kind"])
        assert "all-gather" in kinds or "all-reduce" in kinds, kinds
        print("COLL_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env())
    assert "COLL_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env
