"""Per-arch smoke tests: reduced config, one forward + train-grad + decode
step on CPU; output shapes + no NaNs. (The FULL configs are exercised only
via the dry-run — ShapeDtypeStructs, no allocation.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs, reduce_for_smoke
from repro.models import model as M

ARCHS = list_configs()


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
        batch["tokens"] = None
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = M.forward(params, cfg, batch, q_chunk=16, kv_chunk=16,
                            ssd_chunk=16)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    loss, metrics = M.loss_fn(params, cfg, batch, q_chunk=16, kv_chunk=16,
                              ssd_chunk=16)
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: M.loss_fn(p, cfg, batch, q_chunk=16, kv_chunk=16,
                                     ssd_chunk=16)[0])(params)
    gn = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(g))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 32
    cache = M.init_decode_cache(cfg, B, S)
    if cfg.family == "vlm":
        batch = _batch(cfg, key, B, S)
        _, cache = M.prefill(params, cfg, batch, q_chunk=16, kv_chunk=16)
    tok = jnp.zeros((B, 1), jnp.int32)
    emb = (jax.random.normal(key, (B, 1, cfg.d_model))
           if cfg.family == "audio" else None)
    logits, cache2 = M.decode_step(params, cfg, tok, cache,
                                   jnp.asarray(3, jnp.int32), embeds=emb)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache2) ==
            jax.tree_util.tree_structure(cache))


def test_full_config_param_counts_match_names():
    expected = {"arctic-480b": 477e9, "llama-3.2-vision-90b": 88e9,
                "deepseek-moe-16b": 16.4e9, "qwen3-4b": 4.4e9,
                "phi3-mini-3.8b": 3.8e9, "mamba2-2.7b": 2.8e9,
                "zamba2-2.7b": 2.4e9, "musicgen-large": 3.2e9,
                "qwen1.5-0.5b": 0.46e9, "smollm-360m": 0.36e9}
    for arch, n in expected.items():
        got = get_config(arch).n_params()
        assert abs(got - n) / n < 0.05, (arch, got, n)


def test_moe_active_params():
    cfg = get_config("arctic-480b")
    assert cfg.n_active_params() < 0.05 * cfg.n_params()
