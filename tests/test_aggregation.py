"""WriterPool failure semantics, SubfileSet ownership, aggregator_of
validation — the regression suite for the work-stealing bugfixes."""
import threading
import time

import numpy as np
import pytest

from repro.core.aggregation import SubfileSet, WriterPool, aggregator_of


def _drain_with_timeout(pool, timeout=10.0):
    """Run drain() on a helper thread so a regression (hung drain) fails
    the test instead of hanging the suite."""
    result = {}

    def run():
        try:
            pool.drain()
            result["ok"] = True
        except BaseException as e:              # noqa: BLE001
            result["err"] = e

    t = threading.Thread(target=run, daemon=True)
    t.start()
    t.join(timeout)
    assert not t.is_alive(), "drain() hung — worker thread died on a task"
    return result


def test_pool_survives_failing_task():
    """A failing task must not kill its worker: the pool keeps draining
    and drain() raises the recorded task error."""
    pool = WriterPool(2)
    done = []

    def bad():
        raise OSError("injected task failure")

    pool.submit(bad)
    for i in range(8):
        pool.submit(done.append, i)
    result = _drain_with_timeout(pool)
    assert isinstance(result.get("err"), OSError)
    assert sorted(done) == list(range(8)), "tasks after the failure ran"
    # the pool is still fully usable: same workers, clean drain
    pool.submit(done.append, 99)
    assert _drain_with_timeout(pool).get("ok") is True
    assert 99 in done
    pool.shutdown()


def test_pool_first_error_wins_and_clears():
    pool = WriterPool(1)

    def fail(msg):
        raise ValueError(msg)

    pool.submit(fail, "first")
    pool.submit(fail, "second")
    with pytest.raises(ValueError, match="first"):
        pool.drain()
    # the error was consumed; a clean drain follows
    pool.drain()
    pool.shutdown()


def test_pool_shutdown_raises_pending_error_but_stops_workers():
    pool = WriterPool(2)
    pool.submit(lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    with pytest.raises(RuntimeError, match="boom"):
        pool.shutdown()
    time.sleep(0.15)
    assert all(not t.is_alive() for t in pool._threads)


# ---------------------------------------------------------------- SubfileSet
def test_subfileset_owned_subset(tmpdir_path):
    s = SubfileSet(tmpdir_path, 4, owned=(2,))
    assert s.append(2, b"abcd") == 0
    assert s.append(2, b"efgh") == 4
    with pytest.raises(ValueError, match="not owned"):
        s.append(0, b"nope")
    s.fsync_close()
    assert (tmpdir_path / "data.2").read_bytes() == b"abcdefgh"
    assert not (tmpdir_path / "data.0").exists(), \
        "an owned SubfileSet must not create other processes' subfiles"


def test_subfileset_owned_validation(tmpdir_path):
    with pytest.raises(ValueError, match="out of range"):
        SubfileSet(tmpdir_path, 2, owned=(5,))


def test_subfileset_default_owns_all(tmpdir_path):
    s = SubfileSet(tmpdir_path, 3)
    for i in range(3):
        s.append(i, bytes([i]) * 4)
    s.fsync_close()
    assert sorted(p.name for p in tmpdir_path.glob("data.*")) == \
        ["data.0", "data.1", "data.2"]


# -------------------------------------------------------------- aggregator_of
def test_aggregator_of_validates_rank():
    with pytest.raises(ValueError, match="out of range"):
        aggregator_of(8, 8, 4)
    with pytest.raises(ValueError, match="out of range"):
        aggregator_of(-1, 8, 4)
    with pytest.raises(ValueError, match="n_ranks"):
        aggregator_of(0, 0, 4)
    assert aggregator_of(7, 8, 4) == 3


def test_writer_rank_range_inverts_aggregator_of():
    from repro.launch.distributed import writer_rank_range
    for n_ranks in (1, 3, 8, 17):
        for m in (1, 2, 4, 5):
            mm = min(m, n_ranks)
            for w in range(mm):
                for r in writer_rank_range(w, n_ranks, m):
                    assert aggregator_of(r, n_ranks, m) == w
            covered = sorted(r for w in range(mm)
                             for r in writer_rank_range(w, n_ranks, m))
            assert covered == list(range(n_ranks))
