"""Regression tests for the jbplint JBP001 sweep: every runtime check
that used to be a bare `assert` now raises a real exception — and keeps
raising under `python -O` / PYTHONOPTIMIZE=1, where bare asserts vanish
(which is exactly why they were banned; see repro/analysis/checkers.py)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.darshan import CTR, KNOWN_COUNTERS, DarshanMonitor
from repro.core.sst_engine import SstStream
from repro.data.pipeline import SyntheticTokens
from repro.insitu.reducers import Moments, ReducerSet
from repro.insitu.runner import assert_parity
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import _pad_entries

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


# ----------------------------------------------------- converted raise sites
def test_pipeline_rejects_indivisible_shards():
    with pytest.raises(ValueError, match="not divisible by n_shards"):
        SyntheticTokens(100, 8, global_batch=10, n_shards=3)


def test_sst_stream_step_protocol():
    s = SstStream()
    with pytest.raises(RuntimeError, match="outside a step"):
        s.put("v", np.zeros(2, np.float32))
    s.begin_step(0)
    with pytest.raises(RuntimeError, match="still open"):
        s.begin_step(1)


def test_reducer_set_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate reducer names"):
        ReducerSet([Moments("var/x", name="m"), Moments("var/y", name="m")])


def test_assert_parity_contract():
    a = {"m": np.arange(4, dtype=np.float32)}
    assert_parity(a, {"m": np.arange(4, dtype=np.float32)})  # equal: silent
    with pytest.raises(AssertionError, match="keys"):
        assert_parity(a, {"other": a["m"]})
    with pytest.raises(AssertionError):
        assert_parity(a, {"m": a["m"] + 1})


def test_debug_mesh_device_count_validation():
    with pytest.raises(ValueError, match="even device count"):
        make_debug_mesh(devices=list(range(3)))
    with pytest.raises(ValueError, match="even device count >= 8"):
        make_debug_mesh(multi_pod=True, devices=list(range(6)))


def test_register_rejects_unknown_family():
    from repro.configs.base import register
    from repro.configs.qwen1p5_0p5b import CONFIG
    bad = dataclasses.replace(CONFIG, name="tmp-bad-family", family="nope")
    with pytest.raises(ValueError, match="unknown model family 'nope'"):
        register(bad)


def test_ssd_chunked_rejects_ragged_chunks():
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 1, 6, 2, 4, 3
    with pytest.raises(ValueError, match="not divisible by chunk"):
        ssd_chunked(np.zeros((b, s, h, p), np.float32),
                    np.full((b, s, h), 0.1, np.float32),
                    np.full(h, -1.0, np.float32),
                    np.zeros((b, s, n), np.float32),
                    np.zeros((b, s, n), np.float32),
                    np.zeros(h, np.float32), chunk=4)


def test_flash_rejects_grouped_kv_heads():
    from repro.models.attention import flash_attention_jnp
    q = np.zeros((1, 8, 4, 8), np.float32)
    kv = np.zeros((1, 8, 2, 8), np.float32)
    with pytest.raises(ValueError, match="expand KV heads first"):
        flash_attention_jnp(q, kv, kv)


def test_serve_engine_rejects_overlong_prompt():
    from repro.configs.qwen1p5_0p5b import CONFIG
    from repro.serve.engine import ServeConfig, ServeEngine
    # params=None: the budget check fires BEFORE any compute touches them
    eng = ServeEngine(CONFIG, None, ServeConfig(max_seq=8))
    with pytest.raises(ValueError, match="exceeds the serve cache budget"):
        eng.generate(np.zeros((1, 6), np.int32), new_tokens=4)


def test_pad_entries_flags_overlong_rule():
    assert _pad_entries(("w",), (2, 4), ("model",)) == (None, "model")
    with pytest.raises(RuntimeError, match="fix the param sharding table"):
        _pad_entries(("layer", "w"), (4,), (None, "model"))


# ------------------------------------------------------- the frozen registry
def test_record_rejects_unknown_counter_with_suggestion():
    mon = DarshanMonitor()
    with pytest.raises(KeyError, match="did you mean 'POSIX_WRITES'"):
        mon.record(0, "f", "POSIX_WRITS", 1.0)
    with pytest.raises(KeyError, match="unknown Darshan counter"):
        mon.record(0, "f", CTR.POSIX_WRITES, 1.0, "F_WRIT_TIME", 0.1)
    # the registry itself is frozen — no call site can mint a counter
    with pytest.raises(AttributeError, match="frozen"):
        CTR.POSIX_TYPO = "POSIX_TYPO"
    assert CTR.POSIX_WRITES in KNOWN_COUNTERS
    assert CTR.DXT_EVENTS not in KNOWN_COUNTERS   # report-only key


# ------------------------------------------------------------ the -O contract
def test_validation_survives_python_optimize():
    """PYTHONOPTIMIZE=1 strips bare asserts (the subprocess proves it),
    but every converted site still raises — the point of JBP001."""
    prog = textwrap.dedent("""\
        import numpy as np
        # sanity: bare asserts really ARE stripped in this interpreter
        try:
            assert 1 == 2
        except AssertionError:
            raise SystemExit("asserts not stripped — test is vacuous")

        from repro.core.darshan import DarshanMonitor
        from repro.core.sst_engine import SstStream
        from repro.insitu.runner import assert_parity

        try:
            SstStream().put("v", np.zeros(2, np.float32))
            raise SystemExit("SstStream.put: no error under -O")
        except RuntimeError:
            pass
        try:
            DarshanMonitor().record(0, "f", "POSIX_WRITS", 1.0)
            raise SystemExit("record: no error under -O")
        except KeyError:
            pass
        try:
            assert_parity({"m": np.zeros(2)}, {"m": np.ones(2)})
            raise SystemExit("assert_parity: no error under -O")
        except AssertionError:
            pass
        print("OPTIMIZED-OK")
        """)
    env = dict(os.environ, PYTHONOPTIMIZE="1",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OPTIMIZED-OK" in out.stdout
