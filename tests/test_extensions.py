"""Beyond-paper extensions: microbatch accumulation, SST streaming,
pod-ZeRO-1 specs, straggler absorption."""
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_for_smoke
from repro.optim.adamw import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def test_microbatch_equals_full_batch():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    s1 = init_train_state(cfg, jax.random.PRNGKey(0))
    s2 = jax.tree_util.tree_map(lambda x: x.copy(), s1)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32),
             "labels": rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32)}
    hp = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    kw = dict(q_chunk=16, kv_chunk=16, ssd_chunk=16)
    o1, m1 = jax.jit(make_train_step(cfg, hp, **kw))(s1, batch)
    o2, m2 = jax.jit(make_train_step(cfg, hp, microbatches=4, **kw))(s2, batch)
    for a, b in zip(jax.tree_util.tree_leaves(o1["params"]),
                    jax.tree_util.tree_leaves(o2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=3e-4)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3


def test_sst_streaming_roundtrip():
    from repro.core.sst_engine import SstStream, attach_consumer
    stream = SstStream(queue_depth=2)
    seen = {}
    t = attach_consumer(stream, lambda step, data: seen.update({step: data}))
    for s in range(3):
        stream.begin_step(s)
        stream.put("n", np.full(4, s, np.float32), global_shape=(8,),
                   offset=(0,))
        stream.put("n", np.full(4, s + 10, np.float32), global_shape=(8,),
                   offset=(4,))
        stream.end_step()
    stream.close()
    t.join(timeout=5)
    assert sorted(seen) == [0, 1, 2]
    np.testing.assert_array_equal(
        seen[2]["n"], np.concatenate([np.full(4, 2.0), np.full(4, 12.0)]))


def test_opt_moments_shard_over_pod():
    from repro.train.state import train_state_shardings
    mesh = jax.sharding.AbstractMesh((("pod", 2), ("data", 16),
                                      ("model", 16)))
    cfg = get_config("qwen3-4b")
    sh = train_state_shardings(cfg, mesh)
    m_spec = sh["opt"]["m"]["stack"]["layers"]["ffn"]["gate"]["w"].spec
    p_spec = sh["params"]["stack"]["layers"]["ffn"]["gate"]["w"].spec
    flat_m = [a for e in m_spec if e for a in
              (e if isinstance(e, tuple) else (e,))]
    flat_p = [a for e in p_spec if e for a in
              (e if isinstance(e, tuple) else (e,))]
    assert "pod" in flat_m and "pod" not in flat_p


def test_straggler_ost_absorbed_by_pool():
    """Work-stealing writer pool: a slow OST delays its own stripe stream,
    not the whole step (aggregate wall < serialized sum)."""
    import pathlib
    import tempfile
    from repro.core.bp_engine import BpWriter, EngineConfig
    from repro.core.striping import StripeConfig
    import shutil
    d = pathlib.Path(tempfile.mkdtemp())
    try:
        import repro.core.bp_engine as BE
        from repro.core.striping import OstPool
        # 4 aggregators, OST 0 is slow; pool workers absorb
        cfg = EngineConfig(aggregators=4, workers=4,
                           stripe=StripeConfig(2, 1 << 16), n_osts=4)
        w = BpWriter(d / "s.bp4", 8, cfg)
        w.subfiles._files[0].pool.slow_osts[0] = 0.2    # 200 ms/write on ost0
        t0 = time.perf_counter()
        w.begin_step(0)
        rng = np.random.default_rng(0)
        for r in range(8):
            w.put("x", rng.normal(size=(1 << 15,)).astype(np.float32),
                  global_shape=(8 << 15,), offset=(r << 15,), rank=r)
        w.end_step()
        w.close()
        wall = time.perf_counter() - t0
        # the slow aggregator pays its ~200ms writes while the others
        # proceed in parallel: absorbed wall measures ~0.75s. Fully
        # serializing every stripe behind the slow OST would cost
        # >= 8 x 2 x 200ms = 3.2s — the threshold sits under that with
        # ~2s of headroom for scheduler stalls on noisy shared machines.
        assert wall < 3.0, wall
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_darshan_parser_dump(tmpdir_path):
    from repro.core.darshan import MONITOR, open_file
    MONITOR.reset()
    with open_file(tmpdir_path / "x.bin", "wb", rank=1) as f:
        f.write(b"abc" * 100)
    txt = MONITOR.parser_dump(n_procs=4)
    assert "total_POSIX_WRITES\t1.000000" in txt
    assert "x.bin" in txt and "hist\t" in txt


def test_distributed_helpers():
    from repro.launch.distributed import initialize, io_rank_range
    info = initialize()                      # single-process no-op path
    assert info["num_processes"] == 1 and info["global_devices"] >= 1
    ranges = [list(io_rank_range(64, p, 4)) for p in range(4)]
    flat = [r for rr in ranges for r in rr]
    assert flat == list(range(64))           # partition, no overlap
