"""Property-testing front door: real `hypothesis` when installed, else a
minimal deterministic fallback shim with the same surface the suite uses
(`given`, `settings`, `strategies.integers/sampled_from/binary/lists`).

Import from here instead of `hypothesis` so tier-1 collection never
hard-fails on the dependency:

    from _propcheck import given, settings, strategies as st

The shim draws `max_examples` pseudo-random examples from a fixed per-test
seed (reproducible failures), biasing the first draws toward strategy
corners (min/max sizes and values) where round-trip bugs live.
"""
from __future__ import annotations

try:                                          # the real thing, if available
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    class _Strategy:
        def example(self, rng: random.Random, corner: bool):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=1 << 16):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng, corner):
            if corner:
                return rng.choice((self.lo, self.hi))
            return rng.randint(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng, corner):
            return rng.choice(self.elements)

    class _Binary(_Strategy):
        def __init__(self, min_size=0, max_size=64):
            self.lo, self.hi = int(min_size), int(max_size)

        def example(self, rng, corner):
            n = rng.choice((self.lo, self.hi)) if corner \
                else rng.randint(self.lo, self.hi)
            return rng.randbytes(n)

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=8):
            self.elem, self.lo, self.hi = elements, int(min_size), int(max_size)

        def example(self, rng, corner):
            n = rng.choice((self.lo, self.hi)) if corner \
                else rng.randint(self.lo, self.hi)
            return [self.elem.example(rng, False) for _ in range(n)]

    class strategies:                          # noqa: N801 — mimic module
        integers = _Integers
        sampled_from = _SampledFrom
        binary = _Binary
        lists = _Lists

    class _Settings:
        def __init__(self, max_examples=100, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):                # decorator form: @settings(...)
            fn._pc_settings = self
            return fn

    settings = _Settings

    def given(**drawn):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_pc_settings", _Settings())
                rng = random.Random(f"jbp:{fn.__module__}.{fn.__qualname__}")
                for i in range(cfg.max_examples):
                    ex = {k: s.example(rng, corner=i < 2)
                          for k, s in drawn.items()}
                    try:
                        fn(*args, **kwargs, **ex)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example ({fn.__name__}): {ex!r}"
                        ) from e
                return None

            # pytest must not see the drawn params as fixtures
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in drawn])
            del wrapper.__wrapped__
            return wrapper
        return deco
