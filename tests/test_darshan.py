"""Darshan-style monitoring: exact counter semantics."""
import numpy as np

from repro.core.darshan import MONITOR, open_file
from repro.core.original_io import write_dat, write_dmp


def test_counters_exact(tmpdir_path):
    MONITOR.reset()
    with open_file(tmpdir_path / "f.bin", "wb", rank=3) as f:
        f.write(b"x" * 100)
        f.write(b"y" * 50)
        f.seek(0)
        f.fsync()
    rep = MONITOR.report()
    tot = rep["total"]
    assert tot["POSIX_OPENS"] == 1
    assert tot["POSIX_WRITES"] == 2
    assert tot["POSIX_BYTES_WRITTEN"] == 150
    assert tot["POSIX_SEEKS"] == 1
    assert tot["POSIX_FSYNCS"] == 1
    assert rep["n_ranks"] == 1
    assert rep["avg_per_process"]["F_META_TIME"] > 0


def test_per_rank_attribution(tmpdir_path):
    MONITOR.reset()
    for r in range(4):
        with open_file(tmpdir_path / f"r{r}.bin", "wb", rank=r) as f:
            f.write(bytes(10 * (r + 1)))
    rep = MONITOR.report()
    assert rep["n_ranks"] == 4
    assert rep["avg_per_process"]["POSIX_BYTES_WRITTEN"] == 25.0


def test_original_io_metadata_dominance(tmpdir_path):
    """The paper's Fig 5 pathology: file-per-rank tiny text writes spend
    comparable-or-more time in metadata than in data writes per byte."""
    MONITOR.reset()
    arr = np.arange(64, dtype=np.float32)
    for r in range(16):
        write_dat(tmpdir_path, r, 0, {"ne": arr})
        write_dmp(tmpdir_path, r, 0, {"x": arr})
    rep = MONITOR.report()
    assert rep["total"]["POSIX_OPENS"] == 32           # one per file
    assert MONITOR.total_files_written() == 32          # O(ranks) files
    cost = MONITOR.cost_per_process()
    assert cost["meta_s"] > 0 and cost["write_s"] > 0


def test_access_size_histogram(tmpdir_path):
    MONITOR.reset()
    with open_file(tmpdir_path / "h.bin", "wb") as f:
        f.write(b"a" * 50)            # 0-100 bin
        f.write(b"b" * 5000)          # 1024-10240 bin
    hist = MONITOR.report()["access_size_histogram"]
    assert hist.get("0-100") == 1
    assert hist.get("1024-10240") == 1


def test_flush_and_close_are_real_counters(tmpdir_path):
    """flush() used to be invisible and close() recorded POSIX_STATS with
    inc=0.0 — both are first-class metadata ops now."""
    MONITOR.reset()
    with open_file(tmpdir_path / "f.bin", "wb") as f:
        f.write(b"x" * 10)
        f.flush()
        f.flush()
    tot = MONITOR.report()["total"]
    assert tot["POSIX_FLUSHES"] == 2
    assert tot["POSIX_CLOSES"] == 1
    assert tot.get("POSIX_STATS", 0.0) == 0.0
    assert tot["F_META_TIME"] > 0                # flush/close time attributed


def test_report_n_procs_normalization(tmpdir_path):
    """Aggregated writes are attributed to aggregator ids, so 'observed
    ranks' undercounts the job; n_procs must normalize by the REAL count."""
    MONITOR.reset()
    for r in range(2):                # 2 aggregators acting for 8 ranks
        with open_file(tmpdir_path / f"data.{r}", "wb", rank=r) as f:
            f.write(b"z" * 400)
    rep_observed = MONITOR.report()
    assert rep_observed["n_ranks"] == 2
    assert rep_observed["avg_per_process"]["POSIX_BYTES_WRITTEN"] == 400.0
    rep8 = MONITOR.report(n_procs=8)
    assert rep8["avg_per_process"]["POSIX_BYTES_WRITTEN"] == 100.0
    # totals are NOT normalized — only the per-process view
    assert rep8["total"]["POSIX_BYTES_WRITTEN"] == 800.0
    assert (MONITOR.cost_per_process(8)["write_s"] * 8
            == MONITOR.report()["total"]["F_WRITE_TIME"])


def test_parser_dump_structural_roundtrip(tmpdir_path):
    """One block per counter family: POSIX + TIME totals, TRANSPORT_*,
    SERVICE_*, per-file records, the histogram, and the DXT summary —
    parsed back line-by-line against report()."""
    MONITOR.reset()
    with open_file(tmpdir_path / "x.bin", "wb", rank=1) as f:
        f.write(b"q" * 2048)
        f.flush()
        f.fsync()
    MONITOR.record(1, "transport", "TRANSPORT_SHM_BYTES", inc=4096.0)
    MONITOR.record(0, "served", "SERVICE_CACHE_HIT", inc=3.0)
    dump = MONITOR.parser_dump(n_procs=4)
    lines = dump.splitlines()
    assert "# nprocs: 4" in dump

    totals = {}
    for ln in lines:
        if ln.startswith("total_"):
            k, v = ln.split("\t")
            totals[k[len("total_"):]] = float(v)
    # every family is present...
    for k in ("POSIX_OPENS", "POSIX_WRITES", "POSIX_FLUSHES", "POSIX_CLOSES",
              "POSIX_BYTES_WRITTEN", "F_WRITE_TIME", "F_META_TIME",
              "TRANSPORT_SHM_BYTES", "TRANSPORT_PICKLE_FALLBACK_BYTES",
              "SERVICE_CACHE_HIT", "SERVICE_SOCKET_BYTES"):
        assert k in totals, k
    # ...and every value round-trips report()'s totals exactly
    tot = MONITOR.report()["total"]
    for k, v in totals.items():
        assert v == round(tot.get(k, 0.0), 6), k
    assert totals["TRANSPORT_SHM_BYTES"] == 4096.0
    assert totals["SERVICE_CACHE_HIT"] == 3.0

    # per-file record block and histogram
    assert f"file\t{tmpdir_path / 'x.bin'}" in dump
    assert any(ln.startswith("hist\t1024-10240") for ln in lines)
    # DXT summary block is always present (disabled here)
    assert "dxt_enabled\t0" in dump
    assert "dxt_events\t0" in dump
    assert "dxt_dropped\t0" in dump


def test_parser_dump_dxt_section_counts_ops(tmpdir_path):
    from repro.core.dxt import TRACER
    MONITOR.reset()
    TRACER.enable()
    with open_file(tmpdir_path / "y.bin", "wb") as f:
        f.write(b"k" * 64)
    dump = MONITOR.parser_dump()
    assert "dxt_enabled\t1" in dump
    assert "dxt_op\twrite\t1" in dump
    assert "dxt_op\topen\t1" in dump


def test_merge_worker_payload_mixed_legacy_and_current(tmpdir_path):
    """Satellite of PR 9: one coordinator must absorb — in the SAME merge
    sequence — a legacy worker's bare monitor snapshot (pre-DXT peers,
    possibly epoch-less) and a current worker's {"darshan","dxt",
    "metrics"} payload, with every plane landing additively."""
    import copy

    from repro.core.darshan import DarshanMonitor, merge_worker_payload
    from repro.core.dxt import TRACER, DxtTracer
    from repro.core.metrics import METRICS, MetricsRegistry

    # --- a "legacy" worker: bare snapshot, stripped of its clock epoch
    legacy_mon = DarshanMonitor()
    with open_file(tmpdir_path / "legacy.bin", "wb", rank=1,
                   monitor=legacy_mon) as f:
        f.write(b"a" * 100)
    legacy = legacy_mon.snapshot()
    legacy.pop("epoch", None)            # epoch-less: oldest wire form
    legacy.pop("bin_s", None)

    # --- a "current" worker: full three-plane payload
    cur_mon = DarshanMonitor()
    cur_met = MetricsRegistry()
    cur_met.enable()
    TRACER.enable()                      # conftest disables+resets after
    with open_file(tmpdir_path / "cur.bin", "wb", rank=2,
                   monitor=cur_mon) as f:
        f.write(b"b" * 200)
    cur_met.observe("write", 1e-4, nbytes=200, key="cur.bin")
    current = {"darshan": cur_mon.snapshot(),
               "dxt": TRACER.snapshot(reset=True),
               "metrics": cur_met.snapshot()}

    MONITOR.reset()
    METRICS.reset()
    sink_trc = DxtTracer()
    merge_worker_payload(copy.deepcopy(legacy), MONITOR, sink_trc, METRICS)
    merge_worker_payload(copy.deepcopy(current), MONITOR, sink_trc, METRICS)
    merge_worker_payload(None, MONITOR, sink_trc, METRICS)       # tolerated
    merge_worker_payload({}, MONITOR, sink_trc, METRICS)         # tolerated

    rep = MONITOR.report()
    assert rep["total"]["POSIX_WRITES"] == 2
    assert rep["total"]["POSIX_BYTES_WRITTEN"] == 300
    # per-rank attribution survives the mixed merge
    assert rep["n_ranks"] == 2
    per_file = rep["files"]
    assert per_file[str(tmpdir_path / "legacy.bin")]["POSIX_WRITES"] == 1
    assert per_file[str(tmpdir_path / "cur.bin")]["POSIX_WRITES"] == 1
    # the current worker's other planes landed too
    assert METRICS.merged()["write|cur.bin"]["count"] == 1
    assert any(ev for ev in sink_trc.events())
