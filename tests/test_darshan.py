"""Darshan-style monitoring: exact counter semantics."""
import numpy as np

from repro.core.darshan import MONITOR, open_file
from repro.core.original_io import write_dat, write_dmp


def test_counters_exact(tmpdir_path):
    MONITOR.reset()
    with open_file(tmpdir_path / "f.bin", "wb", rank=3) as f:
        f.write(b"x" * 100)
        f.write(b"y" * 50)
        f.seek(0)
        f.fsync()
    rep = MONITOR.report()
    tot = rep["total"]
    assert tot["POSIX_OPENS"] == 1
    assert tot["POSIX_WRITES"] == 2
    assert tot["POSIX_BYTES_WRITTEN"] == 150
    assert tot["POSIX_SEEKS"] == 1
    assert tot["POSIX_FSYNCS"] == 1
    assert rep["n_ranks"] == 1
    assert rep["avg_per_process"]["F_META_TIME"] > 0


def test_per_rank_attribution(tmpdir_path):
    MONITOR.reset()
    for r in range(4):
        with open_file(tmpdir_path / f"r{r}.bin", "wb", rank=r) as f:
            f.write(bytes(10 * (r + 1)))
    rep = MONITOR.report()
    assert rep["n_ranks"] == 4
    assert rep["avg_per_process"]["POSIX_BYTES_WRITTEN"] == 25.0


def test_original_io_metadata_dominance(tmpdir_path):
    """The paper's Fig 5 pathology: file-per-rank tiny text writes spend
    comparable-or-more time in metadata than in data writes per byte."""
    MONITOR.reset()
    arr = np.arange(64, dtype=np.float32)
    for r in range(16):
        write_dat(tmpdir_path, r, 0, {"ne": arr})
        write_dmp(tmpdir_path, r, 0, {"x": arr})
    rep = MONITOR.report()
    assert rep["total"]["POSIX_OPENS"] == 32           # one per file
    assert MONITOR.total_files_written() == 32          # O(ranks) files
    cost = MONITOR.cost_per_process()
    assert cost["meta_s"] > 0 and cost["write_s"] > 0


def test_access_size_histogram(tmpdir_path):
    MONITOR.reset()
    with open_file(tmpdir_path / "h.bin", "wb") as f:
        f.write(b"a" * 50)            # 0-100 bin
        f.write(b"b" * 5000)          # 1024-10240 bin
    hist = MONITOR.report()["access_size_histogram"]
    assert hist.get("0-100") == 1
    assert hist.get("1024-10240") == 1
