"""Codec unit + property tests (blosc-style shuffle+LZ, bzip2, zlib, none)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import compression as C

CODECS = ["none", "blosc", "bzip2", "zlib"]


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32, np.uint8])
def test_array_roundtrip(codec, dtype):
    rng = np.random.default_rng(0)
    arr = (rng.normal(size=(257, 33)) * 100).astype(dtype)
    buf = C.array_payload(arr, codec)
    back = C.payload_to_array(buf, dtype, arr.shape)
    np.testing.assert_array_equal(back, arr)


@pytest.mark.parametrize("codec", CODECS)
def test_multi_block_roundtrip(codec):
    rng = np.random.default_rng(1)
    arr = rng.normal(size=(300_000,)).astype(np.float32)
    buf = C.array_payload(arr, codec, block=64 * 1024)
    back = C.payload_to_array(buf, np.float32, arr.shape)
    np.testing.assert_array_equal(back, arr)


def test_shuffle_improves_float_compression():
    """The Blosc thesis: byte shuffle makes smooth floats compress better."""
    import zlib
    x = (np.linspace(0, 1, 100_000).astype(np.float32) +
         np.random.default_rng(0).normal(scale=1e-4, size=100_000)
         .astype(np.float32))
    raw = x.tobytes()
    plain = len(zlib.compress(raw, 1))
    shuf = len(zlib.compress(C.byte_shuffle(raw, 4), 1))
    assert shuf < plain * 0.9, (shuf, plain)


def test_incompressible_stored_raw():
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    buf = C.compress(data, "bzip2")
    assert len(buf) <= len(data) + 2 * C.HEADER.size
    assert C.decompress(buf) == data


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=5000),
       codec=st.sampled_from(CODECS),
       itemsize=st.sampled_from([1, 2, 4, 8]),
       block=st.integers(min_value=16, max_value=2048))
def test_property_roundtrip(data, codec, itemsize, block):
    assert C.decompress(C.compress(data, codec, itemsize, block)) == data


@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=0, max_value=600),
       itemsize=st.sampled_from([2, 4, 8]))
def test_property_shuffle_inverse(n, itemsize):
    rng = np.random.default_rng(n)
    buf = rng.integers(0, 256, n * itemsize, dtype=np.uint8).tobytes()
    assert C.byte_unshuffle(C.byte_shuffle(buf, itemsize), itemsize) == buf


# ---------------------------------------------------------- corrupt payloads
# These MUST hold under `python -O` too (asserts are stripped there) — the
# decode path validates with real CorruptPayloadError raises, and the tier-1
# CI job re-runs this file with PYTHONOPTIMIZE=1.

def test_corrupt_bad_magic_raises():
    buf = bytearray(C.compress(b"hello world" * 10, "zlib"))
    buf[:4] = b"XXXX"
    with pytest.raises(C.CorruptPayloadError, match="magic"):
        C.decompress(bytes(buf))


def test_corrupt_truncated_header_raises():
    buf = C.compress(b"hello", "none")
    with pytest.raises(C.CorruptPayloadError, match="truncated"):
        C.decompress(buf[:C.HEADER.size - 2])


def test_corrupt_truncated_payload_raises():
    buf = C.compress(b"hello world" * 50, "zlib")
    with pytest.raises(C.CorruptPayloadError, match="truncated"):
        C.decompress(buf[:len(buf) - 3])


def test_corrupt_stream_raises_not_codec_error():
    """A flipped compressed byte must surface as CorruptPayloadError, not
    leak zlib.error / OSError from the underlying codec."""
    data = b"abcdefgh" * 200
    for codec in ("zlib", "bzip2"):
        buf = bytearray(C.compress(data, codec))
        for i in range(C.HEADER.size, len(buf)):
            buf[i] ^= 0xFF
        with pytest.raises(C.CorruptPayloadError):
            C.decompress(bytes(buf))


def test_corrupt_unknown_codec_id_raises():
    buf = bytearray(C.compress(b"hello", "none"))
    buf[4] = 0x7F                              # codec id byte
    with pytest.raises(C.CorruptPayloadError, match="codec"):
        C.decompress(bytes(buf))


def test_corrupt_payload_shape_mismatch_raises():
    arr = np.arange(64, dtype=np.float32)
    buf = C.array_payload(arr, "zlib")
    with pytest.raises(C.CorruptPayloadError):
        C.payload_to_array(buf, np.float32, (65,))


def test_corruption_detected_under_python_O():
    """Regression: the old `assert magic == MAGIC` vanished under -O and a
    rotted payload decoded into garbage. Run the decode path in a real
    `python -O` subprocess and require the exception to survive."""
    import os
    import pathlib
    import subprocess
    import sys
    code = (
        "import sys\n"
        # an `assert` would be stripped by the very flag under test
        "if not sys.flags.optimize:\n"
        "    raise SystemExit('optimize flag is off')\n"
        "from repro.core import compression as C\n"
        "buf = bytearray(C.compress(b'payload bytes' * 9, 'zlib'))\n"
        "buf[:4] = b'ROTN'\n"
        "try:\n"
        "    C.decompress(bytes(buf))\n"
        "except C.CorruptPayloadError:\n"
        "    print('CAUGHT')\n"
    )
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src), PYTHONOPTIMIZE="1")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "CAUGHT"
