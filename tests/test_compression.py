"""Codec unit + property tests (blosc-style shuffle+LZ, bzip2, zlib, none)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import compression as C

CODECS = ["none", "blosc", "bzip2", "zlib"]


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32, np.uint8])
def test_array_roundtrip(codec, dtype):
    rng = np.random.default_rng(0)
    arr = (rng.normal(size=(257, 33)) * 100).astype(dtype)
    buf = C.array_payload(arr, codec)
    back = C.payload_to_array(buf, dtype, arr.shape)
    np.testing.assert_array_equal(back, arr)


@pytest.mark.parametrize("codec", CODECS)
def test_multi_block_roundtrip(codec):
    rng = np.random.default_rng(1)
    arr = rng.normal(size=(300_000,)).astype(np.float32)
    buf = C.array_payload(arr, codec, block=64 * 1024)
    back = C.payload_to_array(buf, np.float32, arr.shape)
    np.testing.assert_array_equal(back, arr)


def test_shuffle_improves_float_compression():
    """The Blosc thesis: byte shuffle makes smooth floats compress better."""
    import zlib
    x = (np.linspace(0, 1, 100_000).astype(np.float32) +
         np.random.default_rng(0).normal(scale=1e-4, size=100_000)
         .astype(np.float32))
    raw = x.tobytes()
    plain = len(zlib.compress(raw, 1))
    shuf = len(zlib.compress(C.byte_shuffle(raw, 4), 1))
    assert shuf < plain * 0.9, (shuf, plain)


def test_incompressible_stored_raw():
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    buf = C.compress(data, "bzip2")
    assert len(buf) <= len(data) + 2 * C.HEADER.size
    assert C.decompress(buf) == data


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=5000),
       codec=st.sampled_from(CODECS),
       itemsize=st.sampled_from([1, 2, 4, 8]),
       block=st.integers(min_value=16, max_value=2048))
def test_property_roundtrip(data, codec, itemsize, block):
    assert C.decompress(C.compress(data, codec, itemsize, block)) == data


@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=0, max_value=600),
       itemsize=st.sampled_from([2, 4, 8]))
def test_property_shuffle_inverse(n, itemsize):
    rng = np.random.default_rng(n)
    buf = rng.integers(0, 256, n * itemsize, dtype=np.uint8).tobytes()
    assert C.byte_unshuffle(C.byte_shuffle(buf, itemsize), itemsize) == buf


# ---------------------------------------------------------- corrupt payloads
# These MUST hold under `python -O` too (asserts are stripped there) — the
# decode path validates with real CorruptPayloadError raises, and the tier-1
# CI job re-runs this file with PYTHONOPTIMIZE=1.

def test_corrupt_bad_magic_raises():
    buf = bytearray(C.compress(b"hello world" * 10, "zlib"))
    buf[:4] = b"XXXX"
    with pytest.raises(C.CorruptPayloadError, match="magic"):
        C.decompress(bytes(buf))


def test_corrupt_truncated_header_raises():
    buf = C.compress(b"hello", "none")
    with pytest.raises(C.CorruptPayloadError, match="truncated"):
        C.decompress(buf[:C.HEADER.size - 2])


def test_corrupt_truncated_payload_raises():
    buf = C.compress(b"hello world" * 50, "zlib")
    with pytest.raises(C.CorruptPayloadError, match="truncated"):
        C.decompress(buf[:len(buf) - 3])


def test_corrupt_stream_raises_not_codec_error():
    """A flipped compressed byte must surface as CorruptPayloadError, not
    leak zlib.error / OSError from the underlying codec."""
    data = b"abcdefgh" * 200
    for codec in ("zlib", "bzip2"):
        buf = bytearray(C.compress(data, codec))
        for i in range(C.HEADER.size, len(buf)):
            buf[i] ^= 0xFF
        with pytest.raises(C.CorruptPayloadError):
            C.decompress(bytes(buf))


def test_corrupt_unknown_codec_id_raises():
    buf = bytearray(C.compress(b"hello", "none"))
    buf[4] = 0x7F                              # codec id byte
    with pytest.raises(C.CorruptPayloadError, match="codec"):
        C.decompress(bytes(buf))


def test_corrupt_payload_shape_mismatch_raises():
    arr = np.arange(64, dtype=np.float32)
    buf = C.array_payload(arr, "zlib")
    with pytest.raises(C.CorruptPayloadError):
        C.payload_to_array(buf, np.float32, (65,))


def test_corruption_detected_under_python_O():
    """Regression: the old `assert magic == MAGIC` vanished under -O and a
    rotted payload decoded into garbage. Run the decode path in a real
    `python -O` subprocess and require the exception to survive."""
    import os
    import pathlib
    import subprocess
    import sys
    code = (
        "import sys\n"
        # an `assert` would be stripped by the very flag under test
        "if not sys.flags.optimize:\n"
        "    raise SystemExit('optimize flag is off')\n"
        "from repro.core import compression as C\n"
        "buf = bytearray(C.compress(b'payload bytes' * 9, 'zlib'))\n"
        "buf[:4] = b'ROTN'\n"
        "try:\n"
        "    C.decompress(bytes(buf))\n"
        "except C.CorruptPayloadError:\n"
        "    print('CAUGHT')\n"
    )
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src), PYTHONOPTIMIZE="1")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "CAUGHT"


# ------------------------------------------------------------- lossy codec

@pytest.mark.parametrize("dtype", [np.float16, np.float32, np.float64])
@pytest.mark.parametrize("spec,bound,rel", [
    ("lossy:1e-3", 1e-3, False),
    ("lossy:rel:1e-3", 1e-3, True),
])
def test_lossy_bound_holds_in_stored_dtype(dtype, spec, bound, rel):
    rng = np.random.default_rng(3)
    arr = (rng.normal(size=20_000) * 5).astype(dtype)
    buf = C.array_payload(arr, spec, block=32 * 1024)
    back = C.payload_to_array(buf, dtype, arr.shape)
    eps = bound * np.max(np.abs(arr.astype(np.float64))) if rel else bound
    err = np.max(np.abs(back.astype(np.float64) - arr.astype(np.float64)))
    assert err <= eps, (err, eps)
    if dtype is not np.float16:
        # f16: a bound under the ulp floor legitimately falls back to a
        # raw store (err == 0); wider floats must actually compress
        assert len(buf) < arr.nbytes


def test_lossy_beats_lossless_on_noise():
    """The point of the lossy codec: random floats barely compress
    losslessly but quantize-to-bound compresses well."""
    rng = np.random.default_rng(4)
    arr = rng.normal(size=100_000).astype(np.float32)
    lossless = C.array_payload(arr, "blosc")
    lossy = C.array_payload(arr, "lossy:1e-4")
    assert len(lossy) < 0.8 * len(lossless), (len(lossy), len(lossless))


def test_lossy_nonfinite_falls_back_lossless():
    arr = np.array([1.0, np.nan, np.inf, -np.inf, 2.5], dtype=np.float32)
    buf = C.array_payload(arr, "lossy:1e-3")
    back = C.payload_to_array(buf, np.float32, arr.shape)
    np.testing.assert_array_equal(back, arr)   # bitwise: fallback is lossless


def test_lossy_integer_dtype_falls_back_lossless():
    arr = np.arange(1000, dtype=np.int32)
    buf = C.array_payload(arr, "lossy:1e-3")
    np.testing.assert_array_equal(
        C.payload_to_array(buf, np.int32, arr.shape), arr)


def test_lossy_all_zero_rel_bound_falls_back():
    arr = np.zeros(1000, dtype=np.float32)
    buf = C.array_payload(arr, "lossy:rel:1e-3")
    np.testing.assert_array_equal(
        C.payload_to_array(buf, np.float32, arr.shape), arr)


@pytest.mark.parametrize("spec", ["lossy", "lossy:", "lossy:0", "lossy:-1",
                                  "lossy:nan", "lossy:rel:", "lossy:rel:0",
                                  "bogus"])
def test_bad_codec_specs_raise(spec):
    with pytest.raises(ValueError):
        C.parse_codec(spec)


def test_corrupt_lossy_subheader_raises():
    arr = np.random.default_rng(5).normal(size=5000).astype(np.float32)
    buf = C.array_payload(arr, "lossy:1e-3")
    hdr = C.HEADER.unpack_from(buf, 0)
    assert hdr[1] == C.CODEC_IDS["lossy"]
    # cut the block so even the lossy sub-header is gone
    cut = buf[:C.HEADER.size + C.LOSSY_SUB.size - 1]
    with pytest.raises(C.CorruptPayloadError):
        C.decompress(cut)


def test_corrupt_lossy_bad_qsize_raises():
    arr = np.random.default_rng(6).normal(size=5000).astype(np.float32)
    buf = bytearray(C.array_payload(arr, "lossy:1e-3"))
    # LOSSY_SUB = <dB: qsize is the 9th byte after the block header
    buf[C.HEADER.size + 8] = 3                 # not a valid int width
    with pytest.raises(C.CorruptPayloadError):
        C.decompress(bytes(buf))


# ----------------------------------------------------- pre-shuffled blocks

def test_preshuffled_payload_bit_identical_to_host():
    """The device contract: a pre-shuffled encode produces the SAME bytes
    as the host pipeline, so readers cannot tell the paths apart."""
    rng = np.random.default_rng(7)
    arr = np.cumsum(rng.normal(scale=1e-3, size=200_000)).astype(np.float32)
    host = C.array_payload(arr, "blosc", block=64 * 1024)
    shuffled = np.frombuffer(
        b"".join(C.byte_shuffle(arr.tobytes()[i:i + 64 * 1024], 4)
                 for i in range(0, arr.nbytes, 64 * 1024)),
        dtype=np.uint8).copy()
    chunk = C.PreshuffledChunk(shuffled, np.float32, arr.shape, 64 * 1024)
    assert C.array_payload_preshuffled(chunk, "blosc") == host


def test_preshuffled_raw_store_decodes():
    """Incompressible pre-shuffled bytes are raw-stored WITH the flag —
    decode must unshuffle them."""
    rng = np.random.default_rng(8)
    arr = rng.integers(0, 2**32, 4096, dtype=np.uint32)  # noise: raw store
    shuffled = np.frombuffer(C.byte_shuffle(arr.tobytes(), 4),
                             dtype=np.uint8).copy()
    chunk = C.PreshuffledChunk(shuffled, np.uint32, arr.shape, C.DEFAULT_BLOCK)
    buf = C.array_payload_preshuffled(chunk, "blosc")
    hdr = C.HEADER.unpack_from(buf, 0)
    assert hdr[1] == C.CODEC_IDS["none"] and hdr[3] & C.FLAG_PRESHUFFLED
    np.testing.assert_array_equal(
        C.payload_to_array(buf, np.uint32, arr.shape), arr)


def test_corrupt_truncated_preshuffled_block_raises():
    rng = np.random.default_rng(9)
    arr = rng.integers(0, 2**32, 4096, dtype=np.uint32)
    shuffled = np.frombuffer(C.byte_shuffle(arr.tobytes(), 4),
                             dtype=np.uint8).copy()
    chunk = C.PreshuffledChunk(shuffled, np.uint32, arr.shape, C.DEFAULT_BLOCK)
    buf = C.array_payload_preshuffled(chunk, "blosc")
    with pytest.raises(C.CorruptPayloadError):
        C.decompress(buf[:len(buf) - 7])


def test_preshuffled_rejects_non_device_codec():
    chunk = C.PreshuffledChunk(np.zeros(16, np.uint8), np.float32, (4,), 1024)
    with pytest.raises(ValueError):
        C.array_payload_preshuffled(chunk, "bzip2")


def test_old_format_flags_zero_reads_bit_identical():
    """Forward compat: payloads written before the flags field existed
    (flags == 0 everywhere) decode unchanged."""
    rng = np.random.default_rng(10)
    arr = rng.normal(size=50_000).astype(np.float64)
    buf = C.array_payload(arr, "blosc", block=64 * 1024)
    for off, _cid, _isz, flags, _raw, _comp in C.iter_block_headers(buf):
        assert flags == 0                      # host path writes no flags
    np.testing.assert_array_equal(
        C.payload_to_array(buf, np.float64, arr.shape), arr)


# ------------------------------------------------- decompress scaling path

def test_many_block_decompress_preallocates():
    """The O(n^2) fix: decompress pre-scans headers and writes into one
    preallocated buffer. Equality over many small blocks guards the path."""
    data = bytes(range(256)) * 2048            # 512 KiB
    buf = C.compress(data, "zlib", itemsize=1, block=1024)   # 512 blocks
    assert C.decompress(buf) == data


def test_payload_to_array_zero_copy_single_raw_block():
    arr = np.random.default_rng(11).integers(0, 255, 4096, dtype=np.uint8)
    buf = C.array_payload(arr, "none")
    back = C.payload_to_array(buf, np.uint8, arr.shape)
    np.testing.assert_array_equal(back, arr)
    assert back.base is not None               # a view, not a copy
    assert not back.flags.writeable            # of the (immutable) payload


# -------------------------------------------------------- device pipeline

def test_device_array_payload_matches_host():
    jnp = pytest.importorskip("jax.numpy")
    rng = np.random.default_rng(12)
    arr = np.cumsum(rng.normal(scale=1e-3, size=300_000)).astype(np.float32)
    host = C.array_payload(arr, "blosc", block=256 * 1024)
    dev, stats = C.device_array_payload(jnp.asarray(arr), "blosc",
                                        block=256 * 1024)
    assert dev == host
    assert stats.device_bytes == arr.nbytes


def test_device_precondition_roundtrip_and_stats():
    jnp = pytest.importorskip("jax.numpy")
    rng = np.random.default_rng(13)
    arr = rng.normal(size=(100, 700)).astype(np.float32)
    chunk = C.device_precondition(jnp.asarray(arr), block=64 * 1024)
    assert chunk.shape == arr.shape and chunk.dtype == np.float32
    assert chunk.vmin == float(np.min(arr))
    assert chunk.vmax == float(np.max(arr))
    buf = C.array_payload_preshuffled(chunk, "blosc")
    assert buf == C.array_payload(arr, "blosc", block=64 * 1024)
