"""Codec unit + property tests (blosc-style shuffle+LZ, bzip2, zlib, none)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import compression as C

CODECS = ["none", "blosc", "bzip2", "zlib"]


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32, np.uint8])
def test_array_roundtrip(codec, dtype):
    rng = np.random.default_rng(0)
    arr = (rng.normal(size=(257, 33)) * 100).astype(dtype)
    buf = C.array_payload(arr, codec)
    back = C.payload_to_array(buf, dtype, arr.shape)
    np.testing.assert_array_equal(back, arr)


@pytest.mark.parametrize("codec", CODECS)
def test_multi_block_roundtrip(codec):
    rng = np.random.default_rng(1)
    arr = rng.normal(size=(300_000,)).astype(np.float32)
    buf = C.array_payload(arr, codec, block=64 * 1024)
    back = C.payload_to_array(buf, np.float32, arr.shape)
    np.testing.assert_array_equal(back, arr)


def test_shuffle_improves_float_compression():
    """The Blosc thesis: byte shuffle makes smooth floats compress better."""
    import zlib
    x = (np.linspace(0, 1, 100_000).astype(np.float32) +
         np.random.default_rng(0).normal(scale=1e-4, size=100_000)
         .astype(np.float32))
    raw = x.tobytes()
    plain = len(zlib.compress(raw, 1))
    shuf = len(zlib.compress(C.byte_shuffle(raw, 4), 1))
    assert shuf < plain * 0.9, (shuf, plain)


def test_incompressible_stored_raw():
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    buf = C.compress(data, "bzip2")
    assert len(buf) <= len(data) + 2 * C.HEADER.size
    assert C.decompress(buf) == data


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=5000),
       codec=st.sampled_from(CODECS),
       itemsize=st.sampled_from([1, 2, 4, 8]),
       block=st.integers(min_value=16, max_value=2048))
def test_property_roundtrip(data, codec, itemsize, block):
    assert C.decompress(C.compress(data, codec, itemsize, block)) == data


@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=0, max_value=600),
       itemsize=st.sampled_from([2, 4, 8]))
def test_property_shuffle_inverse(n, itemsize):
    rng = np.random.default_rng(n)
    buf = rng.integers(0, 256, n * itemsize, dtype=np.uint8).tobytes()
    assert C.byte_unshuffle(C.byte_shuffle(buf, itemsize), itemsize) == buf
