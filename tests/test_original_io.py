"""BIT1 Original-I/O baseline: roundtrips and the O(ranks) file pathology."""
import numpy as np

from repro.core.original_io import read_dmp, write_dat, write_dmp


def test_dmp_roundtrip(tmpdir_path):
    rng = np.random.default_rng(0)
    arrays = {"x": rng.normal(size=(100,)).astype(np.float32),
              "v": rng.normal(size=(100, 3)).astype(np.float64),
              "ids": np.arange(7, dtype=np.int32)}
    p = write_dmp(tmpdir_path, 2, 50, arrays)
    back = read_dmp(p)
    for k, v in arrays.items():
        np.testing.assert_array_equal(back[k], v)


def test_file_count_scales_with_ranks(tmpdir_path):
    """Paper Table II: total files O(ranks); avg size O(1/ranks)."""
    arr = np.arange(4096, dtype=np.float32)
    for n_ranks in (4, 8):
        d = tmpdir_path / f"r{n_ranks}"
        for r in range(n_ranks):
            write_dat(d, r, 0, {"ne": arr[:4096 // n_ranks]})
            write_dmp(d, r, 0, {"x": arr[:4096 // n_ranks]})
        files = list(d.iterdir())
        assert len(files) == 2 * n_ranks
    s4 = sum(f.stat().st_size for f in (tmpdir_path / "r4").iterdir()) / 8
    s8 = sum(f.stat().st_size for f in (tmpdir_path / "r8").iterdir()) / 16
    assert s8 < s4          # avg file size shrinks with rank count
