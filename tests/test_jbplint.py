"""jbplint static-analyzer tests: one good/bad fixture pair per rule,
path-scoping (checkers bind to directory components, so fixtures written
under a tmp `core/` dir behave exactly like the real tree), suppression
comments (both placements), content-keyed baseline semantics, CLI exit
codes, and the tier-1 gate: the repo's own tree must lint clean."""
import json
import pathlib
import textwrap

from repro.analysis import analyze_paths, baseline_doc
from repro.analysis.framework import PARSE_RULE
from repro.tools.jbplint import main as jbplint_main

REPO = pathlib.Path(__file__).resolve().parents[1]


def _src(tmp, rel, body):
    p = tmp / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))
    return p


def _rules(res):
    return [f.rule for f in res.findings]


# ------------------------------------------------------------------ JBP001
def test_jbp001_flags_bare_assert(tmpdir_path):
    bad = _src(tmpdir_path, "core/bad.py", """\
        def check(n):
            assert n > 0, "n must be positive"
            return n
        """)
    res = analyze_paths([bad])
    assert _rules(res) == ["JBP001"]
    assert res.findings[0].symbol == "check"


def test_jbp001_good_raise_is_clean(tmpdir_path):
    good = _src(tmpdir_path, "core/good.py", """\
        def check(n):
            if n <= 0:
                raise ValueError(f"n must be positive, got {n}")
            return n
        """)
    assert analyze_paths([good]).clean


def test_jbp001_kernel_code_is_exempt(tmpdir_path):
    kern = _src(tmpdir_path, "kernels/ref.py", """\
        def ref(n):
            assert n > 0
            return n
        """)
    assert analyze_paths([kern]).clean


# ------------------------------------------------------------------ JBP002
def test_jbp002_flags_raw_io_on_data_plane(tmpdir_path):
    bad = _src(tmpdir_path, "core/bad_io.py", """\
        import os
        import pathlib

        def load(p):
            raw = open(p).read()
            fd = os.open(p, 0)
            txt = pathlib.Path(p).read_text()
            return raw, fd, txt
        """)
    res = analyze_paths([bad])
    assert _rules(res) == ["JBP002"] * 3


def test_jbp002_open_file_is_clean(tmpdir_path):
    good = _src(tmpdir_path, "core/good_io.py", """\
        from repro.core.darshan import open_file

        def load(p):
            with open_file(p, "rb") as f:
                return f.read()
        """)
    assert analyze_paths([good]).clean


def test_jbp002_scoped_to_io_plane_dirs(tmpdir_path):
    # same raw open() OUTSIDE core/serve/tools — not a data-plane file
    off = _src(tmpdir_path, "insitu/elsewhere.py", """\
        def load(p):
            return open(p).read()
        """)
    assert analyze_paths([off]).clean


# ------------------------------------------------------------------ JBP003
def test_jbp003_flags_counter_literals(tmpdir_path):
    bad = _src(tmpdir_path, "core/bad_ctr.py", """\
        def bump(mon, path):
            mon.record(0, path, "POSIX_WRITES", 1.0)
            mon.record(0, path, counter="SERVICE_CACHE_HIT")
        """)
    res = analyze_paths([bad])
    assert _rules(res) == ["JBP003"] * 2


def test_jbp003_registry_constants_and_dxt_keys_clean(tmpdir_path):
    good = _src(tmpdir_path, "core/good_ctr.py", """\
        from repro.core.darshan import CTR

        def bump(mon, tracer, path):
            mon.record(0, path, CTR.POSIX_WRITES, 1.0)
            tracer.record(0, path, "write", 0, 4, 0.0, 0.1)
        """)
    assert analyze_paths([good]).clean


# ------------------------------------------------------------------ JBP004
def test_jbp004_flags_blocking_under_lock(tmpdir_path):
    bad = _src(tmpdir_path, "serve/bad_lock.py", """\
        def pump(self, sock):
            with self._lock:
                return sock.recv(4096)

        def drain(self, task_q):
            with self._lock:
                return task_q.get()
        """)
    res = analyze_paths([bad])
    assert _rules(res) == ["JBP004"] * 2


def test_jbp004_timeouts_conditions_and_nested_defs_exempt(tmpdir_path):
    good = _src(tmpdir_path, "serve/good_lock.py", """\
        def drain(self, task_q):
            with self._lock:
                return task_q.get(timeout=1.0)

        def wait(self):
            with self._cond_lock:
                self._cond_lock.wait()     # Condition releases the lock

        def plan(self):
            with self._lock:
                def later(sock):           # deferred: runs OUTSIDE the lock
                    return sock.recv(4)
                self.cb = later
        """)
    assert analyze_paths([good]).clean


# ------------------------------------------------------------------ JBP005
def test_jbp005_flags_spawn_unsafe_targets(tmpdir_path):
    bad = _src(tmpdir_path, "core/bad_spawn.py", """\
        import multiprocessing as mp

        def launch(plane, task_q):
            def local():
                return 1
            p = mp.Process(target=lambda: 1)
            spawn_io_workers(plane, local)
            task_q.put(("job", lambda: 2))
            return p
        """)
    res = analyze_paths([bad])
    assert _rules(res) == ["JBP005"] * 3


def test_jbp005_module_level_target_clean(tmpdir_path):
    good = _src(tmpdir_path, "core/good_spawn.py", """\
        import multiprocessing as mp

        def worker_main(q):
            q.put("done")

        def launch(q):
            return mp.Process(target=worker_main, args=(q,))
        """)
    assert analyze_paths([good]).clean


# ------------------------------------------------------------------ JBP006
def test_jbp006_flags_wall_clock_durations(tmpdir_path):
    bad = _src(tmpdir_path, "core/bad_clock.py", """\
        import time

        def slow_op(t0, deadline):
            dt = time.time() - t0
            if time.time() > deadline:
                raise TimeoutError(f"{dt:.1f}s")
            return dt
        """)
    res = analyze_paths([bad])
    assert _rules(res) == ["JBP006"] * 2


def test_jbp006_perf_counter_and_epoch_stamps_clean(tmpdir_path):
    good = _src(tmpdir_path, "core/good_clock.py", """\
        import time

        def timed_op(run):
            t_wall = time.time()          # epoch STAMP: legal
            t0 = time.perf_counter()
            run()
            dt = time.perf_counter() - t0
            return {"t": t_wall, "dt": dt}
        """)
    assert analyze_paths([good]).clean


def test_jbp006_scoped_to_data_plane_dirs(tmpdir_path):
    off_plane = _src(tmpdir_path, "analysis/clock.py", """\
        import time

        def elapsed(t0):
            return time.time() - t0
        """)
    assert analyze_paths([off_plane]).clean


# ----------------------------------------------------------- suppressions
def test_suppression_trailing_and_preceding_comment(tmpdir_path):
    f = _src(tmpdir_path, "core/supp.py", """\
        def a(p):
            return open(p).read()   # jbplint: disable=JBP002

        def b(p):
            # sidecar of the tracer itself, see DESIGN.md
            # jbplint: disable=JBP002
            return open(p).read()

        def c(p):
            return open(p).read()   # jbplint: disable=JBP001
        """)
    res = analyze_paths([f])
    # a+b suppressed; c's directive names the WRONG rule, so it still fires
    assert _rules(res) == ["JBP002"]
    assert res.findings[0].symbol == "c"
    assert res.suppressed == 2


# --------------------------------------------------------------- baseline
def test_baseline_parks_findings_and_survives_line_drift(tmpdir_path):
    body = """\
        def check(n):
            assert n > 0, "positive"
            return n
        """
    f = _src(tmpdir_path, "core/base.py", body)
    first = analyze_paths([f])
    assert len(first.findings) == 1
    keys = frozenset(e["key"]
                     for e in baseline_doc(first.findings)["findings"])

    # unrelated edit ABOVE the finding: line number moves, key must not
    _src(tmpdir_path, "core/base.py", "# a new leading comment\n"
         + textwrap.dedent(body))
    drifted = analyze_paths([f], baseline_keys=keys)
    assert drifted.clean
    assert drifted.baselined == 1

    # a NEW finding in the same file is not covered by the old baseline
    _src(tmpdir_path, "core/base.py", textwrap.dedent(body)
         + "\ndef other(m):\n    assert m, 'm'\n")
    fresh = analyze_paths([f], baseline_keys=keys)
    assert len(fresh.findings) == 1
    assert fresh.findings[0].symbol == "other"
    assert fresh.baselined == 1


def test_syntax_error_is_a_gating_finding(tmpdir_path):
    f = _src(tmpdir_path, "core/broken.py", "def oops(:\n")
    res = analyze_paths([f])
    assert _rules(res) == [PARSE_RULE]


# -------------------------------------------------------------------- CLI
def test_cli_exit_codes(tmpdir_path, capsys):
    bad = _src(tmpdir_path, "core/cli_bad.py", "assert True, 'x'\n")
    good = _src(tmpdir_path, "core/cli_good.py", "X = 1\n")

    assert jbplint_main([]) == 2                       # no paths
    assert jbplint_main(["--rules", "JBP999", str(good)]) == 2
    assert jbplint_main([str(tmpdir_path / "nope.py")]) == 2
    assert jbplint_main([str(good)]) == 0
    assert jbplint_main([str(bad)]) == 1
    assert jbplint_main(["--rules", "JBP002", str(bad)]) == 0  # rule select
    capsys.readouterr()

    assert jbplint_main(["--json", str(bad)]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["tool"] == "jbplint" and doc["clean"] is False
    assert doc["findings"][0]["rule"] == "JBP001"

    base = tmpdir_path / "base.json"
    assert jbplint_main(["--write-baseline", str(base), str(bad)]) == 0
    assert jbplint_main(["--baseline", str(base), str(bad)]) == 0
    assert jbplint_main(["--baseline", str(base), str(bad), str(good)]) == 0
    assert jbplint_main(["--list-rules"]) == 0


# ----------------------------------------------------------- tier-1 gate
def test_jbplint_clean():
    """The repo's own tree lints clean — the zero-finding invariant every
    PR must keep (CI runs the same command and gates on it)."""
    assert jbplint_main([str(REPO / "src" / "repro")]) == 0
