"""Attention: flash-vjp vs O(S^2) reference, GQA expansion, decode parity."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models.attention import (attention_block, decode_attention,
                                    flash_attention_jnp, init_attention,
                                    reference_attention)


def _cfg(H=4, kv=2, hd=16, qk_norm=False, bias=False):
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                       n_heads=H, n_kv_heads=kv, d_ff=64, vocab_size=64,
                       head_dim=hd, qk_norm=qk_norm, qkv_bias=bias)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunks", [(32, 32), (64, 16), (128, 128)])
def test_flash_matches_reference(causal, chunks):
    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 128, 4, 32
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
               for i in range(3))
    got = flash_attention_jnp(q, k, v, causal=causal, q_chunk=chunks[0],
                              kv_chunk=chunks[1])
    ref = reference_attention(q, k, v, causal=causal)
    # the production flash keeps probabilities in bf16 for the MXU AV matmul
    assert jnp.max(jnp.abs(got - ref)) < 2e-2


def test_flash_grads_match_reference():
    key = jax.random.PRNGKey(1)
    B, S, H, D = 2, 64, 2, 16
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
               for i in range(3))

    def lf(f):
        return lambda *a: jnp.sum(f(*a).astype(jnp.float32) ** 2)

    g1 = jax.grad(lf(lambda q, k, v: flash_attention_jnp(
        q, k, v, q_chunk=16, kv_chunk=16)), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lf(lambda q, k, v: reference_attention(q, k, v)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        denom = jnp.maximum(jnp.max(jnp.abs(b)), 1e-6)
        assert jnp.max(jnp.abs(a - b)) / denom < 2e-2


@pytest.mark.parametrize("H,kv", [(4, 4), (4, 2), (6, 2), (15, 5)])
def test_gqa_block_matches_reference(H, kv):
    """attention_block (expanded-KV flash) == grouped O(S^2) reference."""
    cfg = _cfg(H=H, kv=kv)
    key = jax.random.PRNGKey(2)
    p = init_attention(key, cfg)
    B, S = 2, 64
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    y, (k, v) = attention_block(p, x.astype(jnp.bfloat16), cfg=cfg,
                                positions=pos, q_chunk=32, kv_chunk=32)
    # reference path: grouped attention on the SAME projections
    from repro.models.attention import _head_proj, _out_proj, _project_qkv
    q2, k2, v2 = _project_qkv(p, x.astype(jnp.bfloat16),
                              x.astype(jnp.bfloat16), cfg, pos, pos, rope=True)
    o_ref = reference_attention(q2, k2, v2, causal=True)
    y_ref = _out_proj(p["wo"], o_ref)
    denom = jnp.maximum(jnp.max(jnp.abs(y_ref.astype(jnp.float32))), 1e-6)
    assert jnp.max(jnp.abs((y - y_ref).astype(jnp.float32))) / denom < 3e-2
    assert k.shape == (B, S, kv, cfg.resolved_head_dim)


def test_decode_matches_full_forward():
    """Token-by-token decode logits == full-sequence attention outputs."""
    cfg = _cfg(H=4, kv=2)
    key = jax.random.PRNGKey(3)
    p = init_attention(key, cfg)
    B, S = 2, 16
    x = jax.random.normal(key, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    y_full, (k, v) = attention_block(p, x, cfg=cfg, positions=pos,
                                     q_chunk=16, kv_chunk=16)
    hd = cfg.resolved_head_dim
    ck = jnp.zeros((B, S, cfg.n_kv_heads, hd), jnp.bfloat16)
    cv = jnp.zeros_like(ck)
    outs = []
    for t in range(S):
        yt, ck, cv = decode_attention(p, x[:, t:t + 1], ck, cv,
                                      jnp.asarray(t, jnp.int32), cfg=cfg)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, axis=1)
    err = jnp.max(jnp.abs((y_full - y_dec).astype(jnp.float32)))
    assert err < 3e-2, err
