"""repro.tools maintenance subsystem: jbprepack re-aggregation parity
(property-based over W', codec, payload shapes), jbpfsck detection/repair
of torn and truncated series, and the shared tools-runner conventions."""
import json
import sys

import numpy as np
import pytest

sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
from _propcheck import given, settings, strategies as st  # noqa: E402

from repro.core.bp_engine import (IDX_SIZE, BpReader, BpWriter,  # noqa: E402
                                  EngineConfig)
from repro.tools import jbpfsck, jbpls, jbprepack  # noqa: E402
from repro.tools._runner import EXIT_ISSUES, EXIT_OK, EXIT_USAGE  # noqa: E402
from repro.tools.jbprepack import repack, verify_equivalent  # noqa: E402


def _write_series(path, *, n_ranks=8, aggregators=4, codec="none", steps=3,
                  seed=7, with_scalar=True):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=3)
    w = BpWriter(path, n_ranks, cfg)
    rng = np.random.default_rng(seed)
    for s in range(steps):
        w.begin_step(s)
        w.set_attribute(f"/data/{s}/time", float(s) * 0.5)
        g = rng.normal(size=(n_ranks * 8, 3)).astype(np.float32)
        for r in range(n_ranks):
            w.put("mesh/rho", g[r * 8:(r + 1) * 8], global_shape=g.shape,
                  offset=(r * 8, 0), rank=r)
        ints = (rng.integers(0, 1000, size=n_ranks * 4)
                .astype(np.int64))
        for r in range(n_ranks):
            w.put("particles/id", ints[r * 4:(r + 1) * 4],
                  global_shape=ints.shape, offset=(r * 4,), rank=r)
        if with_scalar:
            w.put("scalar/t", np.array([s], np.int64), global_shape=(1,),
                  offset=(0,), rank=0)
        w.end_step()
    w.close()


def _chunk_table(reader, step, name):
    """Comparable chunk-structure view: the repack contract preserves
    (rank, offset, extent, vmin, vmax) — NOT agg/foff/nbytes, which the
    new aggregation/codec legitimately changes."""
    return sorted((c.rank, c.offset, c.extent, c.vmin, c.vmax)
                  for c in reader.iter_chunks(step, name))


# ------------------------------------------------------------ repack parity
@settings(max_examples=8, deadline=None)
@given(w_dst=st.sampled_from([1, 2, 3, 6]),
       codec=st.sampled_from(["none", "blosc"]),
       parallel=st.sampled_from([0, 3]))
def test_repack_reaggregation_parity(w_dst, codec, parallel):
    """Property: repack W=4 -> W' preserves every variable bit-exactly —
    data (compressed chunks included), per-chunk min/max metadata, chunk
    (rank, offset, extent) structure and per-step attributes.

    (Manages its own temp dir: real-hypothesis health checks forbid
    function-scoped fixtures under @given.)"""
    import pathlib
    import shutil
    import tempfile
    root = pathlib.Path(tempfile.mkdtemp(prefix="repro-repack-"))
    try:
        src = root / "src.bp4"
        dst = root / "dst.bp4"
        _write_series(src, aggregators=4, codec="blosc")
        repack(src, dst, n_writers=w_dst, codec=codec, parallel=parallel)
        n = verify_equivalent(src, dst)
        assert n == 3 * 3                # 3 steps x 3 vars, all bit-equal
        with BpReader(src) as a, BpReader(dst) as b:
            assert a.valid_steps() == b.valid_steps()
            for s in a.valid_steps():
                assert a.attributes(s) == b.attributes(s)
                for name in a.var_names(s):
                    assert _chunk_table(a, s, name) == \
                        _chunk_table(b, s, name)
                    # min/max answered from metadata must agree too
                    assert a.var_minmax(s, name) == b.var_minmax(s, name)
            # the output really is W' subfiles (8 source ranks cover all)
            aggs = {c.agg for s in b.valid_steps()
                    for c in b.iter_chunks(s, "mesh/rho")}
            assert aggs == set(range(w_dst))
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_repack_recompress_changes_stored_not_read(tmpdir_path):
    # smooth (cumsum) floats — compressible, unlike the noise series
    w = BpWriter(tmpdir_path / "s.bp4", 4, EngineConfig(aggregators=2))
    rng = np.random.default_rng(3)
    g = np.cumsum(rng.normal(scale=1e-3, size=4 * 4096)
                  ).astype(np.float32)
    w.begin_step(0)
    for r in range(4):
        w.put("mesh/rho", g[r * 4096:(r + 1) * 4096],
              global_shape=g.shape, offset=(r * 4096,), rank=r)
    w.end_step()
    w.close()
    repack(tmpdir_path / "s.bp4", tmpdir_path / "z.bp4", n_writers=2,
           codec="blosc")
    verify_equivalent(tmpdir_path / "s.bp4", tmpdir_path / "z.bp4")
    with BpReader(tmpdir_path / "s.bp4") as a, \
            BpReader(tmpdir_path / "z.bp4") as b:
        raw_a, stored_a = a.var_nbytes(0, "mesh/rho")
        raw_b, stored_b = b.var_nbytes(0, "mesh/rho")
        assert raw_a == raw_b
        assert stored_b < stored_a       # smooth floats compress


def test_repack_drops_torn_steps(tmpdir_path):
    """Repack replays only committed steps — repacking a crashed series
    is also its repair."""
    _write_series(tmpdir_path / "s.bp4", steps=3)
    raw = (tmpdir_path / "s.bp4" / "md.idx").read_bytes()
    (tmpdir_path / "s.bp4" / "md.idx").write_bytes(raw[:2 * IDX_SIZE + 7])
    repack(tmpdir_path / "s.bp4", tmpdir_path / "r.bp4", n_writers=1)
    with BpReader(tmpdir_path / "r.bp4") as b:
        assert b.valid_steps() == [0, 1]


def test_repack_cli_verify_and_exit_codes(tmpdir_path, capsys):
    _write_series(tmpdir_path / "s.bp4", aggregators=2)
    rc = jbprepack.main([str(tmpdir_path / "s.bp4"),
                         str(tmpdir_path / "out.bp4"), "-w", "1",
                         "--parallel", "2", "--verify", "--io-report"])
    assert rc == EXIT_OK
    out = capsys.readouterr()
    assert "bit-identical" in out.out
    assert "POSIX_BYTES_READ" in out.err       # --io-report went to stderr
    # refusing to clobber without --force
    assert jbprepack.main([str(tmpdir_path / "s.bp4"),
                           str(tmpdir_path / "out.bp4"), "-w", "1"]) \
        == EXIT_USAGE
    assert jbprepack.main([str(tmpdir_path / "s.bp4"),
                           str(tmpdir_path / "out.bp4"), "-w", "2",
                           "--force"]) == EXIT_OK
    # not a series
    assert jbprepack.main([str(tmpdir_path / "nope"),
                           str(tmpdir_path / "x.bp4"), "-w", "1"]) \
        == EXIT_USAGE


def test_repack_striped_output_roundtrip(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", aggregators=2, steps=2)
    rc = jbprepack.main([str(tmpdir_path / "s.bp4"),
                         str(tmpdir_path / "st.bp4"), "-w", "2",
                         "--stripe", "2x256", "--verify"])
    assert rc == EXIT_OK
    assert sorted(p.name for p in
                  (tmpdir_path / "st.bp4").glob("ost*/data.*.obj"))


# ------------------------------------------------------------------- jbpfsck
def test_fsck_clean_series(tmpdir_path, capsys):
    _write_series(tmpdir_path / "s.bp4")
    assert jbpfsck.main([str(tmpdir_path / "s.bp4")]) == EXIT_OK
    assert "clean" in capsys.readouterr().out
    assert jbpfsck.main([str(tmpdir_path / "nope")]) == EXIT_USAGE


def test_fsck_torn_idx_tail_report_and_repair(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", steps=3)
    p = tmpdir_path / "s.bp4" / "md.idx"
    p.write_bytes(p.read_bytes()[:-13])          # crash during the seal
    report = jbpfsck.scan(tmpdir_path / "s.bp4")
    kinds = [i["kind"] for i in report["issues"]]
    assert "torn-idx-tail" in kinds
    assert report["committed_steps"] == [0, 1]
    assert jbpfsck.main([str(tmpdir_path / "s.bp4")]) == EXIT_ISSUES
    assert jbpfsck.main([str(tmpdir_path / "s.bp4"), "--repair"]) == EXIT_OK
    # repaired: reader and fsck agree on the resealed prefix
    assert jbpfsck.scan(tmpdir_path / "s.bp4")["issues"] == []
    with BpReader(tmpdir_path / "s.bp4") as r:
        assert r.valid_steps() == [0, 1]
        assert np.isfinite(r.read_var(1, "mesh/rho")).all()


def test_fsck_corrupt_md0_blob_truncates_to_prefix(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", steps=3)
    report = jbpfsck.scan(tmpdir_path / "s.bp4")
    # corrupt step 1's md.0 blob: steps 1 AND 2 fall off the consistent
    # prefix (reseal-to-last-consistent-step semantics)
    md = tmpdir_path / "s.bp4" / "md.0"
    raw = bytearray(md.read_bytes())
    off = report["_records"][1][1]
    raw[off + 5] ^= 0xFF
    md.write_bytes(bytes(raw))
    report = jbpfsck.scan(tmpdir_path / "s.bp4")
    assert [i["kind"] for i in report["issues"]] == ["torn-step"]
    assert report["committed_steps"] == [0, 2]
    assert report["consistent_prefix_steps"] == [0]
    jbpfsck.repair(tmpdir_path / "s.bp4", report)
    with BpReader(tmpdir_path / "s.bp4") as r:
        assert r.valid_steps() == [0]


def test_fsck_truncated_subfile_detected_and_repaired(tmpdir_path):
    """A subfile shorter than the chunk table's extents is metadata that
    validates but payload that is gone — fsck must catch it from stat
    alone and reseal to the consistent prefix."""
    _write_series(tmpdir_path / "s.bp4", steps=3, aggregators=2)
    import os
    data1 = tmpdir_path / "s.bp4" / "data.1"
    sizes = jbpfsck.scan(tmpdir_path / "s.bp4")["_max_end"]
    # keep step 0's extent, cut everything after
    per_step = sizes[1] // 3
    os.truncate(data1, per_step)
    report = jbpfsck.scan(tmpdir_path / "s.bp4")
    kinds = {i["kind"] for i in report["issues"]}
    assert kinds == {"orphaned-extent"}
    assert report["consistent_prefix_steps"] == [0]
    jbpfsck.repair(tmpdir_path / "s.bp4", report, trim=True)
    report2 = jbpfsck.scan(tmpdir_path / "s.bp4")
    assert report2["issues"] == []
    with BpReader(tmpdir_path / "s.bp4") as r:
        assert r.valid_steps() == [0]
        assert np.isfinite(r.read_var(0, "mesh/rho")).all()


def test_fsck_parallel_series_shards_and_orphan_prepare(tmpdir_path):
    """A coordinator crash between prepare and commit leaves sealed shard
    records with no md.idx commit — fsck reports the orphaned prepare as a
    NOTE (dead weight, not damage) and a torn shard tail as an ISSUE."""
    from repro.core.parallel_engine import ParallelBpWriter, shard_path
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 4, EngineConfig(),
                         n_writers=2)
    w.begin_step(0)
    w.put("v", np.arange(8, dtype=np.float32), global_shape=(8,),
          offset=(0,), rank=0)
    w.end_step()
    w._crash_after_prepare = True
    w.begin_step(1)
    w.put("v", np.full(8, 9, np.float32), global_shape=(8,), offset=(0,),
          rank=0)
    with pytest.raises(RuntimeError, match="simulated"):
        w.end_step()
    w._crash_after_prepare = False
    w.close()
    report = jbpfsck.scan(tmpdir_path / "p.bp4")
    assert report["issues"] == []        # orphaned prepare is NOT damage
    assert any(n["kind"] == "orphaned-prepare" and n["steps"] == [1]
               for n in report["notes"])
    # now tear a shard tail: that IS damage (crash mid-prepare)
    sp = shard_path(tmpdir_path / "p.bp4", 0)
    sp.write_bytes(sp.read_bytes()[:-3])
    report = jbpfsck.scan(tmpdir_path / "p.bp4")
    assert any(i["kind"] == "torn-shard-tail" for i in report["issues"])
    jbpfsck.repair(tmpdir_path / "p.bp4", report)
    assert jbpfsck.scan(tmpdir_path / "p.bp4")["issues"] == []


def test_fsck_json_output(tmpdir_path, capsys):
    _write_series(tmpdir_path / "s.bp4", steps=2)
    assert jbpfsck.main([str(tmpdir_path / "s.bp4"), "--json"]) == EXIT_OK
    doc = json.loads(capsys.readouterr().out)
    assert doc["committed_steps"] == [0, 1]
    assert doc["issues"] == [] and "repaired" in doc
    assert "_records" not in doc         # internal fields stay internal


# ------------------------------------------------------------ shared runner
def test_jbpls_shares_runner_conventions(tmpdir_path, capsys):
    _write_series(tmpdir_path / "s.bp4", steps=2)
    assert jbpls.main([str(tmpdir_path / "s.bp4"), "-l", "--parallel", "2",
                       "--dump", "scalar/t", "--io-report"]) == EXIT_OK
    out = capsys.readouterr()
    assert "scalar/t" in out.out
    assert "POSIX_READS" in out.err
    assert jbpls.main([str(tmpdir_path / "nope")]) == EXIT_USAGE
