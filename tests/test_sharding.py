"""Partition rules: divisibility guards, layout selection, spec coverage."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.sharding import (attn_layout, cache_pspec_tree,
                                   param_pspec_tree)
from repro.models import model as M

# AbstractMesh on this JAX takes a single shape tuple of (name, size) pairs
MESH = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = jax.sharding.AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_attn_layout_per_arch():
    from repro.launch.sharding import attn_layouts
    assert attn_layout(get_config("qwen1.5-0.5b"), 16) == "heads"
    assert attn_layout(get_config("phi3-mini-3.8b"), 16) == "heads"
    # q-heads shard; kv (8 heads) stays replicated over model
    assert attn_layouts(get_config("qwen3-4b"), 16) == (("model", None), (None, None))
    assert attn_layouts(get_config("llama-3.2-vision-90b"), 16) == (
        ("model", None), (None, None))
    assert attn_layout(get_config("arctic-480b"), 16) == "head_dim"  # H=56
    assert attn_layout(get_config("smollm-360m"), 16) == "head_dim"  # 15/5


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "arctic-480b",
                                  "mamba2-2.7b", "zamba2-2.7b",
                                  "llama-3.2-vision-90b", "smollm-360m"])
def test_param_specs_cover_and_divide(arch):
    cfg = get_config(arch)
    shapes = M.param_shapes(cfg)
    specs = param_pspec_tree(cfg, MESH, shapes)
    n_sharded = 0
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0]):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= dict(MESH.shape)[a]
            assert dim % total == 0, (path, spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0, "nothing sharded at all"


def test_big_weights_are_sharded():
    """Every leaf >= 1M params must be sharded on at least one axis."""
    import numpy as np
    for arch in ("arctic-480b", "llama-3.2-vision-90b", "mamba2-2.7b"):
        cfg = get_config(arch)
        shapes = M.param_shapes(cfg)
        specs = param_pspec_tree(cfg, MESH, shapes)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(specs)[0]):
            if np.prod(leaf.shape) >= 1_000_000:
                assert any(e is not None for e in spec), (arch, path, leaf.shape)


def test_smollm_attention_weights_shard_head_dim():
    cfg = get_config("smollm-360m")
    shapes = M.param_shapes(cfg)
    specs = param_pspec_tree(cfg, MESH, shapes)
    wq = specs["stack"]["layers"]["attn"]["wq"]["w"]
    # [L, d_model, 15, 64]: heads dim must NOT be sharded, head_dim is
    assert wq[2] is None and wq[3] == "model", wq


def test_cache_specs_divide(tmp_path):
    for arch in ("qwen3-4b", "mamba2-2.7b", "zamba2-2.7b",
                 "llama-3.2-vision-90b"):
        cfg = get_config(arch)
        spec_tree = M.make_decode_cache_spec(cfg, 128, 1024)
        specs = cache_pspec_tree(cfg, MESH, spec_tree)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(spec_tree)[0],
                jax.tree_util.tree_flatten_with_path(specs)[0]):
            for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                total = 1
                for a in axes:
                    total *= dict(MESH.shape)[a]
                assert dim % total == 0, (arch, path, spec, leaf.shape)


def test_multipod_specs_build():
    cfg = get_config("qwen3-4b")
    shapes = M.param_shapes(cfg)
    specs = param_pspec_tree(cfg, MESH_MP, shapes)
    assert len(jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))) > 0
