"""Dry-run integration: one real (arch x shape x mesh) cell compiles on the
production mesh in a subprocess (512 fake devices), plus skip-rule checks."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs.base import get_config
from repro.launch.shapes import SHAPE_TABLE, applicable


def test_shape_table_is_the_assignment():
    assert SHAPE_TABLE["train_4k"].seq == 4096
    assert SHAPE_TABLE["train_4k"].batch == 256
    assert SHAPE_TABLE["prefill_32k"].seq == 32768
    assert SHAPE_TABLE["prefill_32k"].batch == 32
    assert SHAPE_TABLE["decode_32k"].batch == 128
    assert SHAPE_TABLE["long_500k"].seq == 524288
    assert SHAPE_TABLE["long_500k"].batch == 1


def test_long_context_skip_rules():
    ok, _ = applicable(get_config("mamba2-2.7b"), "long_500k")
    assert ok
    ok, _ = applicable(get_config("zamba2-2.7b"), "long_500k")
    assert ok
    for arch in ("phi3-mini-3.8b", "qwen3-4b", "arctic-480b",
                 "llama-3.2-vision-90b", "musicgen-large"):
        ok, why = applicable(get_config(arch), "long_500k")
        assert not ok and "full-attention" in why


@pytest.mark.slow
def test_one_cell_compiles_on_production_mesh():
    code = textwrap.dedent("""
        from repro.launch.dryrun import run_cell
        out = run_cell("qwen1.5-0.5b", "decode_32k", "single", verbose=False)
        assert out["status"] == "ok", out
        r = out["roofline"]
        assert r["flops_per_device"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert out["memory_analysis"]["argument_bytes"] > 0
        print("CELL_OK", r["dominant"])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env())
    assert "CELL_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


@pytest.mark.slow
def test_multipod_mesh_cell():
    code = textwrap.dedent("""
        import os
        os.environ["REPRO_DRYRUN_XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512")
        from repro.launch.dryrun import run_cell
        out = run_cell("qwen1.5-0.5b", "decode_32k", "multi", verbose=False)
        assert out["status"] == "ok", out
        assert out["mesh_info"]["n_devices"] == 512
        print("MULTIPOD_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env())
    assert "MULTIPOD_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env
