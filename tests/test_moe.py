"""MoE: sort-based capacity dispatch correctness vs explicit per-token compute."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models.moe import init_moe, moe_ffn


def _cfg(E=8, k=2, cf=8.0, shared=0, dense=False):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                       n_experts=E, top_k=k, capacity_factor=cf,
                       n_shared_experts=shared, dense_residual=dense,
                       dense_d_ff=32 if dense else 0)


def _dense_reference(p, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1).astype(jnp.float32)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    gate = p["experts"]["gate"].astype(jnp.float32)
    up = p["experts"]["up"].astype(jnp.float32)
    down = p["experts"]["down"].astype(jnp.float32)
    # all-experts compute, then select
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, gate)) * \
        jnp.einsum("td,edf->tef", xf, up)
    y_all = jnp.einsum("tef,efd->ted", h, down)            # [T,E,d]
    sel = jnp.take_along_axis(y_all, top_e[..., None], axis=1)  # [T,k,d]
    return jnp.einsum("tkd,tk->td", sel, top_w).reshape(x.shape)


@pytest.mark.parametrize("E,k", [(4, 1), (8, 2), (8, 6)])
def test_dispatch_matches_dense_reference(E, k):
    cfg = _cfg(E=E, k=k, cf=float(E))    # ample capacity: no drops
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)).astype(jnp.bfloat16)
    y, aux = moe_ffn(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    denom = jnp.maximum(jnp.max(jnp.abs(ref)), 1e-6)
    assert jnp.max(jnp.abs(y.astype(jnp.float32) - ref)) / denom < 3e-2
    assert jnp.isfinite(aux) and aux > 0.5    # ~1.0 when balanced


def test_capacity_drops_are_bounded():
    """With tiny capacity the output degrades but stays finite (token drop)."""
    cfg = _cfg(E=8, k=2, cf=0.25)
    key = jax.random.PRNGKey(1)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 64, cfg.d_model)).astype(jnp.bfloat16)
    y, aux = moe_ffn(p, x, cfg)
    assert jnp.isfinite(y.astype(jnp.float32)).all()


def test_shared_and_dense_residual_paths():
    cfg = _cfg(E=4, k=2, shared=2, dense=True)
    key = jax.random.PRNGKey(2)
    p = init_moe(key, cfg)
    assert "shared" in p and "dense" in p
    x = jax.random.normal(key, (1, 8, cfg.d_model)).astype(jnp.bfloat16)
    y, _ = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    # removing the shared experts changes the output (they contribute)
    p2 = dict(p)
    p2["shared"] = jax.tree_util.tree_map(jnp.zeros_like, p["shared"])
    y2, _ = moe_ffn(p2, x, cfg)
    assert jnp.max(jnp.abs((y - y2).astype(jnp.float32))) > 1e-4


def test_aux_loss_detects_imbalance():
    cfg = _cfg(E=4, k=1)
    p = init_moe(jax.random.PRNGKey(3), cfg)
    # force the router to always pick expert 0 (positive inputs x positive
    # column weight -> logit[:,0] >> others)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model))) + 0.1
    _, aux = moe_ffn(p, x.astype(jnp.bfloat16), cfg)
    assert aux > 2.0     # E * f_0 * P_0 ~ E when collapsed
