"""Multi-process parallel write plane: W-process parity with the sync
single-process writer, two-phase commit semantics, torn-shard recovery,
and the parallel_io wiring through Series / PIC / checkpoints."""
import json

import numpy as np
import pytest

from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.parallel_engine import (ParallelBpWriter, iter_shard_records,
                                        shard_path)
from repro.core.striping import StripeConfig


def _write_series(cls, path, *, n_ranks=8, codec="none", steps=3,
                  stripe=None, fsync_policy="close", **kw):
    cfg = EngineConfig(aggregators=4, codec=codec, workers=3, stripe=stripe,
                       n_osts=4, fsync_policy=fsync_policy)
    w = cls(path, n_ranks, cfg, **kw)
    rng = np.random.default_rng(11)
    truth = {}
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(n_ranks * 16, 4)).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        w.put("scalar/t", np.array([s], np.int64), global_shape=(1,),
              offset=(0,), rank=0)
        w.end_step()
    w.close()
    return truth


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("codec", ["none", "blosc"])
def test_parallel_matches_sync_byte_for_byte(tmpdir_path, codec):
    """W=4 REAL processes must produce data.*/md.0 byte-identical to the
    single-process sync writer for the same puts — the reader needs zero
    format changes (acceptance criterion of the parallel write plane)."""
    truth = _write_series(BpWriter, tmpdir_path / "sync.bp4", codec=codec)
    _write_series(ParallelBpWriter, tmpdir_path / "par.bp4", codec=codec,
                  n_writers=4)
    for name in ["data.0", "data.1", "data.2", "data.3", "md.0"]:
        a = (tmpdir_path / "sync.bp4" / name).read_bytes()
        b = (tmpdir_path / "par.bp4" / name).read_bytes()
        assert a == b, f"{name} differs between sync and parallel writes"
    r = BpReader(tmpdir_path / "par.bp4")
    assert r.valid_steps() == [0, 1, 2]
    for s, g in truth.items():
        np.testing.assert_array_equal(r.read_var(s, "var/x"), g)
        np.testing.assert_array_equal(r.read_var(s, "scalar/t"),
                                      np.array([s], np.int64))
    # semantic metadata parity: same chunk tables through the query layer
    rs = BpReader(tmpdir_path / "sync.bp4")
    assert rs.variables() == r.variables()
    assert rs.layout() == r.layout()


def test_parallel_box_selection_across_subfiles(tmpdir_path):
    truth = _write_series(ParallelBpWriter, tmpdir_path / "p.bp4",
                          n_writers=4)
    r = BpReader(tmpdir_path / "p.bp4")
    sel = r.read_var(1, "var/x", offset=(24, 1), extent=(80, 2))
    np.testing.assert_array_equal(sel, truth[1][24:104, 1:3])


def test_parallel_striped_roundtrip(tmpdir_path):
    """Each writer process stripes its own subfile over the shared OST
    dirs; the striped layout reads back through the standard reader."""
    truth = _write_series(ParallelBpWriter, tmpdir_path / "p.bp4",
                          n_writers=2, n_ranks=4, steps=2,
                          stripe=StripeConfig(stripe_count=2, stripe_size=256))
    r = BpReader(tmpdir_path / "p.bp4")
    np.testing.assert_array_equal(r.read_var(1, "var/x"), truth[1])


def test_parallel_writer_count_clamped(tmpdir_path):
    """n_writers > n_ranks clamps like aggregators do (one process per
    rank at most)."""
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 2, EngineConfig(),
                         n_writers=8)
    assert w.m == 2
    w.begin_step(0)
    w.put("v", np.arange(4, dtype=np.float32), global_shape=(4,),
          offset=(0,), rank=1)
    w.end_step()
    w.close()
    assert len(list((tmpdir_path / "p.bp4").glob("data.*"))) == 2


def test_parallel_put_rank_validation(tmpdir_path):
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 4, EngineConfig(),
                         n_writers=2)
    w.begin_step(0)
    with pytest.raises(ValueError, match="rank=4"):
        w.put("v", np.zeros(4, np.float32), global_shape=(4,), offset=(0,),
              rank=4)
    w.put("v", np.zeros(4, np.float32), global_shape=(4,), offset=(0,),
          rank=0)
    w.end_step()
    w.close()


# -------------------------------------------------------- two-phase commit
def test_crash_between_prepare_and_commit_drops_step(tmpdir_path):
    """Shards sealed (phase 1) but no md.idx record (phase 2 never ran):
    the step must be invisible to the reader — torn-shard/torn-commit
    recovery is 'the commit record is the truth'."""
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 4, EngineConfig(),
                         n_writers=2)
    w.begin_step(0)
    w.put("v", np.arange(8, dtype=np.float32), global_shape=(8,),
          offset=(0,), rank=0)
    w.end_step()
    w._crash_after_prepare = True
    w.begin_step(1)
    w.put("v", np.full(8, 9, np.float32), global_shape=(8,), offset=(0,),
          rank=0)
    with pytest.raises(RuntimeError, match="simulated coordinator crash"):
        w.end_step()
    w._crash_after_prepare = False
    w.close()
    # step 1 was durably PREPARED on the shard...
    assert [s for s, _ in iter_shard_records(tmpdir_path / "p.bp4", 0)] == \
        [0, 1]
    # ...but never committed: the reader drops it exactly like a torn step
    r = BpReader(tmpdir_path / "p.bp4")
    assert r.valid_steps() == [0]
    np.testing.assert_array_equal(r.read_var(0, "v"),
                                  np.arange(8, dtype=np.float32))


def test_torn_shard_tail_is_dropped_on_replay(tmpdir_path):
    """A shard torn mid-record (writer crash during prepare) replays to
    exactly the sealed prefix — the recovery primitive."""
    _write_series(ParallelBpWriter, tmpdir_path / "p.bp4", n_writers=2,
                  n_ranks=4, steps=3)
    sp = shard_path(tmpdir_path / "p.bp4", 1)
    raw = sp.read_bytes()
    sp.write_bytes(raw[:len(raw) - 7])        # tear the last record's tail
    steps = [s for s, _ in iter_shard_records(tmpdir_path / "p.bp4", 1)]
    assert steps == [0, 1]
    # corrupt the SECOND record's payload: replay stops BEFORE it
    from repro.core.parallel_engine import SHARD_HDR
    _, ln0, _ = SHARD_HDR.unpack_from(raw, 0)
    raw2 = bytearray(raw)
    raw2[SHARD_HDR.size + ln0 + SHARD_HDR.size + 2] ^= 0xFF
    sp.write_bytes(bytes(raw2))
    assert [s for s, _ in iter_shard_records(tmpdir_path / "p.bp4", 1)] == [0]


def test_worker_error_aborts_step_not_series(tmpdir_path):
    """A worker-side failure (bad codec) aborts the step with the worker
    traceback surfaced; nothing is committed and close() still tears the
    plane down cleanly."""
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 2,
                         EngineConfig(codec="no-such-codec"), n_writers=2)
    w.begin_step(0)
    w.put("v", np.arange(4, dtype=np.float32), global_shape=(4,),
          offset=(0,), rank=0)
    with pytest.raises(RuntimeError, match="unknown codec"):
        w.end_step()
    w.close()
    assert BpReader(tmpdir_path / "p.bp4").valid_steps() == []
    assert all(not p.is_alive() for p, _ in w._workers)


def test_worker_shard_offset_survives_failed_step(tmpdir_path, monkeypatch):
    """A step that fails AFTER the shard grew (e.g. fsync error) must not
    desync the worker's record-offset accounting: the next successful
    step's 'prepared' ack has to point at ITS OWN sealed record, or every
    later commit on that worker aborts as a torn shard."""
    import queue as q
    import threading
    import zlib as _zlib

    from repro.core import aggregation
    from repro.core.bp_engine import EngineConfig
    from repro.core.parallel_engine import SHARD_HDR, _worker_main

    fail_once = {"armed": True}
    real_fsync = aggregation.SubfileSet.fsync_one

    def flaky_fsync(self, agg_id):
        if fail_once.pop("armed", None):
            raise OSError("injected transient fsync failure")
        return real_fsync(self, agg_id)

    monkeypatch.setattr(aggregation.SubfileSet, "fsync_one", flaky_fsync)
    task_q, result_q = q.Queue(), q.Queue()
    t = threading.Thread(
        target=_worker_main,
        args=(0, str(tmpdir_path), 1,
              EngineConfig(fsync_policy="step"), task_q, result_q),
        daemon=True)
    t.start()
    assert result_q.get(timeout=10)[0] == "ready"
    arr = np.arange(8, dtype=np.float32)
    task_q.put(("step", 0, [("v", 0, (0,), arr)]))
    tag, _, _, payload = result_q.get(timeout=10)
    assert tag == "error" and "injected transient fsync" in payload
    task_q.put(("step", 1, [("v", 0, (0,), arr * 2)]))
    tag, _, mstep, info = result_q.get(timeout=10)
    assert (tag, mstep) == ("prepared", 1)
    task_q.put(("close", None, None))
    assert result_q.get(timeout=10)[0] == "closed"
    t.join(timeout=10)
    # the ack must locate a crc-valid record FOR STEP 1 (the coordinator's
    # phase-1 validation, done by hand here)
    raw = (tmpdir_path / "md.0.shard").read_bytes()
    rec = raw[info["shard_off"]:info["shard_off"] + info["shard_len"]]
    rstep, ln, crc = SHARD_HDR.unpack_from(rec, 0)
    blob = rec[SHARD_HDR.size:SHARD_HDR.size + ln]
    assert rstep == 1 and (_zlib.crc32(blob) & 0xFFFFFFFF) == crc


def test_fsync_step_policy_commits_each_step_durably(tmpdir_path):
    """fsync_policy='step': every end_step returns with the commit record
    (and the workers' subfile+shard fsyncs) on disk — a reader opened
    mid-series sees the committed prefix."""
    w = ParallelBpWriter(tmpdir_path / "p.bp4", 4,
                         EngineConfig(fsync_policy="step"), n_writers=2)
    for s in range(2):
        w.begin_step(s)
        w.put("v", np.full(8, s, np.float32), global_shape=(8,),
              offset=(0,), rank=0)
        w.end_step()
        r = BpReader(tmpdir_path / "p.bp4")
        assert r.valid_steps() == list(range(s + 1))
    w.close()


def test_profiling_has_two_phase_timings(tmpdir_path):
    _write_series(ParallelBpWriter, tmpdir_path / "p.bp4", n_writers=4,
                  steps=2)
    doc = json.loads((tmpdir_path / "p.bp4" / "profiling.json").read_text())
    assert doc["engine"] == "JBP(BP4-parallel)"
    assert doc["writers"] == 4
    for step in doc["steps"]:
        assert step["prepare_s"] > 0 and step["commit_s"] >= 0
        assert len(step["worker_s"]) >= 1


# ------------------------------------------------- persistent writer plane
def test_writer_plane_reused_across_series_same_pids(tmpdir_path):
    """Two series written through one WriterPlane reuse the SAME worker
    processes (retarget via open/finish, no respawn) and both read back."""
    from repro.core.parallel_engine import WriterPlane

    with WriterPlane(2) as plane:
        pids = plane.pids()
        for i in range(2):
            truth = _write_series(
                ParallelBpWriter, tmpdir_path / f"s{i}.bp4",
                n_ranks=4, steps=2, n_writers=2, plane=plane)
            assert plane.pids() == pids, "plane respawned between series"
            assert all(p.is_alive() for p, _ in plane.workers)
            r = BpReader(tmpdir_path / f"s{i}.bp4")
            assert r.valid_steps() == [0, 1]
            np.testing.assert_array_equal(r.read_var(1, "var/x"), truth[1])
    assert all(not p.is_alive() for p, _ in plane.workers)


def test_writer_plane_output_byte_identical_to_owned_workers(tmpdir_path):
    """A plane-backed write must be byte-identical to the spawn-per-series
    writer (same subfiles, same md.0) — the plane is purely a lifetime
    optimization."""
    from repro.core.parallel_engine import WriterPlane

    _write_series(ParallelBpWriter, tmpdir_path / "own.bp4", n_ranks=4,
                  steps=2, n_writers=2)
    with WriterPlane(2) as plane:
        _write_series(ParallelBpWriter, tmpdir_path / "pl.bp4", n_ranks=4,
                      steps=2, n_writers=2, plane=plane)
    for name in ["data.0", "data.1", "md.0"]:
        assert (tmpdir_path / "own.bp4" / name).read_bytes() == \
            (tmpdir_path / "pl.bp4" / name).read_bytes(), name


def test_writer_plane_clamps_to_fewer_writers(tmpdir_path):
    """A writer asking for more writers than the plane has uses the
    plane's worker count; asking for fewer opens only that many."""
    from repro.core.parallel_engine import WriterPlane

    with WriterPlane(2) as plane:
        w = ParallelBpWriter(tmpdir_path / "a.bp4", 8, EngineConfig(),
                             n_writers=4, plane=plane)
        assert w.m == 2
        w.begin_step(0)
        w.put("v", np.arange(8, dtype=np.float32), global_shape=(8,),
              offset=(0,), rank=0)
        w.end_step()
        w.close()
        w2 = ParallelBpWriter(tmpdir_path / "b.bp4", 8, EngineConfig(),
                              n_writers=1, plane=plane)
        assert w2.m == 1
        w2.begin_step(0)
        w2.put("v", np.arange(8, dtype=np.float32), global_shape=(8,),
               offset=(0,), rank=0)
        w2.end_step()
        w2.close()
        assert len(list((tmpdir_path / "b.bp4").glob("data.*"))) == 1


# --------------------------------------------- darshan counters from workers
def test_worker_darshan_counters_merged_into_parent(tmpdir_path):
    """Per-worker I/O happens in the worker PROCESS, whose MONITOR the
    parent never sees — unless the 'closed'/'finished' ack ships the
    counters back. After close(), the parent's parser_dump must cover the
    workers' data.<w>/shard writes."""
    from repro.core.darshan import MONITOR

    MONITOR.reset()
    _write_series(ParallelBpWriter, tmpdir_path / "p.bp4", n_ranks=4,
                  steps=2, n_writers=2)
    rep = MONITOR.report()["files"]
    for w in (0, 1):
        data = [c for p, c in rep.items() if p.endswith(f"data.{w}")]
        assert data and data[0].get("POSIX_BYTES_WRITTEN", 0) > 0, \
            f"worker {w} subfile writes missing from the merged monitor"
        shard = [c for p, c in rep.items() if p.endswith(f"md.{w}.shard")]
        assert shard and shard[0].get("POSIX_BYTES_WRITTEN", 0) > 0
    dump = MONITOR.parser_dump()
    assert "data.1" in dump


# ------------------------------------------------------------------- wiring
def test_series_parallel_io_roundtrip(tmpdir_path):
    from repro.core.openpmd import Series
    s = Series(tmpdir_path / "d.bp4", "w", n_ranks=4,
               engine_config=EngineConfig(aggregators=2), parallel_io=2)
    it = s.iterations[0]
    rc = it.meshes["density"][""]
    arr = np.linspace(0, 1, 64, dtype=np.float32)
    rc.reset_dataset(arr.dtype, arr.shape)
    for r in range(4):
        rc.store_chunk(arr[r * 16:(r + 1) * 16], offset=(r * 16,), rank=r)
    s.flush()
    s.close()
    r = BpReader(tmpdir_path / "d.bp4")
    assert r.valid_steps() == [0]
    np.testing.assert_array_equal(
        r.read_var(0, "/data/0/meshes/density"), arr)


def test_series_validates_plane_combinations_up_front(tmpdir_path):
    """Bad engine-plane combinations must fail AT CONSTRUCTION with the
    correct spelling named — not silently pick one plane or raise at the
    first flush."""
    from repro.core.openpmd import Series

    # stacking the single-process async engine on the parallel plane:
    # the error must point at the async_commit composition
    with pytest.raises(ValueError,
                       match=r"Series\(parallel_io=2, async_commit=True\)"):
        Series(tmpdir_path / "d.bp4", "w", async_io=True, parallel_io=2)
    # async_commit without a parallel plane to pipeline
    with pytest.raises(ValueError, match="requires parallel_io"):
        Series(tmpdir_path / "d.bp4", "w", async_commit=True)
    # nothing above may have constructed a writer (and truncated md.0)
    assert not (tmpdir_path / "d.bp4" / "md.0").exists()
    # unknown transport spelling
    with pytest.raises(ValueError, match="unknown transport"):
        Series(tmpdir_path / "d.bp4", "w", parallel_io=2, transport="tcp")


def test_checkpoint_parallel_io_roundtrip(tmpdir_path):
    from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
             "b": np.ones(8, dtype=np.float32),
             "step": np.int32(7)}
    save_checkpoint(tmpdir_path, state, 7, n_io_ranks=4, parallel_io=2)
    like = {k: np.zeros_like(v) for k, v in state.items()}
    restored, step = restore_checkpoint(tmpdir_path, like)
    assert step == 7
    for k in state:
        np.testing.assert_array_equal(restored[k], state[k])


def test_pic_diagnostic_series_parallel_io(tmpdir_path):
    import jax

    from repro.pic.simulation import (PicConfig, init_sim,
                                      open_diagnostic_series,
                                      run_with_diagnostics)
    cfg = PicConfig(n_cells=64, capacity=1 << 9, n_electrons=256,
                    n_ions=256, n_neutrals=256)
    state = init_sim(cfg, jax.random.PRNGKey(0))
    series = open_diagnostic_series(tmpdir_path / "diag.bp4", n_io_ranks=4,
                                    parallel_io=2)
    run_with_diagnostics(state, cfg, series, n_chunks=2, steps_per_chunk=2,
                         n_io_ranks=4)
    series.close()
    r = BpReader(tmpdir_path / "diag.bp4")
    steps = r.valid_steps()
    assert len(steps) == 2
    dens = r.read_var(steps[0], "/data/%d/meshes/density_e" % steps[0])
    assert dens.shape == (64,) and np.isfinite(dens).all()
