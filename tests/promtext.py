"""Minimal Prometheus text-exposition (0.0.4) parser / validator.

Used by test_metrics / test_jbpd AND by CI (``python tests/promtext.py
FILE``) to validate what `MetricsHttpShim` / `SeriesServer.metrics_text`
serve.  Deliberately dependency-free: the repo may not have
prometheus_client installed, and the exposition grammar is small enough
to check exactly:

  * every non-comment line is ``name{labels} value`` or ``name value``
  * label values are double-quoted with ``\\`` ``\"`` ``\n`` escaped
  * every sample's metric name was declared by a ``# TYPE`` line
    (histogram samples may use the ``_bucket``/``_sum``/``_count``
    suffixes of their family)
  * histogram ``le`` buckets are cumulative, non-decreasing, and end
    with ``+Inf`` whose count equals the family's ``_count``
  * the body ends with a newline (the spec's final-EOL requirement)

``parse(text)`` returns (samples, types) or raises ValueError with a
line-numbered complaint; ``validate(text)`` additionally runs the
histogram-shape checks.
"""
from __future__ import annotations

import math
import re
import sys

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LINE = re.compile(rf"^({_NAME})(?:\{{(.*)\}})? (-?(?:[0-9.eE+-]+|Inf|NaN))$")
_LABEL = re.compile(rf'({_NAME})="((?:[^"\\\n]|\\[\\"n])*)"(?:,|$)')
_SUFFIXES = ("_bucket", "_sum", "_count", "_total")


def _unescape(v: str) -> str:
    return (v.replace("\\\\", "\x00").replace('\\"', '"')
             .replace("\\n", "\n").replace("\x00", "\\"))


def parse(text: str):
    """-> (samples, types): samples is a list of (name, labels, value),
    types maps declared family name -> type string."""
    if not text.endswith("\n"):
        raise ValueError("exposition must end with a newline")
    types: dict[str, str] = {}
    helps: set[str] = set()
    samples: list[tuple[str, dict, float]] = []
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {ln}: malformed TYPE line: {line!r}")
            if parts[2] in types:
                raise ValueError(f"line {ln}: duplicate TYPE for {parts[2]}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3:
                raise ValueError(f"line {ln}: malformed HELP line: {line!r}")
            helps.add(parts[2])
            continue
        if line.startswith("#"):
            continue                       # free comment — legal
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"line {ln}: not a valid sample line: {line!r}")
        name, labelblob, value = m.group(1), m.group(2), m.group(3)
        labels: dict[str, str] = {}
        if labelblob:
            pos = 0
            while pos < len(labelblob):
                lm = _LABEL.match(labelblob, pos)
                if not lm:
                    raise ValueError(f"line {ln}: bad label syntax at "
                                     f"{labelblob[pos:]!r}")
                labels[lm.group(1)] = _unescape(lm.group(2))
                pos = lm.end()
        fam = name
        for suf in _SUFFIXES:
            if name.endswith(suf) and name[: -len(suf)] in types:
                fam = name[: -len(suf)]
                break
        if fam not in types:
            raise ValueError(f"line {ln}: sample {name!r} has no TYPE "
                             f"declaration")
        samples.append((name, labels, float(value)))
    return samples, types


def validate(text: str):
    """parse() + histogram-shape checks; returns (samples, types)."""
    samples, types = parse(text)
    for fam, typ in types.items():
        if typ != "histogram":
            continue
        # group the family's buckets by their non-le label set
        series: dict[tuple, list[tuple[float, float]]] = {}
        counts: dict[tuple, float] = {}
        for name, labels, value in samples:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            if name == fam + "_bucket":
                le = labels.get("le")
                if le is None:
                    raise ValueError(f"{fam}_bucket without le label")
                series.setdefault(key, []).append((float(le), value))
            elif name == fam + "_count":
                counts[key] = value
        for key, buckets in series.items():
            les = [b[0] for b in buckets]
            vals = [b[1] for b in buckets]
            if not math.isinf(les[-1]):
                raise ValueError(f"{fam}{dict(key)}: buckets must end "
                                 f"with le=+Inf")
            if sorted(les) != les:
                raise ValueError(f"{fam}{dict(key)}: le edges not sorted")
            if any(b > a for a, b in zip(vals[1:], vals[:-1])):
                raise ValueError(f"{fam}{dict(key)}: bucket counts not "
                                 f"cumulative")
            if key in counts and counts[key] != vals[-1]:
                raise ValueError(f"{fam}{dict(key)}: +Inf bucket "
                                 f"({vals[-1]}) != _count ({counts[key]})")
    return samples, types


def main(argv) -> int:
    if len(argv) != 2:
        print("usage: python tests/promtext.py FILE", file=sys.stderr)
        return 2
    with open(argv[1]) as fh:   # validator tool, not a data-plane file
        text = fh.read()
    try:
        samples, types = validate(text)
    except ValueError as e:
        print(f"promtext: INVALID: {e}", file=sys.stderr)
        return 1
    print(f"promtext: ok — {len(samples)} samples, "
          f"{len(types)} families ({sum(1 for t in types.values() if t == 'histogram')} histograms)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
