"""Lustre-style striping: layout math, roundtrips, introspection."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.striping import OstPool, StripeConfig, StripedFile


def test_roundtrip_multi_stripe(tmpdir_path):
    pool = OstPool(tmpdir_path, 4)
    cfg = StripeConfig(stripe_count=3, stripe_size=1024)
    f = StripedFile(pool, "data.0", cfg)
    payload = np.random.default_rng(0).bytes(10_000)
    f.write(payload)
    f.fsync()
    assert f.read(0, len(payload)) == payload
    assert f.read(1500, 2000) == payload[1500:3500]
    info = f.getstripe()
    assert info["lmm_stripe_count"] == 3
    assert info["lmm_pattern"] == "raid0"
    assert len(info["objects"]) == 3
    f.close()


def test_object_distribution(tmpdir_path):
    """raid0: stripe k lands on OST k%count at offset (k//count)*size."""
    pool = OstPool(tmpdir_path, 2)
    cfg = StripeConfig(stripe_count=2, stripe_size=100)
    f = StripedFile(pool, "x", cfg)
    f.write(bytes(range(256)) * 2)       # 512 bytes -> 6 stripes
    f.fsync()
    f.close()
    o0 = pool.object_path(0, "x.obj").stat().st_size
    o1 = pool.object_path(1, "x.obj").stat().st_size
    assert o0 == 300 and o1 == 212       # 3 stripes vs 2 stripes + 12


@settings(max_examples=25, deadline=None)
@given(stripe_count=st.integers(1, 4),
       stripe_size=st.integers(16, 512),
       chunks=st.lists(st.integers(1, 900), min_size=1, max_size=8))
def test_property_append_roundtrip(stripe_count, stripe_size, chunks):
    import tempfile, pathlib, shutil
    d = pathlib.Path(tempfile.mkdtemp())
    try:
        pool = OstPool(d, 4)
        f = StripedFile(pool, "p", StripeConfig(stripe_count, stripe_size))
        rng = np.random.default_rng(sum(chunks))
        blob = b"".join(rng.bytes(c) for c in chunks)
        pos = 0
        for c in chunks:
            f.write(blob[pos:pos + c])
            pos += c
        f.fsync()
        assert f.read(0, len(blob)) == blob
        f.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
