"""Lustre-style striping: layout math, roundtrips, introspection."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.striping import OstPool, StripeConfig, StripedFile


def test_roundtrip_multi_stripe(tmpdir_path):
    pool = OstPool(tmpdir_path, 4)
    cfg = StripeConfig(stripe_count=3, stripe_size=1024)
    f = StripedFile(pool, "data.0", cfg)
    payload = np.random.default_rng(0).bytes(10_000)
    f.write(payload)
    f.fsync()
    assert f.read(0, len(payload)) == payload
    assert f.read(1500, 2000) == payload[1500:3500]
    info = f.getstripe()
    assert info["lmm_stripe_count"] == 3
    assert info["lmm_pattern"] == "raid0"
    assert len(info["objects"]) == 3
    f.close()


def test_object_distribution(tmpdir_path):
    """raid0: stripe k lands on OST k%count at offset (k//count)*size."""
    pool = OstPool(tmpdir_path, 2)
    cfg = StripeConfig(stripe_count=2, stripe_size=100)
    f = StripedFile(pool, "x", cfg)
    f.write(bytes(range(256)) * 2)       # 512 bytes -> 6 stripes
    f.fsync()
    f.close()
    o0 = pool.object_path(0, "x.obj").stat().st_size
    o1 = pool.object_path(1, "x.obj").stat().st_size
    assert o0 == 300 and o1 == 212       # 3 stripes vs 2 stripes + 12


def test_read_mode_striped_file(tmpdir_path):
    """mode='r' opens an existing layout without truncating it: reads,
    getstripe() and logical_size all work (the BpReader path used to skip
    __init__ entirely and die in getstripe with AttributeError)."""
    pool = OstPool(tmpdir_path, 4)
    cfg = StripeConfig(stripe_count=3, stripe_size=512)
    w = StripedFile(pool, "data.0", cfg)
    payload = np.random.default_rng(1).bytes(5000)
    w.write(payload)
    w.fsync()
    w.close()

    r = StripedFile(pool, "data.0", cfg, mode="r")
    assert r.logical_size == len(payload)
    assert r.read(0, len(payload)) == payload
    assert r.read(700, 1300) == payload[700:2000]
    info = r.getstripe()                  # regression: no AttributeError
    assert info["logical_size"] == len(payload)
    assert info["lmm_stripe_count"] == 3
    with pytest.raises(ValueError, match="not open for writing"):
        r.write(b"nope")
    r.close()


def test_read_mode_caches_object_handles(tmpdir_path):
    """Repeated reads must reuse per-OST handles, not reopen an object
    file per segment."""
    from repro.core.darshan import MONITOR
    pool = OstPool(tmpdir_path, 2)
    cfg = StripeConfig(stripe_count=2, stripe_size=128)
    w = StripedFile(pool, "x", cfg)
    w.write(bytes(range(256)) * 8)        # 2048 bytes -> 16 stripes
    w.fsync()
    w.close()
    MONITOR.reset()
    r = StripedFile(pool, "x", cfg, mode="r")
    for off in (0, 256, 512, 1024):
        r.read(off, 256)
    opens = sum(c.get("POSIX_OPENS", 0)
                for p, c in MONITOR.report()["files"].items() if ".obj" in p)
    assert opens == 2, f"expected one open per OST, saw {opens}"
    r.close()


def test_parallel_ost_flush_overlaps_stragglers(tmpdir_path):
    """One logical write touching K slow OSTs costs ~max(ost time), not the
    sum — the per-OST flushers run concurrently."""
    import time
    delay = 0.08
    pool = OstPool(tmpdir_path, 2, slow_osts={0: delay, 1: delay})
    cfg = StripeConfig(stripe_count=2, stripe_size=100)
    f = StripedFile(pool, "s", cfg)
    t0 = time.perf_counter()
    f.write(bytes(400))                   # 4 stripes -> 2 per OST
    dt = time.perf_counter() - t0
    f.fsync()
    assert f.read(0, 400) == bytes(400)
    f.close()
    # sequential: 4 * delay = 0.32s; parallel: ~2 * delay = 0.16s
    assert dt < 3.2 * delay, f"stripe flushes did not overlap ({dt:.3f}s)"


@settings(max_examples=25, deadline=None)
@given(stripe_count=st.integers(1, 4),
       stripe_size=st.integers(16, 512),
       chunks=st.lists(st.integers(1, 900), min_size=1, max_size=8))
def test_property_append_roundtrip(stripe_count, stripe_size, chunks):
    import tempfile, pathlib, shutil
    d = pathlib.Path(tempfile.mkdtemp())
    try:
        pool = OstPool(d, 4)
        f = StripedFile(pool, "p", StripeConfig(stripe_count, stripe_size))
        rng = np.random.default_rng(sum(chunks))
        blob = b"".join(rng.bytes(c) for c in chunks)
        pos = 0
        for c in chunks:
            f.write(blob[pos:pos + c])
            pos += c
        f.fsync()
        assert f.read(0, len(blob)) == blob
        f.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_stripe_count_wider_than_pool_raises(tmpdir_path):
    """Promoted from a stripped-under-`-O` assert: a layout cannot stripe
    wider than the OSTs that exist."""
    pool = OstPool(tmpdir_path, 2)
    with pytest.raises(ValueError, match="exceeds"):
        StripedFile(pool, "f", StripeConfig(stripe_count=3, stripe_size=256))
