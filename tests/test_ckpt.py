"""Checkpoint/restore: roundtrip, retention, crash-resume, elastic reshard."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.ckpt.checkpoint import (list_checkpoints, restore_checkpoint,
                                   save_checkpoint)
from repro.ckpt.manager import CheckpointManager
from repro.configs.base import get_config, reduce_for_smoke
from repro.core.bp_engine import EngineConfig
from repro.train.state import init_train_state


def _small_state():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    return cfg, init_train_state(cfg, jax.random.PRNGKey(0))


def test_roundtrip_exact(tmpdir_path):
    cfg, state = _small_state()
    save_checkpoint(tmpdir_path, state, 7, n_io_ranks=4,
                    engine_config=EngineConfig(aggregators=2, codec="blosc"))
    back, step = restore_checkpoint(tmpdir_path, state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bfloat16_roundtrip(tmpdir_path):
    import ml_dtypes
    state = {"w": np.arange(32, dtype=np.float32).astype(ml_dtypes.bfloat16)}
    save_checkpoint(tmpdir_path, state, 1, n_io_ranks=2)
    back, _ = restore_checkpoint(tmpdir_path, state)
    np.testing.assert_array_equal(
        back["w"].view(np.uint16), state["w"].view(np.uint16))


def test_manager_retention_and_latest(tmpdir_path):
    cfg, state = _small_state()
    mgr = CheckpointManager(tmpdir_path, every=1, keep_n=2, async_write=False,
                            engine_async=True)   # AsyncBpWriter ckpt path
    for s in (1, 2, 3, 4):
        state = dict(state, step=jax.numpy.asarray(s))
        mgr.save(state, s)
    assert list_checkpoints(tmpdir_path) == [3, 4]
    restored, step = mgr.restore_latest(state)
    assert step == 4


def test_manager_skips_corrupt_checkpoint(tmpdir_path):
    cfg, state = _small_state()
    mgr = CheckpointManager(tmpdir_path, every=1, keep_n=5, async_write=False)
    mgr.save(state, 1)
    mgr.save(state, 2)
    # corrupt the newest: truncate its index
    from repro.ckpt.checkpoint import checkpoint_path
    idx = checkpoint_path(tmpdir_path, 2) / "md.idx"
    idx.write_bytes(b"")
    restored = mgr.restore_latest(state)
    assert restored is not None and restored[1] == 1


def test_async_save_overlaps(tmpdir_path):
    cfg, state = _small_state()
    mgr = CheckpointManager(tmpdir_path, every=1, keep_n=3, async_write=True)
    mgr.save(state, 1)
    mgr.save(state, 2)        # waits for 1, then writes 2 in background
    mgr.wait()
    assert list_checkpoints(tmpdir_path) == [1, 2]


def test_manager_persistent_parallel_plane_reuses_worker_pids(tmpdir_path):
    """ROADMAP item closed: parallel_io checkpoints must NOT spawn/tear
    down W processes per save — the manager keeps one WriterPlane alive,
    and two consecutive saves run on the SAME worker pids."""
    state = {"w": np.arange(256, dtype=np.float32).reshape(16, 16),
             "b": np.ones(16, dtype=np.float32)}
    with CheckpointManager(tmpdir_path, every=1, keep_n=3,
                           async_write=False, parallel_io=2,
                           n_io_ranks=4) as mgr:
        mgr.save(state, 1)
        mgr.wait()
        plane = mgr._plane
        assert plane is not None and plane.alive()
        pids = plane.pids()
        mgr.save(state, 2)
        mgr.wait()
        assert mgr._plane is plane, "manager respawned the plane"
        assert plane.pids() == pids, "saves did not reuse the worker pids"
        assert all(p.is_alive() for p, _ in plane.workers)
        assert list_checkpoints(tmpdir_path) == [1, 2]
        restored, step = mgr.restore_latest(state, parallel=2)
        assert step == 2
        np.testing.assert_array_equal(restored["w"], state["w"])
    # close() tore the plane down
    assert not plane.alive()
    assert all(not p.is_alive() for p, _ in plane.workers)


@pytest.mark.slow
def test_elastic_resharding_subprocess(tmpdir_path):
    """Save on a (2,2) mesh, restore on a (4,1) mesh — different device
    count per axis; every shard reads only its box."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import save_checkpoint, restore_sharded

        from repro.launch.mesh import compat_make_mesh
        mesh1 = compat_make_mesh((2, 2), ("data", "model"))
        sh1 = NamedSharding(mesh1, P("data", "model"))
        w = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8), sh1)
        save_checkpoint(r"{tmpdir_path}", {{"w": w}}, 3, n_io_ranks=4)

        mesh2 = compat_make_mesh((4, 1), ("data", "model"))
        sh2 = NamedSharding(mesh2, P("model", "data"))
        like = {{"w": jax.ShapeDtypeStruct((8, 8), np.float32)}}
        out, step = restore_sharded(r"{tmpdir_path}", like, {{"w": sh2}})
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(out["w"]), np.arange(64, dtype=np.float32).reshape(8, 8))
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env())
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env
