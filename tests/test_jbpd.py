"""jbpd service plane: ChunkCache (LRU/budget/coalescing) unit tests,
daemon+client end-to-end parity (concurrent clients, overlapping boxes,
bit-identical to direct reads), cache-hit parity after eviction, shm
handoff fallback to socket framing, corrupt-payload error mapping,
restart/reconnect semantics, and the metrics plane (the `metrics` admin
op, the Prometheus HTTP shim, watch-frame stragglers, and the `_dial`
socket-leak regression)."""
import os
import socket
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import promtext
import pytest

from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.compression import CorruptPayloadError
from repro.core.metrics import METRICS
from repro.serve.jbpd import (FRAME, ChunkCache, DaemonDisconnectedError,
                              JbpDaemon, JbpdRequestError, MetricsHttpShim,
                              SeriesClient, SeriesServer)


def _write(path, *, n_ranks=4, aggregators=2, codec="zlib", steps=2, cols=4):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=3)
    w = BpWriter(path, n_ranks, cfg)
    rng = np.random.default_rng(7)
    truth = {}
    rows = n_ranks * 16
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(rows, cols)).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        w.end_step()
    w.close()
    return truth


@pytest.fixture()
def series(tmpdir_path):
    truth = _write(tmpdir_path / "s.bp4")
    return tmpdir_path / "s.bp4", truth


def _daemon(series_path, sock, **kw):
    server_kw = {k: kw.pop(k) for k in ("cache_bytes", "parallel", "open_any")
                 if k in kw}
    server = SeriesServer([series_path], **server_kw)
    return JbpDaemon(server, socket_path=sock, **kw).start()


# ------------------------------------------------------------------ ChunkCache
def test_cache_hit_miss_lru_eviction():
    cache = ChunkCache(budget_bytes=3000)
    fetches = []

    def mk(key, n):
        def fetch():
            fetches.append(key)
            return np.full(n // 4, key[1], np.float32)
        return fetch

    a = cache.get_or_fetch(("s", 1, "v", 0, 0), mk(("s", 1, "v", 0, 0), 1024),
                           1024)
    assert not a.flags.writeable            # shared objects are read-only
    # hit: same key, no new fetch
    cache.get_or_fetch(("s", 1, "v", 0, 0), mk(("s", 1, "v", 0, 0), 1024),
                       1024)
    assert cache.stats()["hits"] == 1 and len(fetches) == 1
    # two more 1 KiB entries blow the 3000-byte budget -> LRU (first) evicted
    cache.get_or_fetch(("s", 2, "v", 0, 0), mk(("s", 2, "v", 0, 0), 1024),
                       1024)
    cache.get_or_fetch(("s", 3, "v", 0, 0), mk(("s", 3, "v", 0, 0), 1024),
                       1024)
    assert cache.stats()["evictions"] == 1
    cache.get_or_fetch(("s", 1, "v", 0, 0), mk(("s", 1, "v", 0, 0), 1024),
                       1024)
    assert fetches.count(("s", 1, "v", 0, 0)) == 2   # re-fetched after evict


def test_cache_oversized_entry_served_not_cached():
    cache = ChunkCache(budget_bytes=100)
    arr = cache.get_or_fetch(("s", 0, "v", 0, 0),
                             lambda: np.zeros(1024, np.uint8), 1024)
    assert arr.nbytes == 1024
    st = cache.stats()
    assert st["entries"] == 0 and st["bytes"] == 0 and st["misses"] == 1


def test_cache_coalesces_concurrent_identical_fetches():
    cache = ChunkCache()
    fetches = []
    gate = threading.Event()

    def slow_fetch():
        fetches.append(1)
        gate.wait(5.0)
        return np.arange(8, dtype=np.float32)

    results = []
    ts = [threading.Thread(
        target=lambda: results.append(
            cache.get_or_fetch(("s", 0, "v", 0, 0), slow_fetch, 32)))
        for _ in range(4)]
    for t in ts:
        t.start()
    time.sleep(0.2)              # all four are in: one leader, 3 followers
    gate.set()
    for t in ts:
        t.join(5.0)
    assert len(fetches) == 1, "coalescing must leave exactly one fetcher"
    assert cache.stats()["coalesced"] == 3
    for r in results:
        np.testing.assert_array_equal(r, results[0])


def test_cache_failed_fetch_propagates_and_does_not_poison():
    cache = ChunkCache()

    def boom():
        raise CorruptPayloadError("injected rot")

    with pytest.raises(CorruptPayloadError):
        cache.get_or_fetch(("s", 0, "v", 0, 0), boom, 32)
    # the key is not stuck in-flight: a healthy retry succeeds
    out = cache.get_or_fetch(("s", 0, "v", 0, 0),
                             lambda: np.ones(4, np.float32), 16)
    np.testing.assert_array_equal(out, np.ones(4, np.float32))


# ------------------------------------------------------------------ end-to-end
def test_metadata_queries_match_direct_reader(series, tmpdir_path):
    path, truth = series
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with BpReader(path) as r, SeriesClient(d.address, path) as c:
            assert c.steps() == r.valid_steps()
            v = c.variables()
            assert set(v) == {"var/x"}
            assert tuple(v["var/x"]["shape"]) == truth[0].shape
            assert c.layout() == r.layout()
            assert c.var_minmax(0, "var/x") == r.var_minmax(0, "var/x")
            chunks = c.iter_chunks(0, "var/x")
            assert len(chunks) == 4
            assert chunks == [ch.to_json() for ch in r.iter_chunks(0, "var/x")]


def test_concurrent_clients_overlapping_boxes_bit_identical(series,
                                                            tmpdir_path):
    """N concurrent SeriesClients reading OVERLAPPING boxes must each get
    bytes identical to a direct BpReader.read_var of the same box."""
    path, truth = series
    boxes = [((0, 0), (64, 4)), ((8, 1), (40, 2)),
             ((0, 0), (32, 4)), ((16, 0), (48, 3))]
    with BpReader(path) as r:
        direct = [r.read_var(1, "var/x", o, e).tobytes() for o, e in boxes]
    errs, done = [], []
    with _daemon(path, tmpdir_path / "d.sock", parallel=2) as d:
        def client(i):
            try:
                with SeriesClient(d.address, path) as c:
                    for _ in range(3):
                        o, e = boxes[i]
                        got = c.read_var(1, "var/x", o, e)
                        assert got.tobytes() == direct[i]
                    done.append(i)
            except Exception as exc:  # noqa: BLE001
                errs.append(exc)

        ts = [threading.Thread(target=client, args=(i,)) for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30.0)
        assert not errs, errs
        assert sorted(done) == [0, 1, 2, 3]
        st = SeriesClient(d.address, path).stats()
        assert st["counters"]["SERVICE_CACHE_HIT"] > 0


def test_cache_hit_path_parity_after_eviction(series, tmpdir_path):
    """A budget too small for one step's chunks forces evictions between
    reads; re-reads (miss -> refetch) and any surviving hits must stay
    bit-identical to the direct read."""
    path, truth = series
    # the series holds 8 chunks x 256 B; a 1 KiB budget fits only 4
    with _daemon(path, tmpdir_path / "d.sock", cache_bytes=1024) as d:
        with SeriesClient(d.address, path) as c:
            for _ in range(3):
                for s in truth:
                    got = c.read_var(s, "var/x")
                    np.testing.assert_array_equal(got, truth[s])
            st = c.stats()["cache"]
            assert st["evictions"] > 0, "budget never forced an eviction"
    # ample budget: second read is all hits, still bit-identical
    with _daemon(path, tmpdir_path / "d2.sock") as d:
        with SeriesClient(d.address, path) as c:
            a = c.read_var(0, "var/x")
            b = c.read_var(0, "var/x")
            assert a.tobytes() == b.tobytes() == truth[0].tobytes()
            st = c.stats()["cache"]
            assert st["hits"] >= 4 and st["evictions"] == 0


def test_coalescing_counter_under_concurrent_identical_reads(series,
                                                             tmpdir_path,
                                                             monkeypatch):
    """Concurrent clients issuing IDENTICAL cold reads must share one
    fetch per chunk — the coalescing counter ends >= 1. A slowed fetch
    makes the overlap deterministic."""
    path, truth = series
    real_fetch = BpReader._fetch_chunk

    def slow_fetch(self, ch, dtype, local):
        time.sleep(0.15)
        return real_fetch(self, ch, dtype, local)

    monkeypatch.setattr(BpReader, "_fetch_chunk", slow_fetch)
    errs = []
    with _daemon(path, tmpdir_path / "d.sock") as d:
        def client():
            try:
                with SeriesClient(d.address, path) as c:
                    got = c.read_var(0, "var/x")
                    assert got.tobytes() == truth[0].tobytes()
            except Exception as exc:  # noqa: BLE001
                errs.append(exc)

        ts = [threading.Thread(target=client) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30.0)
        assert not errs, errs
        st = SeriesClient(d.address, path).stats()
        assert st["counters"]["SERVICE_COALESCED"] >= 1
        assert st["cache"]["coalesced"] >= 1


def test_shm_handoff_falls_back_to_socket_framing(series, tmpdir_path):
    """A response bigger than the connection's ring must arrive framed
    down the socket instead — same bytes, degraded transport."""
    path, truth = series
    with _daemon(path, tmpdir_path / "d.sock", ring_bytes=4096) as d:
        with SeriesClient(d.address, path) as c:
            small = c.read_var(0, "var/x", (0, 0), (16, 4))   # 256 B: shm
            np.testing.assert_array_equal(small, truth[0][:16])
            st = c.stats()["counters"]
            assert st["SERVICE_SHM_BYTES"] > 0
            assert st["SERVICE_SOCKET_BYTES"] == 0
    # a response bigger than the whole ring (16 KiB > 4 KiB capacity)
    big = _write(tmpdir_path / "big.bp4", n_ranks=4, cols=64, steps=1)
    with _daemon(tmpdir_path / "big.bp4", tmpdir_path / "d2.sock",
                 ring_bytes=4096) as d:
        with SeriesClient(d.address, tmpdir_path / "big.bp4") as c:
            got = c.read_var(0, "var/x")
            np.testing.assert_array_equal(got, big[0])
            st = c.stats()["counters"]
            assert st["SERVICE_SOCKET_BYTES"] >= got.nbytes


def test_client_shm_disabled_and_tcp_daemon(series, tmpdir_path):
    path, truth = series
    # unix socket, client opts out of shm
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, path, shm=False) as c:
            np.testing.assert_array_equal(c.read_var(0, "var/x"), truth[0])
    # TCP daemon: shm never negotiated
    server = SeriesServer([path])
    with JbpDaemon(server, port=0) as d:
        d.start()
        with SeriesClient(d.address, path) as c:
            np.testing.assert_array_equal(c.read_var(1, "var/x"), truth[1])
            assert c.stats()["counters"]["SERVICE_SHM_BYTES"] == 0


def test_corrupt_payload_maps_to_clean_error_response(tmpdir_path):
    """A bit-rotted chunk must surface as a 'corrupt-payload' error
    response — the connection and the daemon survive, and healthy
    variables remain readable."""
    w = BpWriter(tmpdir_path / "s.bp4", 2,
                 EngineConfig(aggregators=2, codec="zlib"))
    rng = np.random.default_rng(3)
    w.begin_step(0)
    ga = rng.normal(size=(32,)).astype(np.float32)
    gb = rng.normal(size=(32,)).astype(np.float32)
    for r in range(2):
        w.put("a", ga[r * 16:(r + 1) * 16], global_shape=(32,),
              offset=(r * 16,), rank=r)
        w.put("b", gb[r * 16:(r + 1) * 16], global_shape=(32,),
              offset=(r * 16,), rank=r)
    w.end_step()
    w.close()
    with BpReader(tmpdir_path / "s.bp4") as r:
        ch = next(c for c in r.iter_chunks(0, "b") if c.agg == 1)
    data = tmpdir_path / "s.bp4" / "data.1"
    raw = bytearray(data.read_bytes())
    for i in range(ch.file_offset, ch.file_offset + ch.nbytes):
        raw[i] ^= 0xFF
    data.write_bytes(bytes(raw))
    with _daemon(tmpdir_path / "s.bp4", tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, tmpdir_path / "s.bp4") as c:
            with pytest.raises(JbpdRequestError) as ei:
                c.read_var(0, "b")
            assert ei.value.kind == "corrupt-payload"
            np.testing.assert_array_equal(c.read_var(0, "a"), ga)


def test_client_survives_daemon_restart_with_clear_error(series,
                                                         tmpdir_path):
    path, truth = series
    sock = tmpdir_path / "d.sock"
    d1 = _daemon(path, sock)
    c = SeriesClient(d1.address, path)
    np.testing.assert_array_equal(c.read_var(0, "var/x"), truth[0])
    d1.stop()
    with pytest.raises(DaemonDisconnectedError, match="reconnect"):
        c.read_var(0, "var/x")
    # no daemon at all: still the clear error, not a bare OSError
    with pytest.raises(DaemonDisconnectedError, match="cannot reach"):
        c.ping()
    d2 = _daemon(path, sock)
    try:
        np.testing.assert_array_equal(c.read_var(1, "var/x"), truth[1])
    finally:
        c.close()
        d2.stop()


def test_unregistered_series_rejected_unless_open_any(series, tmpdir_path):
    path, truth = series
    other = _write(tmpdir_path / "o.bp4", steps=1)
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, tmpdir_path / "o.bp4") as c:
            with pytest.raises(JbpdRequestError) as ei:
                c.steps()
            assert ei.value.kind == "not-served"
    with _daemon(path, tmpdir_path / "d2.sock", open_any=True) as d:
        with SeriesClient(d.address, tmpdir_path / "o.bp4") as c:
            np.testing.assert_array_equal(c.read_var(0, "var/x"), other[0])


def test_daemon_shutdown_op_stops_daemon(series, tmpdir_path):
    path, _ = series
    d = _daemon(path, tmpdir_path / "d.sock")
    c = SeriesClient(d.address, path)
    assert c.ping()
    c.shutdown()
    deadline = time.time() + 5.0
    while not d._stopping.is_set() and time.time() < deadline:
        time.sleep(0.02)
    assert d._stopping.is_set()
    # once the accept loop is gone, new connections must be refused
    d._accept_thread.join(5.0)
    assert not d._accept_thread.is_alive()
    with pytest.raises(DaemonDisconnectedError):
        SeriesClient(d.address, path).ping()


def test_parallel_served_reads_use_reader_pool(series, tmpdir_path):
    """parallel=N on the server fans chunk fetches over the shared
    ReaderPool; results stay bit-identical."""
    path, truth = series
    with _daemon(path, tmpdir_path / "d.sock", parallel=4) as d:
        with SeriesClient(d.address, path) as c:
            for s in truth:
                assert c.read_var(s, "var/x").tobytes() == \
                    truth[s].tobytes()


def test_watch_does_not_starve_concurrent_calls(series, tmpdir_path):
    """Regression (jbplint JBP004): watch() used to hold the client's
    request lock for the whole count*interval stream, so a concurrent
    stats() from another thread stalled until the stream finished. The
    stream now runs on its own dedicated connection: stats() must answer
    in a fraction of the stream's duration, while the stream itself still
    delivers every frame."""
    path, _ = series
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, path) as c:
            got = {}

            def stream():
                got["watch"] = c.watch(interval_s=0.25, count=4)

            t = threading.Thread(target=stream, daemon=True)
            t.start()
            time.sleep(0.3)            # stream is mid-flight by now
            t0 = time.perf_counter()
            st = c.stats()             # must NOT wait out the ~1s stream
            latency = time.perf_counter() - t0
            t.join(10.0)
            assert not t.is_alive()
            assert latency < 0.5, f"stats() stalled {latency:.2f}s " \
                                  f"behind the watch stream"
            assert "series" in st or st  # a real stats payload came back
            assert len(got["watch"]["frames"]) == 4
            assert got["watch"]["begin"] is not None


# --------------------------------------------------------------- metrics plane
def test_metrics_op_matches_live_registry(series, tmpdir_path):
    """The `metrics` admin op returns the SAME deterministic percentiles
    the registry computes locally — and the reads the daemon just served
    show up on the serve-plane cells."""
    path, truth = series
    METRICS.enable()
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, path, shm=False) as c:
            for s in truth:
                c.read_var(s, "var/x")
            m = c.metrics()
    assert m["enabled"]
    ops = {ck.split("|")[0] for ck in m["hists"]}
    assert {"cache_fetch", "serve"} <= ops
    # same process here, so op percentiles == live registry percentiles
    from repro.core.metrics import summarize_cell
    live = {ck: summarize_cell(cell) for ck, cell in METRICS.merged().items()}
    for ck, s in m["percentiles"].items():
        assert s["count"] == live[ck]["count"], ck
        assert s["p99_s"] == live[ck]["p99_s"], ck
    # the op also carries the rendered exposition, and it parses
    promtext.validate(m["text"])
    assert isinstance(m["stragglers"], list)


def test_metrics_http_shim_serves_valid_exposition(series, tmpdir_path):
    path, truth = series
    METRICS.enable()
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, path, shm=False) as c:
            c.read_var(0, "var/x")
        with MetricsHttpShim(d.server, port=0) as shim:
            url = f"http://{shim.host}:{shim.port}/metrics"
            with urllib.request.urlopen(url) as resp:
                assert resp.status == 200
                assert resp.headers["Content-Type"].startswith("text/plain")
                text = resp.read().decode()
            samples, types = promtext.validate(text)
            assert types["jbp_latency_seconds"] == "histogram"
            assert types["jbp_counter_total"] == "counter"
            assert "jbp_uptime_seconds" in types
            names = {n for n, _, _ in samples}
            assert "jbp_latency_seconds_bucket" in names
            # anything but / or /metrics is a 404, not a traceback
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(
                    f"http://{shim.host}:{shim.port}/other")
            assert ei.value.code == 404


def test_watch_frames_carry_stragglers_key(series, tmpdir_path):
    path, _ = series
    METRICS.enable()
    with _daemon(path, tmpdir_path / "d.sock") as d:
        with SeriesClient(d.address, path, shm=False) as c:
            res = c.watch(interval_s=0.05, count=2)
    for frame in res["frames"]:
        assert isinstance(frame["stragglers"], list)


def _fd_count() -> int:
    return len(os.listdir("/proc/self/fd"))


def test_dial_closes_socket_on_non_oserror_handshake_failure(tmpdir_path):
    """Regression: `_dial` only closed the fresh socket on OSError, so a
    daemon dying in a way that surfaced as a NON-OSError — e.g. a garbage
    frame making json.loads blow up inside recv_msg — leaked one fd per
    attempt (watch() retry loops ground through them). Every failed
    handshake must now close the socket."""
    sock_path = str(tmpdir_path / "fake.sock")
    srv = socket.socket(socket.AF_UNIX)
    srv.bind(sock_path)
    srv.listen(32)
    stop = threading.Event()

    def garbage_daemon():
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except OSError:
                return
            with conn:
                try:
                    conn.recv(65536)                    # swallow the hello
                    blob = b"\x00this is not json"      # framed garbage
                    conn.sendall(FRAME.pack(len(blob), 0) + blob)
                    conn.recv(1)          # linger until the client closes
                except OSError:
                    pass

    t = threading.Thread(target=garbage_daemon, daemon=True)
    t.start()
    try:
        c = SeriesClient(sock_path, shm=False)
        with pytest.raises((DaemonDisconnectedError, ValueError)):
            c.ping()                                    # warm-up attempt
        base = _fd_count()
        for _ in range(20):
            with pytest.raises((DaemonDisconnectedError, ValueError)):
                c.ping()
        leaked = _fd_count() - base
        assert leaked <= 1, f"{leaked} fds leaked across 20 failed dials"
    finally:
        stop.set()
        srv.close()
        t.join(5.0)
