"""Metrics plane: bucket math, shard/snapshot/merge discipline, journal
parity, stragglers, Prometheus exposition, and the engine integrations.

The two acceptance contracts from PR 9 live here:

  * PARITY — p50/p95/p99 computed by `jbpstat` over a journal are
    IDENTICAL to the live registry's (and therefore to the jbpd
    `metrics` op's) values for the same run, because percentiles are
    deterministic functions of log2 bucket counts and the per-step
    journal deltas sum back to the cumulative exactly.
  * W=2 — a parallel-writer journal carries per-worker histograms whose
    write-cell counts match the merged Darshan per-file POSIX_WRITES.
"""
import json
import threading

import numpy as np
import promtext
import pytest

from repro.core import metrics as M
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.metrics import (METRICS, MetricsRegistry, RollingBaseline,
                                StepJournal, bucket_index, bucket_le,
                                load_journal, merge_cells, new_cell,
                                quantile_from_buckets, straggler_report,
                                sum_journal_hists, summarize_cell,
                                to_prometheus)
from repro.core.parallel_engine import ParallelBpWriter


# ------------------------------------------------------------- bucket math
def test_bucket_index_edges():
    # bucket 0 is <=1 unit; bucket i covers (2^(i-1), 2^i]
    assert bucket_index(0, 32) == 0
    assert bucket_index(1, 32) == 0
    assert bucket_index(2, 32) == 1
    assert bucket_index(3, 32) == 2
    assert bucket_index(4, 32) == 2
    assert bucket_index(5, 32) == 3
    for i in range(1, 30):
        # the upper edge itself lands in bucket i, edge+1 in bucket i+1
        assert bucket_index(bucket_le(i), 32) == i
        assert bucket_index(bucket_le(i) + 1, 32) == i + 1
    # clamp to the top bucket
    assert bucket_index(1 << 60, 32) == 31


def test_quantile_from_buckets():
    counts = [0] * 32
    counts[3] = 50       # 50 obs <= 8 units
    counts[7] = 50       # 50 obs <= 128 units
    assert quantile_from_buckets(counts, 0.50) == 8
    assert quantile_from_buckets(counts, 0.51) == 128
    assert quantile_from_buckets(counts, 0.99) == 128
    assert quantile_from_buckets([0] * 32, 0.5) is None


def test_quantile_is_upper_edge_conservative():
    # single observation of 5 units -> p50 is its bucket's UPPER edge (8)
    counts = [0] * 32
    counts[bucket_index(5, 32)] += 1
    assert quantile_from_buckets(counts, 0.5) == 8


# ---------------------------------------------------------------- registry
def test_observe_and_summarize():
    r = MetricsRegistry()
    r.enable()
    for us in (3, 5, 100, 2000):
        r.observe("write", us * 1e-6, nbytes=us * 10, key="f")
    cells = r.merged()
    assert set(cells) == {"write|f"}
    s = summarize_cell(cells["write|f"])
    assert s["count"] == 4
    assert s["max_s"] == pytest.approx(2000e-6)
    assert s["p50_s"] == pytest.approx(8e-6)      # 5us -> (4,8] bucket
    assert s["p99_s"] == pytest.approx(2048e-6)
    assert s["mean_s"] == pytest.approx((3 + 5 + 100 + 2000) * 1e-6 / 4)


def test_disabled_records_nothing():
    r = MetricsRegistry()
    r.disable()
    r.observe("write", 0.1, nbytes=100)
    with r.timer("read", key="x"):
        pass
    assert r.merged() == {}
    assert r.stats() == {"enabled": False, "cells": 0, "observations": 0}


def test_timer_records_and_nbytes_settable():
    r = MetricsRegistry()
    r.enable()
    with r.timer("compress", key="d0") as t:
        t.nbytes = 4096
    cells = r.merged()
    assert cells["compress|d0"]["count"] == 1
    assert cells["compress|d0"]["sum_b"] == 4096


def test_thread_shards_merge():
    r = MetricsRegistry()
    r.enable()

    def work():
        for _ in range(100):
            r.observe("read", 1e-5, key="t")

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    r.observe("read", 1e-5, key="t")           # main thread's own shard
    assert r.merged()["read|t"]["count"] == 401


def test_snapshot_reset_retires_delta():
    """The parity keystone: reset-snapshots ship deltas, merged() never
    forgets — sum of the shipped deltas == the live cumulative."""
    r = MetricsRegistry()
    r.enable()
    shipped = []
    for step in range(3):
        for _ in range(5):
            r.observe("write", 1e-4, nbytes=512, key="f")
        shipped.append(r.snapshot(reset=True)["hists"])
    assert all(h["write|f"]["count"] == 5 for h in shipped)
    # live cumulative unchanged by the resets
    assert r.merged()["write|f"]["count"] == 15
    # the shipped deltas sum back to the same cumulative
    acc = {}
    for h in shipped:
        merge_cells(acc, h)
    assert acc["write|f"]["count"] == 15
    assert acc["write|f"]["lat"] == r.merged()["write|f"]["lat"]


def test_epoch_rebase_makes_timestamps_wall():
    import time
    r = MetricsRegistry()
    r.enable()
    before = time.time()
    r.observe("write", 1e-4)
    after = time.time()
    cell = r.snapshot()["hists"]["write|"]
    assert before - 1.0 <= cell["t0"] <= after + 1.0
    assert cell["t0"] <= cell["t1"]


def test_merge_foreign_snapshot_and_legacy_bare_hists():
    a = MetricsRegistry()
    a.enable()
    a.observe("write", 1e-4, key="f")
    snap = a.snapshot()
    b = MetricsRegistry()
    b.merge(snap)                                  # full snapshot form
    b.merge(snap["hists"])                         # bare-hists form
    b.merge(None)                                  # tolerated
    b.merge({})                                    # tolerated
    assert b.merged()["write|f"]["count"] == 2


def test_merged_is_deterministic_percentile_source():
    """Same buckets -> same percentiles regardless of which view computes
    them (live vs round-tripped through JSON, the journal path)."""
    r = MetricsRegistry()
    r.enable()
    rng = np.random.default_rng(7)
    for us in rng.integers(1, 100000, size=500):
        r.observe("read", int(us) * 1e-6, key="f")
    live = {ck: summarize_cell(c) for ck, c in r.merged().items()}
    wire = json.loads(json.dumps(r.merged()))
    rt = {ck: summarize_cell(c) for ck, c in wire.items()}
    assert live == rt


# -------------------------------------------------------------- stragglers
def _cell_with_p99(us: int, n: int = 10) -> dict:
    c = new_cell()
    c["count"] = n
    c["lat"][bucket_index(us, M.NB_LAT)] = n
    return c


def test_straggler_report_flags_slow_peer():
    cells = {"write|ost0": _cell_with_p99(100),
             "write|ost1": _cell_with_p99(110),
             "write|ost2": _cell_with_p99(3000),
             "read|only_key": _cell_with_p99(99999)}   # <2 peers: exempt
    rep = straggler_report(cells)
    assert len(rep) == 1
    e = rep[0]
    assert (e["op"], e["key"]) == ("write", "ost2")
    assert e["ratio"] >= 2.0
    assert e["p99_s"] == pytest.approx(4096e-6)


def test_straggler_report_min_count_gate():
    cells = {"write|a": _cell_with_p99(100, n=2),
             "write|b": _cell_with_p99(5000, n=2)}
    assert straggler_report(cells) == []


def test_rolling_baseline_flags_self_regression():
    rb = RollingBaseline(baseline_ratio=3.0)
    # two healthy rounds build the EWMA; peers degrade TOGETHER in round 3
    for _ in range(2):
        rep = rb.update({"write|a": _cell_with_p99(100),
                         "write|b": _cell_with_p99(100)})
        assert rep == []
    rep = rb.update({"write|a": _cell_with_p99(4000),
                     "write|b": _cell_with_p99(4000)})
    # peer-median is blind (both slow); the baseline catches both
    assert {e["key"] for e in rep} == {"a", "b"}
    assert all(e.get("vs_baseline") for e in rep)


# -------------------------------------------------------------- prometheus
def test_to_prometheus_valid_exposition():
    r = MetricsRegistry()
    r.enable()
    r.observe("write", 3e-4, nbytes=4096, key='we"ird\\path\n')
    r.observe("fsync", 2e-3, key="f")
    text = to_prometheus(r.merged(),
                         counters={"POSIX_WRITES": 2.0},
                         gauges={"uptime_seconds": 1.5})
    samples, types = promtext.validate(text)
    assert types["jbp_latency_seconds"] == "histogram"
    assert types["jbp_size_bytes"] == "histogram"
    assert types["jbp_counter_total"] == "counter"
    assert types["jbp_uptime_seconds"] == "gauge"
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    # label escaping round-trips through the parser
    keys = {lb["key"] for lb, _ in by_name["jbp_latency_seconds_count"]}
    assert 'we"ird\\path\n' in keys
    # +Inf bucket == count for every series (validate() checked shape)
    assert all(v in (1.0,) for _, v in by_name["jbp_latency_seconds_count"])


def test_to_prometheus_empty_is_valid():
    samples, types = promtext.validate(to_prometheus({}))
    assert samples == []


# ----------------------------------------------------------------- journal
def test_step_journal_roundtrip(tmpdir_path):
    p = tmpdir_path / "metrics.jsonl"
    j = StepJournal(p)
    r = MetricsRegistry()
    r.enable()
    r.observe("write", 1e-4, key="f")
    j.frame(0, {"write_s": 0.5}, {"POSIX_WRITES": 3.0},
            r.snapshot(reset=True)["hists"])
    r.observe("write", 2e-4, key="f")
    j.frame(1, {"write_s": 0.6}, {"POSIX_WRITES": 7.0},
            r.snapshot(reset=True)["hists"],
            workers={0: {"hists": {"write|w0": _cell_with_p99(100)}}})
    j.close()
    frames = load_journal(p)
    assert [f["step"] for f in frames] == [0, 1]
    # counters are stored as deltas vs the previous frame
    assert frames[0]["counters"]["POSIX_WRITES"] == 3.0
    assert frames[1]["counters"]["POSIX_WRITES"] == 4.0
    assert "stragglers" in frames[0]
    cum = sum_journal_hists(frames)
    assert cum["write|f"]["count"] == 2
    assert cum["write|w0"]["count"] == 10
    # load_journal accepts the series DIRECTORY too
    assert load_journal(tmpdir_path) == frames


def test_load_journal_rejects_foreign_jsonl(tmpdir_path):
    p = tmpdir_path / "metrics.jsonl"
    p.write_text('{"format": "something-else"}\n')
    with pytest.raises(ValueError, match="not a jbp metrics journal"):
        load_journal(p)
    with pytest.raises(FileNotFoundError):
        load_journal(tmpdir_path / "nope.jsonl")


# ----------------------------------------------- engine integration (serial)
def _write(path, n_ranks=4, steps=3, writer=BpWriter, **kw):
    cfg = EngineConfig(aggregators=2, workers=2, codec="blosc")
    w = writer(path, n_ranks, cfg, **kw)
    rng = np.random.default_rng(3)
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(n_ranks * 16, 4)).astype(np.float32)
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16], global_shape=g.shape,
                  offset=(r * 16, 0), rank=r)
        w.end_step()
    w.close()


def test_serial_writer_journal_parity(tmpdir_path):
    """Acceptance: Σ(journal frames) == live merged() — and therefore the
    percentiles jbpstat computes equal the live (jbpd `metrics` op)
    ones."""
    METRICS.enable()
    _write(tmpdir_path / "s.bp4")
    frames = load_journal(tmpdir_path / "s.bp4")
    assert frames[-1]["step"] == -1            # close-time residual frame
    cum = sum_journal_hists(frames)
    merged = METRICS.merged()
    assert set(cum) == set(merged)
    for ck in cum:
        assert cum[ck]["count"] == merged[ck]["count"], ck
        assert cum[ck]["lat"] == merged[ck]["lat"], ck
        assert summarize_cell(cum[ck]) == summarize_cell(merged[ck]), ck
    # the instrumented ops all showed up
    ops = {ck.split("|")[0] for ck in cum}
    assert {"write", "fsync", "compress", "seal"} <= ops


def test_journal_absent_when_metrics_disabled(tmpdir_path):
    _write(tmpdir_path / "s.bp4")
    assert not (tmpdir_path / "s.bp4" / "metrics.jsonl").exists()
    # and the write itself recorded nothing
    assert METRICS.merged() == {}


def test_journal_does_not_break_reader(tmpdir_path):
    METRICS.enable()
    _write(tmpdir_path / "s.bp4")
    r = BpReader(tmpdir_path / "s.bp4")
    assert r.valid_steps() == [0, 1, 2]
    r.read_var(0, "var/x")


# --------------------------------------------- engine integration (parallel)
def test_parallel_writer_journal_w2_acceptance(tmpdir_path):
    """The W=2 criterion: the journal carries per-worker histograms whose
    write-cell bucket sums match the merged Darshan per-file counters."""
    METRICS.enable()
    _write(tmpdir_path / "s.bp4", writer=ParallelBpWriter, n_writers=2)
    frames = load_journal(tmpdir_path / "s.bp4")
    wids = {wid for f in frames for wid in f.get("workers", {})}
    assert wids == {"0", "1"}
    # journal == live parity holds across process boundaries too
    cum = sum_journal_hists(frames)
    merged = METRICS.merged()
    assert set(cum) == set(merged)
    for ck in cum:
        assert cum[ck]["count"] == merged[ck]["count"], ck
        assert cum[ck]["lat"] == merged[ck]["lat"], ck
    # per-worker write cells vs merged Darshan POSIX_WRITES per file:
    # every file a worker wrote is attributed identically in both planes
    per_file = MONITOR.report()["files"]
    wr_by_file: dict[str, int] = {}
    for f in frames:
        for cells in f.get("workers", {}).values():
            for ck, cell in cells.items():
                op, _, path = ck.partition("|")
                if op == "write":
                    wr_by_file[path] = wr_by_file.get(path, 0) + cell["count"]
    assert wr_by_file, "workers shipped no write cells"
    for path, n in wr_by_file.items():
        assert n == per_file[path]["POSIX_WRITES"], path
    # per-worker transport + per-aggregator compress keys feed stragglers
    ops = {ck.split("|")[0] for ck in cum}
    assert {"transport", "prepare", "commit", "shm_write"} <= ops
