"""ReaderPool + parallel read plane: pool scheduling semantics (affinity,
work stealing, error surfacing), byte parity of `read_var(parallel=N)`
with serial reads across codecs/layouts, and the wiring through Series,
reduce_posthoc and checkpoint restore."""
import threading
import time

import numpy as np
import pytest

from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.reader_pool import ReaderPool
from repro.core.striping import StripeConfig


# ----------------------------------------------------------------- pool unit
def test_pool_runs_every_task_with_affinity():
    pool = ReaderPool(3)
    hits = {}
    lock = threading.Lock()

    def task(key):
        with lock:
            hits.setdefault(key, []).append(threading.current_thread().name)

    for i in range(30):
        pool.submit(i % 5, task, i % 5)
    pool.drain()
    pool.shutdown()
    # every task ran exactly once, keyed correctly (which worker ran it is
    # scheduling-dependent — stealing may legally drain everything on one)
    assert sorted(hits) == [0, 1, 2, 3, 4]
    assert all(len(v) == 6 for v in hits.values())


def test_pool_steals_from_straggler_queue():
    """Every task is submitted with ONE affinity (one owner worker); with
    4 workers and blocking tasks, idle workers must steal — total wall
    time bounds prove >1 worker participated."""
    pool = ReaderPool(4)
    ran = []
    lock = threading.Lock()

    def task(i):
        time.sleep(0.05)
        with lock:
            ran.append(threading.current_thread().name)

    t0 = time.perf_counter()
    for i in range(8):
        pool.submit(0, task, i)          # all owned by worker 0
    pool.drain()
    wall = time.perf_counter() - t0
    pool.shutdown()
    assert len(ran) == 8
    assert len(set(ran)) > 1, "no work stealing happened"
    assert wall < 8 * 0.05, f"tasks ran fully serially ({wall:.2f}s)"


def test_pool_error_surfaced_in_drain_pool_survives():
    pool = ReaderPool(2)

    def boom():
        raise ValueError("injected")

    pool.submit(0, boom)
    with pytest.raises(ValueError, match="injected"):
        pool.drain()
    done = []
    pool.submit(1, done.append, 1)       # pool must still be usable
    pool.drain()
    assert done == [1]
    pool.shutdown()


def test_pool_batches_isolate_errors():
    """Two callers sharing one pool: a failure in one caller's batch must
    surface in THAT caller's drain_batch only — never in the other's (and
    never vanish)."""
    pool = ReaderPool(2)
    good, bad = pool.batch(), pool.batch()

    def boom():
        raise ValueError("bad batch task")

    done = []
    for _ in range(4):
        pool.submit(0, done.append, 1, batch=good)
        pool.submit(1, boom, batch=bad)
    pool.drain_batch(good)                   # must not see bad's error
    assert done == [1, 1, 1, 1]
    with pytest.raises(ValueError, match="bad batch task"):
        pool.drain_batch(bad)
    pool.drain()                             # global barrier: also clean
    pool.shutdown()


def test_failed_parallel_read_does_not_poison_later_reads(tmpdir_path):
    """A corrupt chunk must raise from ITS read_var call; subsequent
    parallel reads of healthy variables on the same reader/pool succeed."""
    w = BpWriter(tmpdir_path / "s.bp4", 4,
                 EngineConfig(aggregators=2, codec="zlib"))
    rng = np.random.default_rng(2)
    w.begin_step(0)
    ga = rng.normal(size=(64,)).astype(np.float32)
    gb = rng.normal(size=(64,)).astype(np.float32)
    for r in range(4):
        w.put("a", ga[r * 16:(r + 1) * 16], global_shape=(64,),
              offset=(r * 16,), rank=r)
        w.put("b", gb[r * 16:(r + 1) * 16], global_shape=(64,),
              offset=(r * 16,), rank=r)
    w.end_step()
    w.close()
    with BpReader(tmpdir_path / "s.bp4") as r:
        ch = next(c for c in r.iter_chunks(0, "b") if c.agg == 1)
        data = tmpdir_path / "s.bp4" / "data.1"
        raw = bytearray(data.read_bytes())
        for i in range(ch.file_offset, ch.file_offset + ch.nbytes):
            raw[i] ^= 0xFF                   # corrupt ONLY b's chunk
        data.write_bytes(bytes(raw))
        with pytest.raises(Exception):
            r.read_var(0, "b", parallel=4)
        got = r.read_var(0, "a", parallel=4)  # same pool, healthy var
        np.testing.assert_array_equal(got, ga)


def test_pool_submit_after_shutdown_rejected():
    pool = ReaderPool(1)
    pool.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        pool.submit(0, lambda: None)


# ------------------------------------------------------------- read parity
def _write(path, *, n_ranks=8, aggregators=4, codec="none", steps=2,
           stripe=None, cols=4):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=3,
                       stripe=stripe, n_osts=4)
    w = BpWriter(path, n_ranks, cfg)
    rng = np.random.default_rng(5)
    truth = {}
    rows = n_ranks * 16
    for s in range(steps):
        w.begin_step(s)
        g = rng.normal(size=(rows, cols)).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * 16:(r + 1) * 16],
                  global_shape=g.shape, offset=(r * 16, 0), rank=r)
        w.end_step()
    w.close()
    return truth


@pytest.mark.parametrize("codec", ["none", "blosc", "zlib"])
def test_parallel_read_bit_parity(tmpdir_path, codec):
    """read_var(parallel=4) over an 8-chunk box must return bytes
    IDENTICAL to the serial read — full arrays and partial boxes."""
    truth = _write(tmpdir_path / "s.bp4", codec=codec)
    with BpReader(tmpdir_path / "s.bp4") as r:
        for s in truth:
            a = r.read_var(s, "var/x")
            b = r.read_var(s, "var/x", parallel=4)
            assert a.tobytes() == b.tobytes()
            np.testing.assert_array_equal(b, truth[s])
        sel_serial = r.read_var(1, "var/x", offset=(8, 1), extent=(100, 2))
        sel_par = r.read_var(1, "var/x", offset=(8, 1), extent=(100, 2),
                             parallel=4)
        assert sel_serial.tobytes() == sel_par.tobytes()
        np.testing.assert_array_equal(sel_par, truth[1][8:108, 1:3])


def test_parallel_read_constructor_default(tmpdir_path):
    truth = _write(tmpdir_path / "s.bp4")
    with BpReader(tmpdir_path / "s.bp4", parallel=3) as r:
        assert r.default_parallel == 3
        np.testing.assert_array_equal(r.read_var(0, "var/x"), truth[0])
        # per-call override back to serial
        np.testing.assert_array_equal(
            r.read_var(0, "var/x", parallel=0), truth[0])


def test_parallel_read_striped_layout(tmpdir_path):
    truth = _write(tmpdir_path / "s.bp4", n_ranks=4, aggregators=2,
                   stripe=StripeConfig(stripe_count=2, stripe_size=256))
    with BpReader(tmpdir_path / "s.bp4") as r:
        a = r.read_var(1, "var/x")
        b = r.read_var(1, "var/x", parallel=4)
        assert a.tobytes() == b.tobytes()
        np.testing.assert_array_equal(b, truth[1])


def test_parallel_read_empty_selection_zero_payload_io(tmpdir_path):
    _write(tmpdir_path / "s.bp4")
    with BpReader(tmpdir_path / "s.bp4") as r:
        MONITOR.reset()
        out = r.read_var(0, "var/x", offset=(0, 0), extent=(0, 0),
                         parallel=4)
        assert out.size == 0
        files = MONITOR.report()["files"]
        assert not any("data." in p and c.get("POSIX_BYTES_READ", 0) > 0
                       for p, c in files.items())


def test_reader_close_releases_pool_and_thread_handles(tmpdir_path):
    truth = _write(tmpdir_path / "s.bp4")
    r = BpReader(tmpdir_path / "s.bp4")
    r.read_var(0, "var/x", parallel=4)
    pool = r._pool
    assert pool is not None and len(r._side_handles) > 0
    r.close()
    assert r._pool is None and r._side_handles == []
    with pytest.raises(RuntimeError, match="shut down"):
        pool.submit(0, lambda: None)
    # metadata stays queryable and payload handles reopen lazily
    np.testing.assert_array_equal(r.read_var(1, "var/x", parallel=2),
                                  truth[1])
    r.close()


def test_pool_grows_on_larger_request(tmpdir_path):
    _write(tmpdir_path / "s.bp4")
    with BpReader(tmpdir_path / "s.bp4") as r:
        r.read_var(0, "var/x", parallel=2)
        assert r._pool.n_workers == 2
        r.read_var(0, "var/x", parallel=4)
        assert r._pool.n_workers == 4
        r.read_var(0, "var/x", parallel=2)     # smaller request: reuse
        assert r._pool.n_workers == 4


# ----------------------------------------------------------------- wiring
def test_series_parallel_read(tmpdir_path):
    from repro.core.openpmd import Series
    with Series(tmpdir_path / "d.bp4", "w", n_ranks=4,
                engine_config=EngineConfig(aggregators=2)) as s:
        rc = s.iterations[0].meshes["density"][""]
        arr = np.linspace(0, 1, 64, dtype=np.float32)
        rc.reset_dataset(arr.dtype, arr.shape)
        for r in range(4):
            rc.store_chunk(arr[r * 16:(r + 1) * 16], offset=(r * 16,),
                           rank=r)
        s.flush()
    sr = Series(tmpdir_path / "d.bp4", "r", parallel_read=4)
    assert sr._reader().default_parallel == 4
    got = sr.iterations[0].meshes["density"][""].load_chunk()
    np.testing.assert_array_equal(got, arr)
    sr.close()


def test_reduce_posthoc_parallel_parity(tmpdir_path):
    from repro.insitu.reducers import Moments, ReducerSet
    from repro.insitu.runner import reduce_posthoc
    _write(tmpdir_path / "s.bp4", codec="blosc")
    serial = reduce_posthoc(tmpdir_path / "s.bp4",
                            ReducerSet([Moments("var/x")]))
    par = reduce_posthoc(tmpdir_path / "s.bp4",
                         ReducerSet([Moments("var/x")]), parallel=4)
    from repro.insitu.runner import assert_parity
    assert_parity(serial, par)


def test_reduce_posthoc_closes_reader_on_reducer_error(tmpdir_path):
    """The exception-path cleanup contract: a reducer blowing up mid-replay
    must not leak the reader's pool/handles (context manager throughout)."""
    from repro.insitu.reducers import ReducerSet
    from repro.insitu.runner import reduce_posthoc

    _write(tmpdir_path / "s.bp4")
    seen = {}
    real_close = BpReader.close

    def tracking_close(self):
        seen["closed"] = True
        real_close(self)

    class BoomSet(ReducerSet):
        def update(self, step, vars):
            raise RuntimeError("reducer exploded")

    BpReader.close = tracking_close
    try:
        with pytest.raises(RuntimeError, match="reducer exploded"):
            reduce_posthoc(tmpdir_path / "s.bp4", BoomSet([]), parallel=2)
    finally:
        BpReader.close = real_close
    assert seen.get("closed"), "reader not closed on the exception path"


def test_reduce_posthoc_leaves_caller_reader_open(tmpdir_path):
    from repro.insitu.reducers import Moments, ReducerSet
    from repro.insitu.runner import reduce_posthoc
    truth = _write(tmpdir_path / "s.bp4")
    with BpReader(tmpdir_path / "s.bp4") as r:
        reduce_posthoc(r, ReducerSet([Moments("var/x")]))
        # still usable: posthoc over a caller-owned reader must not close it
        np.testing.assert_array_equal(r.read_var(0, "var/x"), truth[0])


def test_restore_checkpoint_parallel(tmpdir_path):
    from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
    state = {"w": np.arange(256, dtype=np.float32).reshape(16, 16),
             "b": np.ones(16, dtype=np.float32)}
    save_checkpoint(tmpdir_path, state, 3, n_io_ranks=4,
                    engine_config=EngineConfig(aggregators=2, codec="blosc"))
    like = {k: np.zeros_like(v) for k, v in state.items()}
    restored, step = restore_checkpoint(tmpdir_path, like, parallel=4)
    assert step == 3
    for k in state:
        np.testing.assert_array_equal(restored[k], state[k])


def test_idle_pool_never_wakes():
    """Regression: idle workers used to spin on `cond.wait(timeout=0.1)`,
    waking ~10N times/sec forever — a daemon hosting a pool burned CPU at
    rest. Waits are now purely notification-driven: an idle pool must show
    ZERO wakeups."""
    pool = ReaderPool(4)
    try:
        time.sleep(0.6)                # ~24 spurious wakeups under the old spin
        assert pool.wakeups == 0
        done = []
        pool.submit(0, lambda: done.append(1))
        pool.drain()
        assert done == [1]
        time.sleep(0.3)                # let every notified worker re-park
        woke = pool.wakeups
        assert woke >= 1               # real work does wake workers
        time.sleep(0.4)                # ...and idling again stays silent
        assert pool.wakeups == woke
    finally:
        pool.shutdown()
