"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitshuffle import ops as bops
from repro.kernels.bitshuffle.ref import byte_shuffle_ref
from repro.kernels.deposit import ops as dops
from repro.kernels.deposit.ref import deposit_ref
from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention.ref import flash_ref_headmajor, reference_attention
from repro.kernels.ssd_scan import ops as sops
from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_recurrent_reference


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("S", [128, 256, 320])
@pytest.mark.parametrize("D", [32, 64, 128])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_shapes(S, D, causal):
    key = jax.random.PRNGKey(S + D)
    B, H = 2, 2
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D),
                                 jnp.float32) for i in range(3))
    got = fops.flash_attention(q, k, v, causal=causal, qc=128, kc=128)
    ref = reference_attention(q, k, v, causal=causal)
    assert jnp.max(jnp.abs(got - ref)) < 2e-5, (S, D, causal)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    B, S, H, D = 1, 256, 2, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D),
                                 jnp.float32).astype(dtype) for i in range(3))
    got = fops.flash_attention(q, k, v, qc=128, kc=128)
    ref = reference_attention(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.max(jnp.abs(got.astype(jnp.float32) -
                           ref.astype(jnp.float32))) < tol


# ------------------------------------------------------------------ ssd_scan
@pytest.mark.parametrize("s,chunk", [(128, 64), (256, 128), (192, 64)])
@pytest.mark.parametrize("p,n", [(32, 16), (64, 32)])
def test_ssd_kernel_shapes(s, chunk, p, n):
    key = jax.random.PRNGKey(s + p)
    b, h = 2, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    D = jnp.ones((h,))
    got = sops.ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    ref, _ = ssd_recurrent_reference(x, dt, A, B, C, D)
    assert jnp.max(jnp.abs(got - ref.astype(jnp.float32))) < 5e-2


# ---------------------------------------------------------------- bitshuffle
@pytest.mark.parametrize("itemsize", [2, 4, 8])
@pytest.mark.parametrize("n_bytes", [4096, 40_000, 123_456])
def test_bitshuffle_kernel(itemsize, n_bytes):
    rng = np.random.default_rng(n_bytes)
    n_bytes -= n_bytes % itemsize
    data = jnp.asarray(rng.integers(0, 256, n_bytes, dtype=np.uint8))
    shuf, n = bops.shuffle(data, itemsize=itemsize)
    pad = (-n_bytes) % (itemsize * 1024)
    ref = byte_shuffle_ref(jnp.pad(data, (0, pad)), itemsize=itemsize)
    assert (shuf == ref).all()
    back = bops.unshuffle(shuf, n, itemsize=itemsize)
    assert (back == data).all()


# ------------------------------------------------------------------- deposit
@pytest.mark.parametrize("n,n_cells", [(2000, 128), (5000, 300), (1024, 1024)])
def test_deposit_kernel(n, n_cells):
    rng = np.random.default_rng(n)
    dx = 1.0 / n_cells
    x = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    alive = jnp.asarray((rng.uniform(0, 1, n) > 0.25).astype(np.float32))
    got = dops.deposit(x, w, alive, n_cells=n_cells, dx=dx)
    ref = deposit_ref(x, w, alive, n_cells, dx)
    rel = jnp.max(jnp.abs(got - ref)) / jnp.maximum(jnp.max(jnp.abs(ref)), 1e-9)
    assert rel < 1e-4


def test_deposit_conserves_charge():
    rng = np.random.default_rng(9)
    n, n_cells = 4096, 256
    dx = 1.0 / n_cells
    x = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    w = jnp.ones((n,), jnp.float32)
    alive = jnp.ones((n,), jnp.float32)
    rho = dops.deposit(x, w, alive, n_cells=n_cells, dx=dx)
    assert abs(float(jnp.sum(rho) * dx) - n) / n < 1e-5

@pytest.mark.parametrize("itemsize", [2, 4, 8])
@pytest.mark.parametrize("n_items", [1, 7, 512, 16384, 65521])
def test_bitshuffle_block_vs_numpy_oracle(itemsize, n_items):
    """shuffle_block (whole-block, one grid point — the device compression
    path) against the host numpy shuffle it must be bit-compatible with."""
    from repro.core.compression import byte_shuffle
    rng = np.random.default_rng(itemsize * 100 + n_items)
    raw = rng.integers(0, 256, n_items * itemsize, dtype=np.uint8)
    got = np.asarray(bops.shuffle_block(jnp.asarray(raw), itemsize=itemsize))
    oracle = np.frombuffer(byte_shuffle(raw.tobytes(), itemsize), np.uint8)
    np.testing.assert_array_equal(got, oracle)


def test_bitshuffle_block_rejects_ragged_length():
    with pytest.raises(ValueError):
        bops.shuffle_block(jnp.zeros(10, jnp.uint8), itemsize=4)


def test_bitshuffle_block_property_dtype_views():
    """Property sweep: for real dtype arrays (as the write path sees them),
    device shuffle of the byte view == numpy oracle, odd lengths included."""
    from repro.core.compression import byte_shuffle
    rng = np.random.default_rng(99)
    for dtype in (np.float16, np.float32, np.float64, np.int32, np.uint64):
        for n in (3, 100, 1000, 4097):
            arr = rng.normal(size=n) * 100
            arr = arr.astype(dtype)
            raw = arr.view(np.uint8).reshape(-1)
            got = np.asarray(bops.shuffle_block(
                jnp.asarray(raw), itemsize=arr.dtype.itemsize))
            oracle = np.frombuffer(
                byte_shuffle(raw.tobytes(), arr.dtype.itemsize), np.uint8)
            np.testing.assert_array_equal(got, oracle, err_msg=f"{dtype} {n}")


def test_device_precondition_matches_host_per_block():
    """Block boundaries fixed at precondition time must mirror the host
    encoder: a block whose length is not a multiple of itemsize passes
    through UNshuffled on both sides."""
    from repro.core import compression as C
    rng = np.random.default_rng(5)
    arr = rng.normal(size=1001).astype(np.float32)      # 4004 bytes
    block = 999                                         # 999 % 4 != 0
    chunk = C.device_precondition(jnp.asarray(arr), block=block)
    host = b"".join(
        C.byte_shuffle(arr.tobytes()[i:i + block], 4)
        for i in range(0, arr.nbytes, block))
    assert chunk.data.tobytes() == host
