"""In-situ subsystem: reducer correctness, stream/post-hoc parity, the
BpReader metadata query layer, jbpls O(metadata) listing, and the
SstStream close/timeout fixes."""
import queue
import threading
import time

import numpy as np
import pytest

from repro.core.async_engine import AsyncBpWriter
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.sst_engine import SstStream, attach_consumer
from repro.insitu import (FieldEnergy, Histogram, Moments, PhaseSpace2D,
                          ReducerSet, SpeciesCount, assert_parity,
                          attach_reducers, reduce_posthoc)
from repro.tools import jbpls


def _subfile_reads() -> float:
    """Total read ops+bytes recorded against any data.* subfile."""
    files = MONITOR.report()["files"]
    return sum(c.get("POSIX_READS", 0) + c.get("POSIX_BYTES_READ", 0)
               for p, c in files.items() if "data." in p)


def _produce_stream(stream, *, n_steps, n_ranks=4, n=64, seed=0):
    """Deterministic multi-rank producer; returns the per-step truth."""
    rng = np.random.default_rng(seed)
    truth = {}
    per = n // n_ranks
    for s in range(n_steps):
        g = rng.normal(size=(n,)).astype(np.float32)
        w = rng.uniform(size=(n,)).astype(np.float32)
        truth[s] = {"density/e": g, "weight/e": w}
        stream.begin_step(s)
        for r in range(n_ranks):
            sl = slice(r * per, (r + 1) * per)
            stream.put("density/e", g[sl], global_shape=(n,),
                       offset=(r * per,), rank=r)
            stream.put("weight/e", w[sl], global_shape=(n,),
                       offset=(r * per,), rank=r)
        stream.end_step()
    return truth


def _reducer_suite():
    return ReducerSet([
        Moments("density/e"),
        Histogram("density/e", bins=32, range=(-4.0, 4.0)),
        Histogram("density/e", bins=16, range=(-4.0, 4.0),
                  weight_var="weight/e", name="weighted_hist"),
        PhaseSpace2D("density/e", "weight/e", bins=(8, 8),
                     range=((-4.0, 4.0), (0.0, 1.0))),
        FieldEnergy("density/e", cell_volume=0.5),
        SpeciesCount("weight/e", scale=2.0),
    ])


# ------------------------------------------------------------- reducer math
def test_moments_matches_numpy():
    r = Moments("x")
    chunks = [np.arange(10, dtype=np.float64), np.linspace(-3, 5, 7)]
    for s, a in enumerate(chunks):
        r.update(s, {"x": a})
    allv = np.concatenate(chunks)
    res = r.result()
    assert res["n"] == allv.size and res["steps"] == 2
    np.testing.assert_allclose(res["mean"], allv.mean())
    np.testing.assert_allclose(res["var"], allv.var(), rtol=1e-12)
    assert res["min"] == allv.min() and res["max"] == allv.max()


def test_histogram_matches_numpy():
    r = Histogram("x", bins=20, range=(-2.0, 2.0))
    vals = [np.random.default_rng(i).normal(size=100) for i in range(3)]
    for s, a in enumerate(vals):
        r.update(s, {"x": a})
    expect, edges = np.histogram(np.concatenate(vals), bins=20,
                                 range=(-2.0, 2.0))
    res = r.result()
    np.testing.assert_array_equal(res["counts"], expect.astype(np.float64))
    np.testing.assert_array_equal(res["edges"], edges)


def test_reducers_skip_missing_vars():
    rset = _reducer_suite()
    rset.update(0, {"unrelated": np.ones(4)})
    res = rset.results()
    assert res["moments(density/e)"]["n"] == 0
    assert res["count(weight/e)"]["steps"].size == 0


def test_reducer_set_needed_vars():
    assert _reducer_suite().needed_vars == {"density/e", "weight/e"}


# ---------------------------------------------------------------- parity
@pytest.mark.parametrize("codec", ["none", "blosc"])
def test_parity_stream_vs_posthoc(tmpdir_path, codec):
    """The acceptance guarantee: a live reduction over SstStream equals the
    post-hoc replay over BpReader on the teed series, bit for bit."""
    path = tmpdir_path / "teed.bp4"
    tee = AsyncBpWriter(path, 4, EngineConfig(aggregators=2, codec=codec))
    stream = SstStream(queue_depth=2, tee=tee)
    live = _reducer_suite()
    t = attach_reducers(stream, live)
    _produce_stream(stream, n_steps=25)
    stream.close()
    t.join(timeout=30)
    assert not t.is_alive()

    posthoc = reduce_posthoc(str(path), _reducer_suite())
    assert_parity(live.results(), posthoc)


def test_parity_detects_divergence():
    a = ReducerSet([Moments("x")])
    b = ReducerSet([Moments("x")])
    a.update(0, {"x": np.ones(4)})
    b.update(0, {"x": np.zeros(4)})
    with pytest.raises(AssertionError, match="moments"):
        assert_parity(a.results(), b.results())


def test_reduce_posthoc_reads_only_needed_vars(tmpdir_path):
    """`needs` declarations prune the replay's payload reads."""
    path = tmpdir_path / "s.bp4"
    w = BpWriter(path, 2, EngineConfig(aggregators=2))
    for s in range(3):
        w.begin_step(s)
        for name in ("wanted", "ignored"):
            for r in range(2):
                w.put(name, np.full(8, s, np.float32), global_shape=(16,),
                      offset=(r * 8,), rank=r)
        w.end_step()
    w.close()
    seen = []
    reader = BpReader(path)
    orig = reader.read_var
    reader.read_var = lambda step, name, *a, **k: (
        seen.append(name), orig(step, name, *a, **k))[1]
    reduce_posthoc(reader, ReducerSet([Moments("wanted")]))
    assert set(seen) == {"wanted"}


# ------------------------------------------------- metadata query layer
def _write_series(path, *, n_ranks=8, aggregators=3, codec="blosc", steps=2,
                  n=128):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=3)
    w = BpWriter(path, n_ranks, cfg)
    rng = np.random.default_rng(7)
    truth = {}
    per = n // n_ranks
    for s in range(steps):
        w.begin_step(s)
        g = np.cumsum(rng.normal(size=(n,))).astype(np.float32)
        truth[s] = g
        for r in range(n_ranks):
            w.put("var/x", g[r * per:(r + 1) * per], global_shape=(n,),
                  offset=(r * per,), rank=r)
        w.end_step()
    w.close()
    return truth


def test_var_minmax_from_metadata(tmpdir_path):
    truth = _write_series(tmpdir_path / "s.bp4")
    MONITOR.reset()
    r = BpReader(tmpdir_path / "s.bp4")
    for s, g in truth.items():
        lo, hi = r.var_minmax(s, "var/x")
        assert lo == float(g.min()) and hi == float(g.max())
    assert _subfile_reads() == 0


def test_var_nbytes_and_ratio(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", codec="none")
    r = BpReader(tmpdir_path / "s.bp4")
    raw, stored = r.var_nbytes(0, "var/x")
    assert raw == 128 * 4
    # codec none: stored = raw + per-block headers
    assert stored >= raw


def test_chunks_in_box_and_iter(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", n_ranks=8, n=128)
    r = BpReader(tmpdir_path / "s.bp4")
    chunks = list(r.iter_chunks(0, "var/x"))
    assert len(chunks) == 8
    assert all(c.vmin is not None for c in chunks)
    # box [20, 52) covers rank chunks 1..3 (16 elements each)
    plan = r.chunks_in_box(0, "var/x", (20,), (32,))
    assert sorted(c.offset[0] for c in plan) == [16, 32, 48]


def test_layout_matches_aggregators(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", n_ranks=8, aggregators=3)
    r = BpReader(tmpdir_path / "s.bp4")
    lay = r.layout()
    assert sorted(lay) == [0, 1, 2]
    # occupancy reconstructed from chunk tables matches the files on disk
    for agg, d in lay.items():
        assert d["end"] == (tmpdir_path / "s.bp4" / f"data.{agg}").stat().st_size


def test_lazy_metadata_parsing(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", steps=5)
    r = BpReader(tmpdir_path / "s.bp4")
    assert r._meta == {}                      # nothing parsed at open
    r.var_names(3)
    assert sorted(r._meta) == [3]             # only the touched step
    assert sorted(r.steps) == [0, 1, 2, 3, 4]  # compat view parses all


def test_variables_union(tmpdir_path):
    _write_series(tmpdir_path / "s.bp4", steps=3)
    r = BpReader(tmpdir_path / "s.bp4")
    v = r.variables()["var/x"]
    assert v["steps"] == [0, 1, 2]
    assert v["shape"] == (128,) and v["chunks_per_step"] == 8


# ----------------------------------------------------------------- jbpls
def test_jbpls_metadata_only_100_steps(tmpdir_path, capsys):
    """Acceptance: list a >=100-step series with ZERO data.* reads."""
    n_steps = 120
    _write_series(tmpdir_path / "big.bp4", n_ranks=4, steps=n_steps, n=64)
    MONITOR.reset()
    rc = jbpls.main([str(tmpdir_path / "big.bp4"), "-l", "-s", "-L", "-A"])
    out = capsys.readouterr().out
    assert rc == 0
    assert f"steps: {n_steps} (0..{n_steps - 1})" in out
    assert "var/x" in out and "min/max" in out
    assert _subfile_reads() == 0, \
        "jbpls touched a data.* subfile — the O(metadata) guarantee broke"


def test_jbpls_dump_reads_payload(tmpdir_path, capsys):
    truth = _write_series(tmpdir_path / "s.bp4")
    MONITOR.reset()
    rc = jbpls.main([str(tmpdir_path / "s.bp4"), "--dump", "var/x",
                     "--step", "1"])
    assert rc == 0
    assert _subfile_reads() > 0               # --dump is the documented exception
    assert f"{truth[1][0]:.6g}"[:6] in capsys.readouterr().out


def test_jbpls_json_and_filters(tmpdir_path, capsys):
    import json
    _write_series(tmpdir_path / "s.bp4", steps=3)
    rc = jbpls.main([str(tmpdir_path / "s.bp4"), "--json", "--var", "var"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["variables"]["var/x"]["steps"] == [0, 1, 2]
    assert doc["minmax"]["var/x"] is not None


def test_jbpls_not_a_series(tmpdir_path, capsys):
    assert jbpls.main([str(tmpdir_path)]) == 2
    assert "no md.idx" in capsys.readouterr().err


def test_jbpls_minmax_spans_all_steps(tmpdir_path):
    """The listed range is the whole series', not the last step's."""
    path = tmpdir_path / "s.bp4"
    w = BpWriter(path, 1, EngineConfig())
    for s, (lo, hi) in enumerate([(-9.0, 9.0), (-1.0, 1.0)]):
        w.begin_step(s)
        w.put("x", np.linspace(lo, hi, 16, dtype=np.float32),
              global_shape=(16,), offset=(0,), rank=0)
        w.end_step()
    w.close()
    sv = jbpls.survey(BpReader(path))
    assert sv["minmax"]["x"] == (-9.0, 9.0)   # extrema live in step 0


def test_chunk_stats_nan_safe_and_json_strict(tmpdir_path, capsys):
    """NaN/inf blocks never leak NaN tokens into md.0 or jbpls --json."""
    import json
    path = tmpdir_path / "s.bp4"
    w = BpWriter(path, 1, EngineConfig())
    w.begin_step(0)
    w.put("mixed", np.array([np.nan, 1.0, np.inf, -2.0], np.float32),
          global_shape=(4,), offset=(0,), rank=0)
    w.put("allnan", np.full(4, np.nan, np.float32),
          global_shape=(4,), offset=(0,), rank=0)
    w.end_step()
    w.close()
    r = BpReader(path)
    assert r.var_minmax(0, "mixed") == (-2.0, 1.0)   # finite values only
    assert r.var_minmax(0, "allnan") is None
    assert jbpls.main([str(path), "--json"]) == 0
    strict = json.loads(capsys.readouterr().out,
                        parse_constant=lambda c: (_ for _ in ()).throw(
                            ValueError(f"non-strict token {c}")))
    assert strict["minmax"]["allnan"] is None


def test_jbpls_bad_step_and_dump_exit_cleanly(tmpdir_path, capsys):
    _write_series(tmpdir_path / "s.bp4", steps=2)
    assert jbpls.main([str(tmpdir_path / "s.bp4"), "--step", "99"]) == 1
    assert "no valid step 99" in capsys.readouterr().err
    assert jbpls.main([str(tmpdir_path / "s.bp4"), "--dump", "nope"]) == 1
    assert "no variable 'nope'" in capsys.readouterr().err


# -------------------------------------------------- SstStream lifecycle
def test_sst_close_with_full_queue_and_no_consumer():
    """The deadlock fix: close() must return even when nobody drains."""
    stream = SstStream(queue_depth=1)
    stream.begin_step(0)
    stream.put("x", np.ones(4))
    stream.end_step()                          # queue now full
    done = threading.Event()

    def closer():
        stream.close()
        done.set()

    t = threading.Thread(target=closer, daemon=True)
    t.start()
    assert done.wait(timeout=5), "close() deadlocked on a full queue"
    # a late consumer still receives the queued step, then a clean end
    got = list(stream.steps(timeout=2))
    assert len(got) == 1 and got[0][0] == 0


def test_sst_steps_timeout_ends_iterator():
    """steps(timeout=...) ends cleanly instead of leaking queue.Empty."""
    stream = SstStream(queue_depth=2)
    t0 = time.monotonic()
    assert list(stream.steps(timeout=0.3)) == []
    assert 0.2 < time.monotonic() - t0 < 2.0


def test_sst_steps_timeout_is_per_step():
    stream = SstStream(queue_depth=4)
    for s in range(3):
        stream.begin_step(s)
        stream.put("x", np.full(2, s))
        stream.end_step()
    stream.close()
    got = [s for s, _ in stream.steps(timeout=0.5)]
    assert got == [0, 1, 2]


def test_sst_blocked_consumer_wakes_on_close():
    """A consumer already blocked in steps() (no timeout) ends after close."""
    stream = SstStream(queue_depth=2)
    seen = []
    t = attach_consumer(stream, lambda s, v: seen.append(s))
    time.sleep(0.15)                          # consumer is parked in get()
    stream.begin_step(7)
    stream.put("x", np.ones(3))
    stream.end_step()
    stream.close()
    t.join(timeout=5)
    assert not t.is_alive() and seen == [7]


def test_sst_raising_consumer_does_not_wedge_producer():
    """A consumer that raises records t.error, keeps draining, and the
    producer runs to completion instead of deadlocking in end_step."""
    stream = SstStream(queue_depth=1)

    def bad(step, vars):
        raise ValueError("boom")

    t = attach_consumer(stream, bad)
    for s in range(5):                     # >> queue_depth: needs draining
        stream.begin_step(s)
        stream.put("x", np.full(4, s))
        stream.end_step()
    stream.close()
    t.join(timeout=5)
    assert not t.is_alive()
    assert isinstance(t.error, ValueError)


def test_scan_tracks_varying_shapes(tmpdir_path):
    path = tmpdir_path / "s.bp4"
    w = BpWriter(path, 1, EngineConfig())
    for s, n in enumerate([8, 16]):        # dmp-style growing variable
        w.begin_step(s)
        w.put("grow", np.zeros(n, np.float32), global_shape=(n,),
              offset=(0,), rank=0)
        w.end_step()
    w.close()
    v = BpReader(path).scan()["variables"]["grow"]
    assert v["shape"] == (16,) and v["shape_varies"]
    assert v["raw"] == (8 + 16) * 4


def test_jbpls_var_filter_is_consistent(tmpdir_path):
    """--var restricts per-step totals and layout too, not just the
    variables table."""
    path = tmpdir_path / "s.bp4"
    w = BpWriter(path, 1, EngineConfig())
    w.begin_step(0)
    w.put("density/e", np.zeros(8, np.float32), global_shape=(8,),
          offset=(0,), rank=0)
    w.put("vdist/e", np.zeros(32, np.float32), global_shape=(32,),
          offset=(0,), rank=0)
    w.end_step()
    w.close()
    sv = jbpls.survey(BpReader(path), var_filter="density")
    assert list(sv["variables"]) == ["density/e"]
    assert sv["per_step"][0]["n_vars"] == 1
    var_stored = sv["variables"]["density/e"]["stored"]
    assert sv["per_step"][0]["stored"] == var_stored
    assert sum(d["bytes"] for d in sv["layout"].values()) == var_stored


# --------------------------------------------------------- PIC wiring
@pytest.mark.slow
def test_pic_run_with_live_reducers(tmpdir_path):
    import jax
    from repro.pic.simulation import (PicConfig, init_sim,
                                      open_diagnostic_series,
                                      run_with_diagnostics)
    cfg = PicConfig(n_cells=64, capacity=1 << 9, n_electrons=256,
                    n_ions=256, n_neutrals=256)
    rset = ReducerSet([SpeciesCount("density/e", scale=cfg.dx, name="n_e"),
                       Moments("vdist/e")])
    stream = SstStream(queue_depth=2)
    streamed = []
    t = attach_consumer(stream, lambda s, v: streamed.append(s))
    series = open_diagnostic_series(tmpdir_path / "diag.bp4", n_io_ranks=4)
    state = init_sim(cfg, jax.random.PRNGKey(0))
    run_with_diagnostics(state, cfg, series, n_chunks=3, steps_per_chunk=2,
                         n_io_ranks=4, reducers=rset, stream=stream)
    series.close()
    stream.close()
    t.join(timeout=10)
    res = rset.results()
    assert list(res["n_e"]["steps"]) == [2, 4, 6] == streamed
    assert res["moments(vdist/e)"]["steps"] == 3
    # the openPMD series persisted the same iterations
    assert BpReader(tmpdir_path / "diag.bp4").valid_steps() == [2, 4, 6]
