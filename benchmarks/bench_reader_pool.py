"""Aggregate READ throughput scaling with N ReaderPool workers.

The read-side mirror of bench_parallel_io's W1->W4 write story: a series
with many chunks spread over M subfiles is read back as one box selection,
serially vs `read_var(parallel=N)`. The pool overlaps payload reads across
subfiles and decompression across cores (zlib releases the GIL), so on the
2-core CI box parallel=2..4 should beat serial measurably — while
returning bit-identical bytes, which this benchmark asserts every round.

    PYTHONPATH=src python benchmarks/bench_reader_pool.py
"""
from __future__ import annotations

from benchmarks.common import MiB, Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig


def _write_series(path, *, n_ranks, bytes_per_rank, steps, codec,
                  aggregators):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=4)
    w = BpWriter(path, n_ranks, cfg)
    payloads = [pic_payload(r, bytes_per_rank)["particles"]
                for r in range(n_ranks)]
    n = payloads[0].size
    for s in range(steps):
        w.begin_step(s)
        for r, arr in enumerate(payloads):
            w.put("particles/x", arr, global_shape=(n * n_ranks,),
                  offset=(n * r,), rank=r)
        w.end_step()
    w.close()
    return n * n_ranks


def measure(reader: BpReader, steps: int, parallel: int, repeats: int,
            baseline=None):
    """Best-of-N wall clock for a full sweep of every step's array."""
    best = None
    nbytes = 0
    for _ in range(repeats):
        with Timer() as t:
            for s in range(steps):
                arr = reader.read_var(s, "particles/x", parallel=parallel)
        nbytes = arr.nbytes * steps
        if baseline is not None:      # bit parity with the serial read
            assert arr.tobytes() == baseline, \
                f"parallel={parallel} read differs from serial"
        if best is None or t.dt < best:
            best = t.dt
    return best, nbytes / best / MiB


def run(parallel_counts=(1, 2, 4), n_ranks=8, bytes_per_rank=2 * MiB,
        steps=3, codec="zlib", aggregators=4, repeats=3, attempts=3):
    print("mode,parallel,wall_s,agg_MiB_s")
    ok = True
    with tmp_io_dir() as d:
        path = d / "read.bp4"
        _write_series(path, n_ranks=n_ranks, bytes_per_rank=bytes_per_rank,
                      steps=steps, codec=codec, aggregators=aggregators)
        reader = BpReader(path)
        baseline = reader.read_var(steps - 1, "particles/x").tobytes()
        for attempt in range(attempts):
            rows = {}
            for n in parallel_counts:
                rows[f"P{n}"] = measure(reader, steps, n, repeats,
                                        baseline=None if n == 1
                                        else baseline)
            lo, hi = min(parallel_counts), max(parallel_counts)
            # the claim under test: aggregate read throughput RISES with N
            scaling = rows[f"P{hi}"][1] / rows[f"P{lo}"][1]
            ok = hi == lo or scaling > 1.1
            if ok or attempt == attempts - 1:
                break
            print(f"  .. noisy measurement (P{hi}/P{lo} = {scaling:.2f}x), "
                  f"remeasuring")
        reader.close()
    for name, (wall, mib) in rows.items():
        print(f"{name},{name[1:]},{wall:.3f},{mib:.0f}")
        emit(f"reader_pool/{codec}/{name}", wall * 1e6 / steps,
             f"{mib:.0f}MiB/s")
    print(f"\nparallel read plane {'OK' if ok else 'REGRESSED'}: "
          f"P{hi} vs P{lo} aggregate throughput {scaling:.2f}x")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
