"""Paper Fig 9 + Table III: Lustre stripe count x stripe size sweep (write
time of the blosc+1AGGR configuration over emulated OSTs)."""
from __future__ import annotations

from benchmarks.common import MiB, Timer, emit, tmp_io_dir
from benchmarks.bench_openpmd_io import write_steps
from repro.core.bp_engine import EngineConfig
from repro.core.darshan import MONITOR
from repro.core.striping import StripeConfig


def run(n_ranks=64, bytes_per_rank=512 * 1024, steps=2, workers=4,
        counts=(1, 2, 4, 8), sizes=(64 * 1024, 256 * 1024, 1 * MiB, 4 * MiB)):
    for c in counts:
        for s in sizes:
            MONITOR.reset()
            cfg = EngineConfig(aggregators=1, codec="blosc", workers=workers,
                               stripe=StripeConfig(c, s), n_osts=max(counts))
            with tmp_io_dir() as d, Timer() as t:
                write_steps(d, n_ranks, bytes_per_rank, steps, cfg)
            emit(f"striping/count={c}/size={s // 1024}K", t.dt * 1e6 / steps,
                 f"write_time={t.dt:.4f}s")


if __name__ == "__main__":
    run()
