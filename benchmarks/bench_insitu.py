"""In-situ subsystem benchmarks: rapid metadata extraction + reducer cost.

Two claims measured:

  1. `jbpls`-style listing of an N-step series is O(metadata): it reads
     md.idx/md.0 only, so it beats a full payload scan by orders of
     magnitude and performs ZERO `data.*` reads (checked via
     `DarshanMonitor` counters, exactly like the paper attributes I/O
     with Darshan).
  2. Live reduction over an `SstStream` costs the producer almost nothing:
     the reducers run on the consumer thread, so producer wall time with an
     attached ReducerSet stays within a small factor of the bare stream.

    PYTHONPATH=src python -m benchmarks.run --only insitu [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, tmp_io_dir
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.sst_engine import SstStream
from repro.insitu import Histogram, Moments, ReducerSet, attach_reducers
from repro.tools import jbpls


def _write_series(path, *, n_steps, n_ranks, n_cells, codec="blosc"):
    w = BpWriter(path, n_ranks, EngineConfig(aggregators=min(4, n_ranks),
                                             codec=codec, workers=4))
    rng = np.random.default_rng(0)
    per = n_cells // n_ranks
    for s in range(n_steps):
        w.begin_step(s)
        g = np.cumsum(rng.normal(scale=1e-2, size=n_cells)).astype(np.float32)
        for name in ("density/e", "density/D", "vdist/e"):
            for r in range(n_ranks):
                w.put(name, g[r * per:(r + 1) * per], global_shape=(n_cells,),
                      offset=(r * per,), rank=r)
        w.end_step()
    w.close()


def bench_metadata_vs_scan(*, n_steps, n_ranks, n_cells):
    with tmp_io_dir() as d:
        path = d / "series.bp4"
        _write_series(path, n_steps=n_steps, n_ranks=n_ranks,
                      n_cells=n_cells)

        MONITOR.reset()
        with Timer() as t_meta:
            reader = BpReader(path)
            sv = jbpls.survey(reader)
            jbpls.format_listing(sv, long_listing=True, show_layout=True)
        rep = MONITOR.report()["files"]
        data_reads = sum(c.get("POSIX_READS", 0) + c.get("POSIX_BYTES_READ", 0)
                         for p, c in rep.items() if "data." in p)
        assert data_reads == 0, "jbpls listing touched a subfile"
        assert len(sv["steps"]) == n_steps

        with Timer() as t_scan:
            reader = BpReader(path)
            total = 0
            for s in reader.valid_steps():
                for name in reader.var_names(s):
                    total += reader.read_var(s, name).nbytes
        emit("insitu/jbpls_list", t_meta.dt * 1e6,
             f"steps={n_steps} data_reads=0")
        emit("insitu/full_scan", t_scan.dt * 1e6,
             f"bytes={total} speedup={t_scan.dt / max(t_meta.dt, 1e-9):.1f}x")
        return t_meta.dt, t_scan.dt


def bench_reducer_overhead(*, n_steps, n_cells, repeats=3):
    """Producer wall time: bare stream vs stream + attached reducers."""
    rng = np.random.default_rng(1)
    payload = [np.cumsum(rng.normal(scale=1e-2, size=n_cells))
               .astype(np.float32) for _ in range(n_steps)]

    def produce(rset):
        stream = SstStream(queue_depth=4)
        t = attach_reducers(stream, rset) if rset is not None else None
        if t is None:
            # bare run still needs a consumer draining the bounded queue
            from repro.core.sst_engine import attach_consumer
            t = attach_consumer(stream, lambda step, vars: None)
        with Timer() as tm:
            for s, arr in enumerate(payload):
                stream.begin_step(s)
                stream.put("density/e", arr, global_shape=arr.shape,
                           offset=(0,))
                stream.end_step()
            stream.close()
        t.join(timeout=30)
        return tm.dt

    bare = min(produce(None) for _ in range(repeats))
    reduced = min(produce(ReducerSet([
        Moments("density/e"),
        Histogram("density/e", bins=64, range=(-5.0, 5.0)),
    ])) for _ in range(repeats))
    emit("insitu/producer_bare", bare / n_steps * 1e6, f"steps={n_steps}")
    emit("insitu/producer_reduced", reduced / n_steps * 1e6,
         f"overhead={(reduced / max(bare, 1e-9) - 1) * 100:.0f}%")
    return bare, reduced


def run(n_steps=200, n_ranks=8, n_cells=4096):
    bench_metadata_vs_scan(n_steps=n_steps, n_ranks=n_ranks, n_cells=n_cells)
    bench_reducer_overhead(n_steps=max(n_steps // 2, 20), n_cells=n_cells)


if __name__ == "__main__":
    run()
