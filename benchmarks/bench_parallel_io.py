"""Aggregate write throughput scaling with W real writer processes.

The paper's Fig. 1 story: N ranks stream simultaneously into M aggregated
subfiles. `BpWriter` (and the async pipeline) drive every rank from ONE
Python process, so compression + append throughput is bounded by one core
and one GIL; `ParallelBpWriter` fans the per-aggregator work out to W
spawned writer processes. With a CPU-bound codec the aggregate throughput
should scale with W — that scaling (W=1 -> W=4) is what this benchmark
demonstrates, against the single-process sync writer as the floor.

Worker spawn/teardown is excluded from the timed region up to the ready
handshake (ParallelBpWriter.__init__ blocks until every worker has its
subfile + shard open); close() IS timed — it contains the final fsyncs a
fair comparison must charge to both engines.

    PYTHONPATH=src python benchmarks/bench_parallel_io.py
"""
from __future__ import annotations

from benchmarks.common import MiB, Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.parallel_engine import ParallelBpWriter


def _write_loop(w, payloads, n_ranks, steps):
    total = 0
    for s in range(steps):
        w.begin_step(s)
        for r, arr in enumerate(payloads):
            total += arr.nbytes
            w.put("particles/x", arr, global_shape=(arr.size * n_ranks,),
                  offset=(arr.size * r,), rank=r)
        w.end_step()
    w.close()
    return total


def measure(mode, n_writers, *, n_ranks, bytes_per_rank, steps, codec,
            repeats):
    """Best-of-N wall clock for one engine config; verifies readback."""
    cfg = EngineConfig(aggregators=max(n_writers, 1), codec=codec, workers=4)
    payloads = [pic_payload(r, bytes_per_rank)["particles"]
                for r in range(n_ranks)]
    best = None
    for _ in range(repeats):
        with tmp_io_dir() as d:
            path = d / f"{mode}.bp4"
            if mode == "sync":
                w = BpWriter(path, n_ranks, cfg)
            else:
                w = ParallelBpWriter(path, n_ranks, cfg,
                                     n_writers=n_writers)
            with Timer() as t:
                total = _write_loop(w, payloads, n_ranks, steps)
            r = BpReader(path)
            assert r.valid_steps() == list(range(steps))
            assert r.read_var(0, "particles/x").nbytes == \
                bytes_per_rank // 4 * 4 * n_ranks
            r.close()
            if best is None or t.dt < best[0]:
                best = (t.dt, total / t.dt / MiB)
    return best


def run(writer_counts=(1, 2, 4), n_ranks=8, bytes_per_rank=2 * MiB,
        steps=4, codec="zlib", repeats=3, attempts=3):
    print("mode,writers,wall_s,agg_MiB_s")
    ok = True
    for attempt in range(attempts):
        rows = {}
        wall, mib = measure("sync", 1, n_ranks=n_ranks,
                            bytes_per_rank=bytes_per_rank, steps=steps,
                            codec=codec, repeats=repeats)
        rows["sync"] = (wall, mib)
        for nw in writer_counts:
            rows[f"W{nw}"] = measure(
                "parallel", nw, n_ranks=n_ranks,
                bytes_per_rank=bytes_per_rank, steps=steps, codec=codec,
                repeats=repeats)
        lo, hi = min(writer_counts), max(writer_counts)
        # the claim under test: aggregate throughput RISES with W
        scaling = rows[f"W{hi}"][1] / rows[f"W{lo}"][1]
        ok = hi == lo or scaling > 1.1
        if ok or attempt == attempts - 1:
            break
        print(f"  .. noisy measurement (W{hi}/W{lo} = {scaling:.2f}x), "
              f"remeasuring")
    for name, (wall, mib) in rows.items():
        nw = name[1:] if name.startswith("W") else "1(proc)"
        print(f"{name},{nw},{wall:.3f},{mib:.0f}")
        emit(f"parallel_io/{codec}/{name}", wall * 1e6 / steps,
             f"{mib:.0f}MiB/s")
    print(f"\nparallel write plane {'OK' if ok else 'REGRESSED'}: "
          f"W{hi} vs W{lo} aggregate throughput "
          f"{rows[f'W{hi}'][1] / rows[f'W{lo}'][1]:.2f}x")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
