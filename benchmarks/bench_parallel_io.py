"""Aggregate write throughput scaling with W real writer processes, plus
the chunk-transport sweep (pickle vs zero-copy shm) and the composed
async∘parallel mode.

The paper's Fig. 1 story: N ranks stream simultaneously into M aggregated
subfiles. `BpWriter` (and the async pipeline) drive every rank from ONE
Python process, so compression + append throughput is bounded by one core
and one GIL; `ParallelBpWriter` fans the per-aggregator work out to W
spawned writer processes. With a CPU-bound codec the aggregate throughput
should scale with W — that scaling (W=1 -> W=4) is what `run()`
demonstrates, against the single-process sync writer as the floor.

`run_transport_sweep()` isolates the TRANSPORT: chunk payloads from
64 KiB to 64 MiB, codec "none" (so neither compression nor the disk
dominates), comparing

  * `transport="pickle"` — every chunk serialized down a mp queue
    (3+ copies through 64 KiB pipe windows), the PR-3 baseline;
  * `transport="shm"`    — one memcpy into a per-worker shared-memory
    ring, only a header down the queue;
  * `async_commit=True`  — the shm plane behind a bounded snapshot queue:
    the producer pays one deep copy per step, the whole two-phase commit
    runs behind it (`producer_step_s` is the visible latency).

Worker spawn/teardown is excluded from the timed region up to the ready
handshake (ParallelBpWriter.__init__ blocks until every worker has its
subfile + shard open); close() IS timed — it contains the final fsyncs a
fair comparison must charge to both engines.

    PYTHONPATH=src python benchmarks/bench_parallel_io.py            # scaling
    PYTHONPATH=src python -m benchmarks.bench_parallel_io \
        --transport shm --async-commit -w 2 --json sweep.json       # sweep
"""
from __future__ import annotations

import json
import time

from benchmarks.common import GiB, KiB, MiB, Timer, emit, pic_payload, \
    tmp_io_dir
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.core.parallel_engine import ParallelBpWriter


def _write_loop(w, payloads, n_ranks, steps):
    total = 0
    step_s = []
    for s in range(steps):
        w.begin_step(s)
        for r, arr in enumerate(payloads):
            total += arr.nbytes
            w.put("particles/x", arr, global_shape=(arr.size * n_ranks,),
                  offset=(arr.size * r,), rank=r)
        t0 = time.perf_counter()
        w.end_step()
        step_s.append(time.perf_counter() - t0)   # producer-visible latency
    if hasattr(w, "drain"):
        w.drain()
    w.close()
    return total, step_s


def measure(mode, n_writers, *, n_ranks, bytes_per_rank, steps, codec,
            repeats, transport="shm", async_commit=False, base_dir="/tmp"):
    """Best-of-N wall clock for one engine config; verifies readback."""
    cfg = EngineConfig(aggregators=max(n_writers, 1), codec=codec, workers=4)
    payloads = [pic_payload(r, bytes_per_rank)["particles"]
                for r in range(n_ranks)]
    best = None
    for _ in range(repeats):
        with tmp_io_dir(base_dir) as d:
            path = d / f"{mode}.bp4"
            if mode == "sync":
                w = BpWriter(path, n_ranks, cfg)
            else:
                # ring sized to hold a full step per worker so the sweep
                # measures the transport, not fallback spills
                ring = max(64 * MiB, 2 * bytes_per_rank * max(
                    1, n_ranks // max(n_writers, 1)))
                w = ParallelBpWriter(path, n_ranks, cfg,
                                     n_writers=n_writers,
                                     transport=transport,
                                     async_commit=async_commit,
                                     ring_bytes=ring)
            with Timer() as t:
                total, step_s = _write_loop(w, payloads, n_ranks, steps)
            r = BpReader(path)
            assert r.valid_steps() == list(range(steps))
            assert r.read_var(0, "particles/x").nbytes == \
                bytes_per_rank // 4 * 4 * n_ranks
            r.close()
            if best is None or t.dt < best[0]:
                best = (t.dt, total / t.dt / MiB,
                        sum(step_s) / len(step_s))
    return best


# ------------------------------------------------------------- W scaling
def run(writer_counts=(1, 2, 4), n_ranks=8, bytes_per_rank=2 * MiB,
        steps=4, codec="zlib", repeats=3, attempts=3):
    print("mode,writers,wall_s,agg_MiB_s")
    ok = True
    for attempt in range(attempts):
        rows = {}
        wall, mib, _ = measure("sync", 1, n_ranks=n_ranks,
                               bytes_per_rank=bytes_per_rank, steps=steps,
                               codec=codec, repeats=repeats)
        rows["sync"] = (wall, mib)
        for nw in writer_counts:
            w, m, _ = measure(
                "parallel", nw, n_ranks=n_ranks,
                bytes_per_rank=bytes_per_rank, steps=steps, codec=codec,
                repeats=repeats)
            rows[f"W{nw}"] = (w, m)
        lo, hi = min(writer_counts), max(writer_counts)
        # the claim under test: aggregate throughput RISES with W
        scaling = rows[f"W{hi}"][1] / rows[f"W{lo}"][1]
        ok = hi == lo or scaling > 1.1
        if ok or attempt == attempts - 1:
            break
        print(f"  .. noisy measurement (W{hi}/W{lo} = {scaling:.2f}x), "
              f"remeasuring")
    for name, (wall, mib) in rows.items():
        nw = name[1:] if name.startswith("W") else "1(proc)"
        print(f"{name},{nw},{wall:.3f},{mib:.0f}")
        emit(f"parallel_io/{codec}/{name}", wall * 1e6 / steps,
             f"{mib:.0f}MiB/s")
    print(f"\nparallel write plane {'OK' if ok else 'REGRESSED'}: "
          f"W{hi} vs W{lo} aggregate throughput "
          f"{rows[f'W{hi}'][1] / rows[f'W{lo}'][1]:.2f}x")
    return ok


# ------------------------------------------------------- transport sweep
def run_transport_sweep(writer_counts=(1, 2, 4),
                        chunk_sizes=(64 * KiB, 1 * MiB, 4 * MiB, 16 * MiB,
                                     64 * MiB),
                        steps=3, repeats=2, include_async=True,
                        json_path=None, attempts=3, transports=None):
    """Payload-size sweep: effective GB/s for pickle vs shm transport at
    each W, plus the composed async_commit mode (throughput AND the
    producer-visible per-step latency). The claim under test: on big
    chunks the shm transport beats the pickle copy, and the composed mode
    hides the commit from the producer (lower step latency than the pure
    parallel plane at the same W).

    The series goes to tmpfs (when available): this sweep isolates the
    TRANSPORT, so the storage medium must be the same constant for every
    variant instead of burying the copy-path difference under fsync."""
    print("transport,writers,chunk,wall_s,agg_GiB_s,producer_step_s")
    rows = []
    # "shm" still measures the pickle baseline (the speedup gate needs it);
    # "pickle" alone is a baseline-only run with no gate to fail
    transports = transports or ("pickle", "shm")
    variants = [(t, False) for t in transports]
    if include_async and "shm" in transports:
        variants.append(("shm", True))
    for nw in writer_counts:
        n_ranks = max(nw, 2)           # >= 1 chunk per writer every step
        for chunk in chunk_sizes:
            for transport, async_commit in variants:
                wall, mib, step_s = measure(
                    "parallel", nw, n_ranks=n_ranks, bytes_per_rank=chunk,
                    steps=steps, codec="none", repeats=repeats,
                    transport=transport, async_commit=async_commit,
                    base_dir="/dev/shm")
                label = transport + ("+async" if async_commit else "")
                gib = mib * MiB / GiB
                rows.append({"transport": label, "writers": nw,
                             "chunk_bytes": chunk, "wall_s": wall,
                             "agg_GiB_s": gib, "producer_step_s": step_s})
                print(f"{label},{nw},{chunk // KiB}KiB,{wall:.3f},"
                      f"{gib:.2f},{step_s * 1e3:.1f}ms")
                emit(f"parallel_transport/{label}/W{nw}/"
                     f"{chunk // KiB}KiB", wall * 1e6 / steps,
                     f"{gib:.2f}GiB/s")

    def _row(label, nw, chunk):
        for r in rows:
            if (r["transport"], r["writers"], r["chunk_bytes"]) == \
                    (label, nw, chunk):
                return r
        return None

    # acceptance: shm >= 1.3x pickle aggregate throughput at W=2 on the
    # biggest measured >= 4 MiB chunk; async_commit producer latency below
    # the pure plane's. Gated only when both sides were measured; a noisy
    # attempt remeasures EVERY gated variant together so the compared rows
    # always come from the same load conditions.
    ok = True
    w_ref = 2 if 2 in writer_counts else max(writer_counts)
    big = [c for c in chunk_sizes if c >= 4 * MiB] or [max(chunk_sizes)]
    gated = [v for v in variants if v[0] == "shm" or v == ("pickle", False)]
    if {"pickle", "shm"} <= set(transports):
        for attempt in range(attempts):
            shm = _row("shm", w_ref, big[-1])
            pkl = _row("pickle", w_ref, big[-1])
            ac = _row("shm+async", w_ref, big[-1])
            speedup = shm["agg_GiB_s"] / pkl["agg_GiB_s"]
            hid = (ac is None
                   or ac["producer_step_s"] < shm["producer_step_s"])
            ok = speedup >= 1.3 and hid
            if ok or attempt == attempts - 1:
                break
            print(f"  .. noisy measurement (shm/pickle = {speedup:.2f}x, "
                  f"async {'hidden' if hid else 'NOT hidden'} at "
                  f"W{w_ref}/{big[-1] // MiB}MiB), remeasuring")
            for label, async_commit in gated:
                wall, mib, step_s = measure(
                    "parallel", w_ref, n_ranks=max(w_ref, 2),
                    bytes_per_rank=big[-1], steps=steps, codec="none",
                    repeats=repeats, transport=label,
                    async_commit=async_commit, base_dir="/dev/shm")
                r = _row(label + ("+async" if async_commit else ""),
                         w_ref, big[-1])
                r.update(wall_s=wall, agg_GiB_s=mib * MiB / GiB,
                         producer_step_s=step_s)
        print(f"\nshm transport {'OK' if ok else 'REGRESSED'}: "
              f"{speedup:.2f}x pickle at W{w_ref}, "
              f"{big[-1] // MiB}MiB chunks")
        if ac is not None:
            print(f"async_commit producer step latency "
                  f"{ac['producer_step_s'] * 1e3:.1f}ms vs pure parallel "
                  f"{shm['producer_step_s'] * 1e3:.1f}ms "
                  f"({'hidden' if hid else 'NOT hidden'})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"rows": rows, "ok": ok}, f, indent=1)
    return ok


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", choices=("pickle", "shm", "both"),
                    default=None,
                    help="run the transport sweep: 'shm'/'both' compare "
                         "against the pickle baseline (speedup gate), "
                         "'pickle' measures the baseline alone (no gate)")
    ap.add_argument("--async-commit", action="store_true",
                    help="include the composed async_commit mode")
    ap.add_argument("-w", "--writers", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--chunks-kib", type=int, nargs="+", default=None,
                    help="chunk sizes in KiB (default 64..65536)")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI (<= 4 MiB chunks)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.transport is None and not args.async_commit:
        return 0 if run() else 1
    if args.chunks_kib is not None:
        chunks = tuple(k * KiB for k in args.chunks_kib)
    elif args.quick:
        chunks = (64 * KiB, 4 * MiB, 16 * MiB)
    else:
        chunks = (64 * KiB, 1 * MiB, 4 * MiB, 16 * MiB, 64 * MiB)
    transports = (("pickle",) if args.transport == "pickle"
                  else ("pickle", "shm"))
    ok = run_transport_sweep(
        writer_counts=tuple(args.writers), chunk_sizes=chunks,
        steps=args.steps, repeats=args.repeats,
        include_async=args.async_commit, json_path=args.json,
        transports=transports)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
