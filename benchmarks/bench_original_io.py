"""Paper Fig 2/3 + Table II (baseline): BIT1 Original file-per-rank I/O.

Write throughput vs rank count for the pre-openPMD path: one small text .dat
per rank per diagnostic + one binary .dmp per rank per checkpoint. Shows the
metadata-dominated scaling collapse the paper measures."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GiB, MiB, Timer, emit, pic_payload, tmp_io_dir
from repro.core.darshan import MONITOR
from repro.core.original_io import write_dat, write_dmp


def run(rank_counts=(4, 16, 64, 256), bytes_per_rank=256 * 1024, dumps=3):
    for n_ranks in rank_counts:
        MONITOR.reset()
        with tmp_io_dir() as d, Timer() as t:
            for step in range(dumps):
                for r in range(n_ranks):
                    arrs = pic_payload(r, bytes_per_rank)
                    write_dat(d, r, step, {k: v[:512] for k, v in arrs.items()})
                    write_dmp(d, r, step, arrs)
            nfiles = MONITOR.total_files_written()
            nbytes = MONITOR.report()["total"]["POSIX_BYTES_WRITTEN"]
        thr = nbytes / t.dt / GiB
        emit(f"original_io/ranks={n_ranks}", t.dt * 1e6 / (dumps * n_ranks),
             f"{thr:.3f}GiB/s files={nfiles} "
             f"avg={nbytes/max(nfiles,1)/MiB:.3f}MiB")


if __name__ == "__main__":
    run()
