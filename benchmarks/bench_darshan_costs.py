"""Paper Fig 5: Darshan avg I/O cost per process (reads / metadata / writes)
for Original I/O vs openPMD+BP4 — the metadata-collapse result."""
from __future__ import annotations

from benchmarks.common import Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.original_io import write_dat, write_dmp


def run(n_ranks=64, bytes_per_rank=128 * 1024, dumps=3):
    # --- original ---------------------------------------------------------
    MONITOR.reset()
    with tmp_io_dir() as d:
        for step in range(dumps):
            for r in range(n_ranks):
                arrs = pic_payload(r, bytes_per_rank)
                write_dat(d, r, step, {k: v[:512] for k, v in arrs.items()})
                write_dmp(d, r, step, arrs)
        orig = MONITOR.cost_per_process(n_ranks)
    emit("darshan/original meta_s", orig["meta_s"] * 1e6,
         f"read={orig['read_s']:.6f}s write={orig['write_s']:.6f}s "
         f"meta={orig['meta_s']:.6f}s")

    # --- openPMD + BP4 ------------------------------------------------------
    MONITOR.reset()
    with tmp_io_dir() as d:
        w = BpWriter(d / "s.bp4", n_ranks,
                     EngineConfig(aggregators=4, codec="none", workers=4))
        for s in range(dumps):
            w.begin_step(s)
            for r in range(n_ranks):
                arr = pic_payload(r, bytes_per_rank)["particles"]
                w.put("p/x", arr, global_shape=(arr.size * n_ranks,),
                      offset=(arr.size * r,), rank=r)
            w.end_step()
        w.close()
        bp = MONITOR.cost_per_process(n_ranks)
    emit("darshan/openpmd_bp4 meta_s", bp["meta_s"] * 1e6,
         f"read={bp['read_s']:.6f}s write={bp['write_s']:.6f}s "
         f"meta={bp['meta_s']:.6f}s")
    if bp["meta_s"] > 0:
        emit("darshan/meta_reduction", 0.0,
             f"{(1 - bp['meta_s'] / max(orig['meta_s'], 1e-12)) * 100:.2f}%")


if __name__ == "__main__":
    run()
