"""Paper Fig 5: Darshan avg I/O cost per process (reads / metadata / writes)
for Original I/O vs openPMD+BP4 — the metadata-collapse result.

Also the home of the instrumentation-overhead sweep
(`run_tracing_overhead`): the cost contract is "off = one branch per op,
on = bounded ring-buffer appends / histogram bumps", and the sweep
measures the same BpWriter write path with the full observability plane
(DXT tracing AND metrics histograms + step journal) off vs on,
interleaved min-of-N trials, and ASSERTS the overhead stays ≤5% — CI
runs this, so a regression that makes the hot-path hooks expensive fails
the build, not just a dashboard."""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpWriter, EngineConfig
from repro.core.darshan import MONITOR
from repro.core.dxt import TRACER
from repro.core.metrics import METRICS
from repro.core.original_io import write_dat, write_dmp


def run(n_ranks=64, bytes_per_rank=128 * 1024, dumps=3):
    # --- original ---------------------------------------------------------
    MONITOR.reset()
    with tmp_io_dir() as d:
        for step in range(dumps):
            for r in range(n_ranks):
                arrs = pic_payload(r, bytes_per_rank)
                write_dat(d, r, step, {k: v[:512] for k, v in arrs.items()})
                write_dmp(d, r, step, arrs)
        orig = MONITOR.cost_per_process(n_ranks)
    emit("darshan/original meta_s", orig["meta_s"] * 1e6,
         f"read={orig['read_s']:.6f}s write={orig['write_s']:.6f}s "
         f"meta={orig['meta_s']:.6f}s")

    # --- openPMD + BP4 ------------------------------------------------------
    MONITOR.reset()
    with tmp_io_dir() as d:
        w = BpWriter(d / "s.bp4", n_ranks,
                     EngineConfig(aggregators=4, codec="none", workers=4))
        for s in range(dumps):
            w.begin_step(s)
            for r in range(n_ranks):
                arr = pic_payload(r, bytes_per_rank)["particles"]
                w.put("p/x", arr, global_shape=(arr.size * n_ranks,),
                      offset=(arr.size * r,), rank=r)
            w.end_step()
        w.close()
        bp = MONITOR.cost_per_process(n_ranks)
    emit("darshan/openpmd_bp4 meta_s", bp["meta_s"] * 1e6,
         f"read={bp['read_s']:.6f}s write={bp['write_s']:.6f}s "
         f"meta={bp['meta_s']:.6f}s")
    if bp["meta_s"] > 0:
        emit("darshan/meta_reduction", 0.0,
             f"{(1 - bp['meta_s'] / max(orig['meta_s'], 1e-12)) * 100:.2f}%")


def _traced_write_pass(d, n_ranks, bytes_per_rank, steps, *,
                       device=False, arrays=None):
    """One full BpWriter write pass; returns wall seconds. `device=True`
    runs the on-chip compression pipeline (codec=blosc +
    device_compress, jax.Array chunks in `arrays`) so the sweep also
    covers the COMPRESS_DEVICE_BYTES/COMPRESS_OVERLAP_TIME recording."""
    cfg = (EngineConfig(aggregators=2, codec="blosc", device_compress=True)
           if device else EngineConfig(aggregators=2, codec="none"))
    with Timer() as t:
        w = BpWriter(d / "s.bp4", n_ranks, cfg)
        for s in range(steps):
            w.begin_step(s)
            for r in range(n_ranks):
                arr = (arrays[r] if arrays is not None
                       else pic_payload(r, bytes_per_rank)["particles"])
                w.put("p/x", arr, global_shape=(arr.size * n_ranks,),
                      offset=(arr.size * r,), rank=r)
            w.end_step()
        w.close()
    return t.dt


def run_tracing_overhead(n_ranks=16, bytes_per_rank=256 * 1024, steps=3,
                         trials=5, max_overhead_pct=5.0, device=False):
    """Observability-overhead sweep: the same write path with the whole
    plane (DXT tracing + metrics histograms + step journal) off vs on,
    interleaved (off, on, off, on, ...) so drift in the machine hits both
    arms, min-of-N per arm. Asserts on-vs-off overhead ≤5%.

    `device=True` measures the device-compress write path instead (on-chip
    bitshuffle + the new compress counters recording on every chunk) —
    the observability budget must hold there too."""
    was_enabled = TRACER.enabled
    metrics_was_enabled = METRICS.enabled
    t_off, t_on = float("inf"), float("inf")
    arrays = None
    if device:
        import jax.numpy as jnp
        # H2D + jit warm-up OUTSIDE the timed region, shared by both arms
        arrays = [jnp.asarray(pic_payload(r, bytes_per_rank)["particles"])
                  for r in range(n_ranks)]
        with tmp_io_dir("/dev/shm") as d:
            _traced_write_pass(d, n_ranks, bytes_per_rank, 1,
                               device=True, arrays=arrays)
    try:
        for _ in range(trials):
            for mode_on in (False, True):
                MONITOR.reset()
                TRACER.disable()
                TRACER.reset()
                METRICS.disable()
                METRICS.reset()
                if mode_on:
                    TRACER.enable()
                    METRICS.enable()
                with tmp_io_dir("/dev/shm") as d:
                    dt = _traced_write_pass(d, n_ranks, bytes_per_rank, steps,
                                            device=device, arrays=arrays)
                if mode_on:
                    t_on = min(t_on, dt)
                else:
                    t_off = min(t_off, dt)
        n_events = TRACER.stats()["events"]
    finally:
        TRACER.disable()
        TRACER.reset()
        if was_enabled:
            TRACER.enable()
        METRICS.disable()
        METRICS.reset()
        if metrics_was_enabled:
            METRICS.enable()
    tag = "dxt_device" if device else "dxt"
    overhead_pct = (t_on / t_off - 1.0) * 100.0
    emit(f"darshan/{tag}_off s", t_off * 1e6, f"{t_off:.6f}s min of {trials}")
    emit(f"darshan/{tag}_on s", t_on * 1e6,
         f"{t_on:.6f}s min of {trials}, {n_events} events/run")
    emit(f"darshan/{tag}_overhead_pct", overhead_pct,
         f"{overhead_pct:+.2f}% (budget {max_overhead_pct:.0f}%)")
    assert overhead_pct <= max_overhead_pct, (
        f"DXT tracing overhead {overhead_pct:+.2f}% exceeds the "
        f"{max_overhead_pct:.0f}% budget (off={t_off:.6f}s on={t_on:.6f}s)")
    return overhead_pct


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Darshan cost comparison + DXT tracing-overhead sweep")
    ap.add_argument("--overhead-only", action="store_true",
                    help="run only the tracing-overhead sweep (CI smoke)")
    ap.add_argument("--ranks", type=int, default=16)
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--max-overhead-pct", type=float, default=5.0)
    ap.add_argument("--device", action="store_true",
                    help="measure the device-compress write path (on-chip "
                         "bitshuffle + compress counters) instead")
    args = ap.parse_args()
    if not args.overhead_only:
        run()
    run_tracing_overhead(n_ranks=args.ranks, trials=args.trials,
                         max_overhead_pct=args.max_overhead_pct,
                         device=args.device)
