"""Paper Fig 3/4: openPMD + JBP(BP4) write throughput vs rank count —
the headline comparison against Original I/O."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GiB, MiB, Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpWriter, EngineConfig
from repro.core.darshan import MONITOR


def write_steps(d, n_ranks, bytes_per_rank, steps, cfg):
    w = BpWriter(d / "sim.bp4", n_ranks, cfg)
    total = 0
    for s in range(steps):
        w.begin_step(s)
        for r in range(n_ranks):
            arr = pic_payload(r, bytes_per_rank)["particles"]
            total += arr.nbytes
            w.put("particles/x", arr, global_shape=(arr.size * n_ranks,),
                  offset=(arr.size * r,), rank=r)
        w.end_step()
    w.close()
    return total


def run(rank_counts=(4, 16, 64, 256), bytes_per_rank=256 * 1024, steps=3,
        aggregators=4, workers=4):
    for n_ranks in rank_counts:
        MONITOR.reset()
        cfg = EngineConfig(aggregators=min(aggregators, n_ranks),
                           codec="none", workers=workers)
        with tmp_io_dir() as d, Timer() as t:
            total = write_steps(d, n_ranks, bytes_per_rank, steps, cfg)
            nfiles = MONITOR.total_files_written()
        thr = total / t.dt / GiB
        emit(f"openpmd_bp4/ranks={n_ranks}", t.dt * 1e6 / (steps * n_ranks),
             f"{thr:.3f}GiB/s files={nfiles} "
             f"avg={total/max(nfiles,1)/MiB:.2f}MiB")


if __name__ == "__main__":
    run()
