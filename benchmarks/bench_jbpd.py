"""Client-scaling benchmark for the jbpd served read plane.

The workload the daemon exists for: N analysis clients all want the same
box out of the same series (the "everyone plots the last step" pattern).
Without the daemon each client opens its own `BpReader` and pays the full
payload read + decompress per read. Through jbpd the first read fills the
LRU chunk cache and every subsequent read — from ANY client — is a memcpy
out of shared pages (shm ring handoff), with concurrent cold reads
coalesced onto one fetch.

Claims asserted every run:
  * aggregate throughput of N concurrent `SeriesClient`s re-reading a
    shared box is >= 2x the N-independent-readers baseline,
  * the coalescing counter ended >= 1 (concurrent cold reads shared fetches),
  * every served read is bit-identical to a direct `BpReader.read_var`.

    PYTHONPATH=src python benchmarks/bench_jbpd.py
"""
from __future__ import annotations

import threading

from benchmarks.common import MiB, Timer, emit, pic_payload, tmp_io_dir
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig
from repro.serve.jbpd import JbpDaemon, SeriesClient, SeriesServer


def _write_series(path, *, n_ranks, bytes_per_rank, steps, codec,
                  aggregators):
    cfg = EngineConfig(aggregators=aggregators, codec=codec, workers=4)
    w = BpWriter(path, n_ranks, cfg)
    payloads = [pic_payload(r, bytes_per_rank)["particles"]
                for r in range(n_ranks)]
    n = payloads[0].size
    for s in range(steps):
        w.begin_step(s)
        for r, arr in enumerate(payloads):
            w.put("particles/x", arr, global_shape=(n * n_ranks,),
                  offset=(n * r,), rank=r)
        w.end_step()
    w.close()


def _drive(n_clients: int, repeats: int, read_fn, baseline: bytes) -> float:
    """`n_clients` threads each call `read_fn(client_index)` `repeats`
    times; returns wall seconds for ALL of them. Every read's bytes are
    checked against `baseline`."""
    errs: list[BaseException] = []
    start = threading.Barrier(n_clients + 1)

    def client(i):
        try:
            start.wait()
            for _ in range(repeats):
                got = read_fn(i)
                if got.tobytes() != baseline:
                    raise AssertionError(f"client {i}: served bytes differ "
                                         f"from direct read")
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errs.append(e)

    ts = [threading.Thread(target=client, args=(i,))
          for i in range(n_clients)]
    for t in ts:
        t.start()
    start.wait()
    with Timer() as t:
        for th in ts:
            th.join()
    if errs:
        raise errs[0]
    return t.dt


def run(n_clients=4, n_ranks=4, bytes_per_rank=2 * MiB, codec="zlib",
        aggregators=4, repeats=6, attempts=3):
    print("mode,clients,wall_s,agg_MiB_s")
    with tmp_io_dir() as d:
        path = d / "served.bp4"
        _write_series(path, n_ranks=n_ranks, bytes_per_rank=bytes_per_rank,
                      steps=1, codec=codec, aggregators=aggregators)
        with BpReader(path) as r:
            baseline = r.read_var(0, "particles/x").tobytes()
        total = len(baseline) * n_clients * repeats

        for attempt in range(attempts):
            # baseline: N independent opens, every read decompresses
            readers = [BpReader(path) for _ in range(n_clients)]
            try:
                wall_direct = _drive(
                    n_clients, repeats,
                    lambda i: readers[i].read_var(0, "particles/x"),
                    baseline)
            finally:
                for rd in readers:
                    rd.close()

            # served: one daemon, shared cache, shm handoff. Ring sized to
            # the response (2x the box) — prefaulting the 64 MiB default
            # would bill the daemon's cold start to the steady-state claim.
            server = SeriesServer([path])
            ring = 2 * n_ranks * bytes_per_rank
            with JbpDaemon(server, socket_path=d / "bench.sock",
                           ring_bytes=ring) as daemon:
                daemon.start()
                clients = [SeriesClient(daemon.address, path)
                           for _ in range(n_clients)]
                try:
                    wall_served = _drive(
                        n_clients, repeats,
                        lambda i: clients[i].read_var(0, "particles/x"),
                        baseline)
                    stats = clients[0].stats()
                finally:
                    for c in clients:
                        c.close()

            speedup = wall_direct / wall_served
            coalesced = stats["counters"]["SERVICE_COALESCED"]
            hits = stats["counters"]["SERVICE_CACHE_HIT"]
            ok = speedup >= 2.0 and coalesced >= 1
            if ok or attempt == attempts - 1:
                break
            print(f"  .. noisy measurement (served/direct = {speedup:.2f}x, "
                  f"coalesced={coalesced:.0f}), remeasuring")

    mib_direct = total / wall_direct / MiB
    mib_served = total / wall_served / MiB
    print(f"direct,{n_clients},{wall_direct:.3f},{mib_direct:.0f}")
    print(f"served,{n_clients},{wall_served:.3f},{mib_served:.0f}")
    emit(f"jbpd/{codec}/direct_x{n_clients}",
         wall_direct * 1e6 / (n_clients * repeats), f"{mib_direct:.0f}MiB/s")
    emit(f"jbpd/{codec}/served_x{n_clients}",
         wall_served * 1e6 / (n_clients * repeats), f"{mib_served:.0f}MiB/s")
    emit(f"jbpd/{codec}/speedup_x{n_clients}", 0.0,
         f"{speedup:.2f}x;hits={hits:.0f};coalesced={coalesced:.0f}")
    print(f"\nserved read plane {'OK' if ok else 'REGRESSED'}: "
          f"{n_clients} clients {speedup:.2f}x vs independent readers, "
          f"cache hits {hits:.0f}, coalesced {coalesced:.0f}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
