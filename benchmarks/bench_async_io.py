"""Sync vs async JBP write pipeline: effective throughput + compute overlap.

Models the paper's production loop: each step the simulation "computes"
(device time, emulated with a sleep — XLA compute does not hold the host)
and then dumps a diagnostic payload. The sync engine serialises
compute -> write; the async engine hides the write behind the next step's
compute, so its *effective* write throughput (bytes / time NOT spent
computing) rises toward the raw disk rate and its overlap fraction
(share of write time hidden behind compute) goes to ~1.

    PYTHONPATH=src python benchmarks/bench_async_io.py
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import MiB, Timer, pic_payload, tmp_io_dir
from repro.core.async_engine import AsyncBpWriter
from repro.core.bp_engine import BpReader, BpWriter, EngineConfig


def run_loop(cls, d, *, n_ranks, bytes_per_rank, steps, compute_s, cfg, **kw):
    """compute + dump loop; returns (wall_s, total_bytes)."""
    # payloads pre-staged outside the timed loop — in production they arrive
    # via device->host transfer, not host-side generation
    payloads = [pic_payload(r, bytes_per_rank)["particles"]
                for r in range(n_ranks)]
    w = cls(d, n_ranks, cfg, **kw)
    total = 0
    with Timer() as t:
        for s in range(steps):
            time.sleep(compute_s)               # the PIC step (device-side)
            w.begin_step(s)
            for r, arr in enumerate(payloads):
                total += arr.nbytes
                w.put("particles/x", arr, global_shape=(arr.size * n_ranks,),
                      offset=(arr.size * r,), rank=r)
            w.end_step()
        w.close()                               # async: drains the pipeline
    return t.dt, total


def measure_config(codec, aggs, *, n_ranks, bytes_per_rank, steps, compute_s,
                   repeats):
    """Best-of-N comparison for one codec/aggregator config. Repeats are
    INTERLEAVED between modes: min wall is the standard low-noise estimator
    on shared machines, and alternating the modes makes a load burst hit
    both equally instead of wiping out one mode's whole repeat block."""
    cfg = EngineConfig(aggregators=aggs, codec=codec, workers=4)
    modes = (("sync", BpWriter, {}),
             ("async", AsyncBpWriter, {"queue_depth": 2}))
    rows = {}
    for _ in range(repeats):
        for mode, cls, kw in modes:
            with tmp_io_dir() as d:
                path = d / f"{mode}.bp4"
                wall, total = run_loop(
                    cls, path, n_ranks=n_ranks,
                    bytes_per_rank=bytes_per_rank, steps=steps,
                    compute_s=compute_s, cfg=cfg, **kw)
                # effective write throughput: bytes over the time the
                # producer was NOT doing simulation compute
                io_wall = max(wall - steps * compute_s, 1e-9)
                eff = total / io_wall / MiB
                prof = json.loads((path / "profiling.json").read_text())
                overlap = prof.get("async", {}).get("overlap_fraction", 0.0)
                # the output must stay readable by the standard reader
                r = BpReader(path)
                assert r.valid_steps() == list(range(steps))
                assert r.read_var(0, "particles/x").nbytes == \
                    bytes_per_rank * n_ranks
                best = rows.get(mode)
                if best is None or wall < best[0]:
                    rows[mode] = (wall, eff, overlap)
    return rows


def run(rank_counts=(8,), bytes_per_rank=1 * MiB, steps=8, compute_s=0.08,
        codecs=("none", "blosc"), aggregator_counts=(1, 4), repeats=5,
        attempts=3):
    print("codec,aggs,mode,wall_s,eff_MiB_s,overlap_fraction")
    ok = True
    for codec in codecs:
        for aggs in aggregator_counts:
            # a CPU-starved window can stall one mode's entire repeat block;
            # a config only counts as regressed if it fails `attempts`
            # independent measurements in a row
            for attempt in range(attempts):
                rows = measure_config(
                    codec, aggs, n_ranks=rank_counts[0],
                    bytes_per_rank=bytes_per_rank, steps=steps,
                    compute_s=compute_s, repeats=repeats)
                sync_eff, async_eff = rows["sync"][1], rows["async"][1]
                # 3% noise band: when writes are cheap enough to hide
                # entirely (codec=none, many aggregators) both modes sit at
                # the compute floor and the comparison is a timing tie
                config_ok = (async_eff >= 0.97 * sync_eff and
                             rows["async"][2] > 0.0)
                if config_ok or attempt == attempts - 1:
                    break
                print(f"  .. noisy measurement (async {async_eff:.0f} vs "
                      f"sync {sync_eff:.0f} MiB/s), remeasuring")
            for mode in ("sync", "async"):
                best = rows[mode]
                print(f"{codec},{aggs},{mode},{best[0]:.3f},{best[1]:.0f},"
                      f"{best[2]:.2f}")
            if not config_ok:
                ok = False
                print(f"  !! regression: codec={codec} aggs={aggs} "
                      f"async {async_eff:.0f} MiB/s vs sync "
                      f"{sync_eff:.0f} MiB/s, overlap {rows['async'][2]:.2f}")
    print(f"\nasync pipeline {'OK' if ok else 'REGRESSED'}: effective "
          f"throughput >= sync and nonzero compute overlap on every config"
          if ok else "\nasync pipeline REGRESSED")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
