"""Shared benchmark harness: synthetic PIC-like payloads, timing, CSV."""
from __future__ import annotations

import pathlib
import shutil
import tempfile
import time
from contextlib import contextmanager

import numpy as np

GiB = 1024 ** 3
MiB = 1024 ** 2
KiB = 1024


def pic_payload(rank: int, nbytes: int) -> dict[str, np.ndarray]:
    """Per-rank diagnostic-like arrays (smooth floats — compressible like
    real particle/field data, unlike pure noise)."""
    n = nbytes // 4
    rng = np.random.default_rng(rank)
    base = np.cumsum(rng.normal(scale=1e-3, size=n).astype(np.float32))
    return {"particles": base}


@contextmanager
def tmp_io_dir(base: str = "/tmp"):
    """Scratch dir for one benchmark run. `base="/dev/shm"` puts the series
    on tmpfs — used when the benchmark isolates a non-storage variable
    (e.g. the chunk transport) and the disk must be held constant."""
    if not pathlib.Path(base).is_dir():
        base = "/tmp"
    d = pathlib.Path(tempfile.mkdtemp(prefix="repro-bench-", dir=base))
    try:
        yield d
    finally:
        shutil.rmtree(d, ignore_errors=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")
